"""L2 model tests: architecture invariants, forward variants, training
machinery and the dataset generator."""

import numpy as np
import pytest
import jax.numpy as jnp

from compile import dataset, model as M, nn, train
from compile.kernels import ref


@pytest.fixture(scope="module")
def resnet():
    m = M.resnet32()
    params, state = m.init(0)
    return m, params, state


@pytest.fixture(scope="module")
def mnv2():
    m = M.mobilenetv2()
    params, state = m.init(0)
    return m, params, state


@pytest.fixture(scope="module")
def batch():
    rng = np.random.RandomState(0)
    return jnp.asarray(rng.randn(2, 32, 32, 3).astype(np.float32))


# ---------------------------------------------------------------------------
# Architecture invariants (paper §II-C / §IV-A)
# ---------------------------------------------------------------------------


def test_resnet32_structure(resnet):
    m, _, _ = resnet
    assert len(m.nodes) == 14, "paper: ResNet-32 over up to 14 nodes"
    assert m.exit_nodes() == list(range(1, 14)), "13 exit points"
    assert m.skippable_nodes() == [2, 3, 4, 5, 7, 8, 9, 10, 12, 13], \
        "paper: 10 skip connections"


def test_mnv2_structure(mnv2):
    m, _, _ = mnv2
    assert len(m.nodes) == 11, "paper: MobileNetV2 over up to 11 nodes"
    assert m.exit_nodes() == list(range(1, 11)), "10 exit points"
    assert all(2 <= k <= 10 for k in m.skippable_nodes())


def test_boundary_shapes_chain(resnet):
    m, _, _ = resnet
    shapes = m.boundary_shapes()
    # walking node specs must reproduce the boundary chain
    shape = m.input_shape
    for n in m.nodes:
        assert shapes[n.index] == shape
        _, shape = n.specs(shape)
    assert shape == (10,)


@pytest.mark.parametrize("name", ["resnet32", "mobilenetv2"])
def test_node_specs_cover_table1_kinds(name):
    m = M.build(name)
    kinds = {rec["kind"] for recs in m.node_specs().values() for rec in recs}
    assert "conv" in kinds
    assert "batchnorm" in kinds
    assert "add" in kinds
    if name == "mobilenetv2":
        assert "depthwise_conv" in kinds


# ---------------------------------------------------------------------------
# Forward variants
# ---------------------------------------------------------------------------


def test_forward_full_shape(resnet, batch):
    m, params, state = resnet
    y, _ = m.forward_full(ref, params, state, batch)
    assert y.shape == (2, 10)


def test_forward_exits_match_manual(resnet, batch):
    """forward_all_exits must agree with running forward_exit per exit."""
    m, params, state = resnet
    outs, _ = m.forward_all_exits(ref, params, state, batch)
    for e in [1, 7, 13]:
        manual, _ = m.forward_exit(ref, params, state, batch, e)
        np.testing.assert_allclose(np.asarray(outs[str(e)]), np.asarray(manual),
                                   rtol=1e-5, atol=1e-5)


def test_forward_skip_changes_output(resnet, batch):
    m, params, state = resnet
    full, _ = m.forward_full(ref, params, state, batch)
    skipped, _ = m.forward_skip(ref, params, state, batch, 3)
    assert skipped.shape == full.shape
    assert not np.allclose(np.asarray(full), np.asarray(skipped)), \
        "skipping a block must change the logits"


def test_forward_skip_non_skippable_raises(resnet, batch):
    m, params, state = resnet
    with pytest.raises(AssertionError):
        m.forward_skip(ref, params, state, batch, 6)  # downsampling node


def test_mnv2_forward_variants(mnv2, batch):
    m, params, state = mnv2
    y, _ = m.forward_full(ref, params, state, batch)
    assert y.shape == (2, 10)
    e, _ = m.forward_exit(ref, params, state, batch, m.exit_nodes()[0])
    assert e.shape == (2, 10)
    s, _ = m.forward_skip(ref, params, state, batch, m.skippable_nodes()[0])
    assert s.shape == (2, 10)


# ---------------------------------------------------------------------------
# Training machinery
# ---------------------------------------------------------------------------


def test_adam_reduces_quadratic():
    params = {"w": jnp.asarray([4.0, -3.0])}
    opt = train.adam_init(params)
    import jax
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt = train.adam_update(params, grads, opt, lr=0.1)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_cross_entropy_and_accuracy():
    logits = jnp.asarray([[10.0, 0.0], [0.0, 10.0]])
    labels = jnp.asarray([0, 1])
    assert float(train.cross_entropy(logits, labels)) < 0.01
    assert float(train.accuracy(logits, labels)) == 1.0
    assert float(train.accuracy(logits, jnp.asarray([1, 0]))) == 0.0


def test_one_train_step_decreases_loss():
    m = M.resnet32()
    params, state = m.init(0)
    params = nn.tree_map(jnp.asarray, params)
    state = nn.tree_map(jnp.asarray, state)
    opt = train.adam_init(params)
    step = train.make_train_step(m, 1e-3)
    (x, y), _ = dataset.splits(32, 8, seed=3)
    x, y = jnp.asarray(x), jnp.asarray(y)
    losses = []
    for _ in range(3):
        params, state, opt, loss, _ = step(params, state, opt, x, y)
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"loss did not decrease: {losses}"


def test_weight_save_load_roundtrip(tmp_path):
    m = M.resnet32()
    params, state = m.init(0)
    p = tmp_path / "w.npz"
    train.save_weights(p, params, state)
    params2, state2 = train.load_weights(p, m, seed=1)
    flat1 = nn.tree_flatten(params)
    flat2 = nn.tree_flatten(params2)
    assert len(flat1) == len(flat2)
    for (k1, v1), (k2, v2) in zip(flat1, flat2):
        assert k1 == k2
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_node_weight_stats_shape(resnet):
    m, params, _ = resnet
    stats = train.node_weight_stats(m, params)
    assert set(stats) == {f"n{i}" for i in range(1, 15)} | \
        {f"e{i}" for i in range(1, 14)}
    for v in stats.values():
        assert len(v) == 8  # count, mean, std, q0..q100
        assert v[0] > 0


# ---------------------------------------------------------------------------
# Dataset
# ---------------------------------------------------------------------------


def test_dataset_deterministic():
    x1, y1 = dataset.synth_cifar(16, seed=5)
    x2, y2 = dataset.synth_cifar(16, seed=5)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)


def test_dataset_seeds_differ():
    x1, _ = dataset.synth_cifar(8, seed=1)
    x2, _ = dataset.synth_cifar(8, seed=2)
    assert not np.allclose(x1, x2)


def test_dataset_splits_disjoint_streams():
    (x_tr, _), (x_te, _) = dataset.splits(16, 16, seed=0)
    assert not np.allclose(x_tr, x_te)


def test_dataset_shapes_and_classes():
    x, y = dataset.synth_cifar(64, seed=0)
    assert x.shape == (64, 32, 32, 3)
    assert x.dtype == np.float32
    assert y.min() >= 0 and y.max() < dataset.NUM_CLASSES
    assert len(np.unique(y)) > 3, "labels should cover several classes"


def test_dataset_is_learnable_by_linear_probe():
    """Even a linear model should beat chance on the raw pixels — the
    classes are separable (sanity that training can succeed)."""
    (x, y), (xt, yt) = dataset.splits(512, 128, seed=0)
    xf = x.reshape(len(x), -1)
    xtf = xt.reshape(len(xt), -1)
    # ridge-regression one-vs-all probe
    onehot = np.eye(10, dtype=np.float32)[y]
    w = np.linalg.solve(xf.T @ xf + 50.0 * np.eye(xf.shape[1], dtype=np.float32),
                        xf.T @ onehot)
    acc = (np.argmax(xtf @ w, axis=1) == yt).mean()
    assert acc > 0.3, f"linear probe accuracy {acc}"
