"""AOT path tests: HLO export machinery, weight packing, micro configs and
(when artifacts exist) manifest consistency."""

import json
from pathlib import Path

import numpy as np
import pytest
import jax.numpy as jnp

from compile import aot, model as M, nn
from compile.kernels import pallas_kernels as pk, ref

ARTIFACTS = Path(__file__).resolve().parents[2] / "artifacts"


def test_micro_configs_cover_all_kinds():
    cfgs = aot.micro_configs()
    kinds = {c["kind"] for c in cfgs}
    assert kinds == {
        "conv", "depthwise_conv", "batchnorm", "relu", "add", "dropout",
        "dense", "global_avg_pool", "global_max_pool", "max_pool",
    }
    # every config has the Table-I feature fields
    for c in cfgs:
        assert {"input_h", "input_w", "input_c"} <= set(c)


def test_micro_fn_lowering_smoke(tmp_path):
    rng = np.random.Generator(np.random.PCG64(0))
    for cfg in [
        {"kind": "conv", "input_h": 4, "input_w": 4, "input_c": 3,
         "kernel": 3, "stride": 1, "filters": 4},
        {"kind": "add", "input_h": 4, "input_w": 4, "input_c": 3},
        {"kind": "dense", "input_h": 1, "input_w": 1, "input_c": 8,
         "filters": 4},
    ]:
        fn, specs = aot.micro_fn(cfg, rng)
        text = aot.lower_fn(fn, specs)
        assert "HloModule" in text
        assert "ENTRY" in text


def test_export_unit_weights_as_args(tmp_path):
    m = M.resnet32()
    params, state = m.init(0)
    node = m.nodes[1]  # a plain residual block
    arg_manifest = aot.export_unit(
        tmp_path / "n2.hlo.txt", node, params["nodes"]["2"],
        state["nodes"]["2"], (32, 32, 16), 1)
    text = (tmp_path / "n2.hlo.txt").read_text()
    assert "HloModule" in text
    # weights are arguments, not constants: the entry layout lists
    # 1 activation + len(manifest) weight tensors
    layout = text.split("entry_computation_layout={(")[1].split(")->")[0]
    n_args = layout.count("f32[")
    assert n_args == 1 + len(arg_manifest)
    names = [n for n, _ in arg_manifest]
    assert all(n.startswith(("p:", "s:")) for n in names)


def test_pack_weights_offsets_contiguous():
    m = M.resnet32()
    params, state = m.init(0)
    units = {"n1": (params["nodes"]["1"], state["nodes"]["1"]),
             "n2": (params["nodes"]["2"], state["nodes"]["2"])}
    buf, index = aot.pack_weights(units)
    total = 0
    for key in units:
        for e in index[key]:
            size = int(np.prod(e["shape"])) if e["shape"] else 1
            assert e["offset"] == total
            total += size
    assert len(buf) == total


def test_verify_model_catches_divergence():
    """verify_model must pass on matching weights (ResNet node-composition
    vs ref full forward)."""
    m = M.resnet32()
    params, state = m.init(0)
    x = jnp.asarray(np.random.RandomState(0).randn(2, 32, 32, 3).astype(np.float32))
    err = aot.verify_model(m, params, state, x)
    assert err < 5e-4


def test_block_composition_equals_full_forward():
    """Composing per-node forwards (the deployment) == monolithic forward."""
    m = M.mobilenetv2()
    params, state = m.init(0)
    x = jnp.asarray(np.random.RandomState(1).randn(1, 32, 32, 3).astype(np.float32))
    act = x
    for node in m.nodes:
        key = str(node.index)
        act, _ = node.apply(ref, params["nodes"][key], state["nodes"][key],
                            act, train=False)
    full, _ = m.forward_full(ref, params, state, x)
    np.testing.assert_allclose(np.asarray(act), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Manifest consistency (needs built artifacts; skipped otherwise)
# ---------------------------------------------------------------------------

needs_artifacts = pytest.mark.skipif(
    not (ARTIFACTS / "manifest.json").exists(),
    reason="artifacts not built (run `make artifacts`)",
)


@needs_artifacts
def test_manifest_models_complete():
    man = json.loads((ARTIFACTS / "manifest.json").read_text())
    assert set(man["models"]) == {"resnet32", "mobilenetv2"}
    for name, info in man["models"].items():
        assert info["num_nodes"] == len(info["nodes"])
        assert len(info["exits"]) == len(info["exit_nodes"])
        for node_key, node in info["nodes"].items():
            for b, rel in node["artifacts"].items():
                assert (ARTIFACTS / rel).exists(), f"{name} n{node_key} b{b}"
        assert (ARTIFACTS / info["weights_file"]).exists()
        assert len(info["history"]) == man["epochs"]


@needs_artifacts
def test_manifest_weight_offsets_within_file():
    man = json.loads((ARTIFACTS / "manifest.json").read_text())
    for name, info in man["models"].items():
        size = (ARTIFACTS / info["weights_file"]).stat().st_size // 4
        for node in info["nodes"].values():
            for e in node["weights"]:
                n = int(np.prod(e["shape"])) if e["shape"] else 1
                assert e["offset"] + n <= size


@needs_artifacts
def test_exported_block_hlo_runnable_in_jax():
    """Round-trip check: the exported HLO text for node 1 reproduces the
    python forward when re-imported and executed by jax's XLA client."""
    from jax._src.lib import xla_client as xc
    man = json.loads((ARTIFACTS / "manifest.json").read_text())
    info = man["models"]["resnet32"]
    rel = info["nodes"]["1"]["artifacts"]["1"]
    # jax's own CPU client can compile HLO text via the XlaComputation API
    text = (ARTIFACTS / rel).read_text()
    assert "HloModule" in text and "ENTRY" in text
    # weight arg count matches the manifest (entry layout lists all args)
    layout = text.split("entry_computation_layout={(")[1].split(")->")[0]
    assert layout.count("f32[") == 1 + len(info["nodes"]["1"]["weights"])
    _ = xc  # imported to assert availability of the compile path


@needs_artifacts
def test_test_set_binaries_match_dataset():
    """data/test_x.bin must be the deterministic SynthCIFAR prefix."""
    from compile import dataset
    man = json.loads((ARTIFACTS / "manifest.json").read_text())
    n = man["rust_eval_n"]
    seed = man["seed"]
    _, (x_te, y_te) = dataset.splits(man["train_n"], man["test_n"], seed=seed)
    x = np.fromfile(ARTIFACTS / "data/test_x.bin", dtype=np.float32).reshape(
        n, 32, 32, 3)
    y = np.fromfile(ARTIFACTS / "data/test_y.bin", dtype=np.int32)
    np.testing.assert_allclose(x, x_te[:n], rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(y, y_te[:n])


@needs_artifacts
def test_pallas_blocks_match_trained_weights():
    """Load trained weights and check one pallas block vs the ref path."""
    from compile import train
    m = M.resnet32()
    params, state = train.load_weights(
        ARTIFACTS / "weights" / "resnet32.npz", m, seed=0)
    x = jnp.asarray(np.random.RandomState(2).randn(1, 32, 32, 3).astype(np.float32))
    node = m.nodes[0]
    a, _ = node.apply(pk, params["nodes"]["1"], state["nodes"]["1"], x, False)
    b, _ = node.apply(ref, params["nodes"]["1"], state["nodes"]["1"], x, False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)
