"""L1 correctness: Pallas kernels (interpret=True) vs the pure-jnp oracle.

This is the CORE correctness signal of the build path: the AOT artifacts
are lowered from the Pallas implementations, and the models were trained
through the oracle — these tests prove both compute the same functions.

`hypothesis` is unavailable offline, so shape/dtype sweeps are explicit
parameterised grids plus seeded random shape draws (documented substitute).
"""

import numpy as np
import pytest
import jax.numpy as jnp

from compile.kernels import pallas_kernels as pk, ref

RNG = np.random.RandomState(1234)


def rand(*shape, scale=1.0):
    return jnp.asarray((RNG.randn(*shape) * scale).astype(np.float32))


def assert_close(a, b, tol=3e-5):
    a, b = np.asarray(a), np.asarray(b)
    assert a.shape == b.shape, f"shape {a.shape} vs {b.shape}"
    np.testing.assert_allclose(a, b, rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------

CONV_CASES = [
    # (n, h, w, cin, cout, k, stride, padding)
    (1, 8, 8, 3, 8, 3, 1, "SAME"),
    (2, 8, 8, 5, 7, 3, 2, "SAME"),
    (1, 16, 16, 8, 16, 1, 1, "SAME"),
    (2, 16, 16, 4, 4, 1, 2, "SAME"),
    (1, 7, 7, 3, 5, 3, 1, "SAME"),   # odd spatial
    (1, 9, 5, 2, 3, 3, 2, "SAME"),   # non-square, odd
    (1, 8, 8, 3, 4, 3, 1, "VALID"),
    (1, 32, 32, 3, 16, 3, 1, "SAME"),  # stem-shaped
]


@pytest.mark.parametrize("n,h,w,cin,cout,k,s,pad", CONV_CASES)
def test_conv2d_matches_ref(n, h, w, cin, cout, k, s, pad):
    x = rand(n, h, w, cin)
    wgt = rand(k, k, cin, cout)
    assert_close(pk.conv2d(x, wgt, stride=s, padding=pad),
                 ref.conv2d(x, wgt, stride=s, padding=pad))


def test_conv2d_with_bias():
    x = rand(2, 8, 8, 4)
    wgt = rand(3, 3, 4, 6)
    b = rand(6)
    assert_close(pk.conv2d(x, wgt, b), ref.conv2d(x, wgt, b))


def test_conv2d_channel_mismatch_raises():
    with pytest.raises(AssertionError):
        pk.conv2d(rand(1, 8, 8, 4), rand(3, 3, 5, 6))


def test_conv2d_random_shapes():
    rng = np.random.RandomState(7)
    for _ in range(6):
        h = int(rng.randint(4, 20))
        w = int(rng.randint(4, 20))
        cin = int(rng.randint(1, 9))
        cout = int(rng.randint(1, 17))
        s = int(rng.choice([1, 2]))
        x = rand(1, h, w, cin)
        wgt = rand(3, 3, cin, cout)
        assert_close(pk.conv2d(x, wgt, stride=s), ref.conv2d(x, wgt, stride=s))


# ---------------------------------------------------------------------------
# depthwise conv
# ---------------------------------------------------------------------------

DW_CASES = [
    (1, 8, 8, 4, 3, 1),
    (2, 8, 8, 8, 3, 2),
    (1, 16, 16, 16, 3, 1),
    (1, 7, 9, 5, 3, 2),
]


@pytest.mark.parametrize("n,h,w,c,k,s", DW_CASES)
def test_depthwise_matches_ref(n, h, w, c, k, s):
    x = rand(n, h, w, c)
    wgt = rand(k, k, c)
    assert_close(pk.depthwise_conv2d(x, wgt, stride=s),
                 ref.depthwise_conv2d(x, wgt, stride=s))


def test_depthwise_matches_lax_grouped_conv():
    """ref's shifted-MAC depthwise must equal lax grouped convolution."""
    import jax
    x = rand(2, 10, 10, 6)
    wgt = rand(3, 3, 6)
    lax_out = jax.lax.conv_general_dilated(
        x, wgt.reshape(3, 3, 1, 6), (2, 2), "SAME",
        feature_group_count=6, dimension_numbers=("NHWC", "HWIO", "NHWC"))
    assert_close(ref.depthwise_conv2d(x, wgt, stride=2), lax_out)


# ---------------------------------------------------------------------------
# dense / matmul tiling
# ---------------------------------------------------------------------------

DENSE_CASES = [
    (1, 16, 10),
    (33, 150, 70),     # non-multiple of tiles
    (128, 128, 128),   # exact tile
    (130, 260, 5),     # ragged both dims
    (2, 2048, 64),     # wide reduction (exit-head shaped)
]


@pytest.mark.parametrize("m,k,n", DENSE_CASES)
def test_dense_matches_ref(m, k, n):
    x = rand(m, k, scale=0.3)
    wgt = rand(k, n, scale=0.3)
    assert_close(pk.dense(x, wgt), ref.dense(x, wgt), tol=2e-4)


def test_dense_bias():
    x, w, b = rand(4, 32), rand(32, 10), rand(10)
    assert_close(pk.dense(x, w, b), ref.dense(x, w, b), tol=1e-4)


def test_matmul_tile_override():
    x, w = rand(64, 64, scale=0.3), rand(64, 64, scale=0.3)
    out = pk.matmul(x, w, tile_m=16, tile_n=16, tile_k=16)
    assert_close(out, ref.dense(x, w), tol=2e-4)


# ---------------------------------------------------------------------------
# elementwise: batchnorm, relu, relu6, add
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(1, 4, 4, 2), (2, 8, 8, 16), (3, 1, 1, 64)])
def test_batchnorm_matches_ref(shape):
    c = shape[-1]
    x = rand(*shape)
    gamma, beta = rand(c), rand(c)
    mean = rand(c, scale=0.2)
    var = jnp.abs(rand(c)) + 0.3
    assert_close(pk.batchnorm(x, gamma, beta, mean, var),
                 ref.batchnorm(x, gamma, beta, mean, var))


def test_batchnorm_eps_handling():
    x = rand(1, 2, 2, 3)
    g, b = jnp.ones(3), jnp.zeros(3)
    m, v = jnp.zeros(3), jnp.zeros(3)  # zero variance: eps must protect
    out = pk.batchnorm(x, g, b, m, v, eps=1e-3)
    assert np.all(np.isfinite(np.asarray(out)))
    assert_close(out, ref.batchnorm(x, g, b, m, v, eps=1e-3))


@pytest.mark.parametrize("shape", [(1, 5), (2, 8, 8, 3), (1, 100003)])
def test_relu_relu6_add(shape):
    x = rand(*shape, scale=4.0)
    y = rand(*shape, scale=4.0)
    assert_close(pk.relu(x), ref.relu(x))
    assert_close(pk.relu6(x), ref.relu6(x))
    assert_close(pk.add(x, y), ref.add(x, y))


def test_add_shape_mismatch_raises():
    with pytest.raises(AssertionError):
        pk.add(rand(2, 3), rand(3, 2))


# ---------------------------------------------------------------------------
# pooling
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(1, 4, 4, 8), (2, 8, 8, 3), (1, 2, 2, 64)])
def test_global_pools(shape):
    x = rand(*shape)
    assert_close(pk.global_avg_pool(x), ref.global_avg_pool(x))
    assert_close(pk.global_max_pool(x), ref.global_max_pool(x))


@pytest.mark.parametrize("h,w,window,stride", [(8, 8, 2, 2), (16, 16, 2, 2), (9, 9, 3, 3)])
def test_max_pool(h, w, window, stride):
    x = rand(2, h, w, 4)
    assert_close(pk.max_pool(x, window, stride), ref.max_pool(x, window, stride))


# ---------------------------------------------------------------------------
# dtype coverage: bfloat16 path stays close to f32 oracle
# ---------------------------------------------------------------------------


def test_conv_bfloat16_close_to_f32():
    x = rand(1, 8, 8, 4)
    w = rand(3, 3, 4, 8)
    out_bf = pk.conv2d(x.astype(jnp.bfloat16), w.astype(jnp.bfloat16))
    out_f32 = ref.conv2d(x, w)
    np.testing.assert_allclose(
        np.asarray(out_bf, dtype=np.float32), np.asarray(out_f32),
        rtol=5e-2, atol=5e-2)
