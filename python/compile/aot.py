"""AOT compilation: lower every serving artifact to HLO *text* + manifest.

Run once via ``make artifacts`` (no-op when inputs are unchanged). Python
never runs again after this: the rust coordinator loads the HLO text files
through `HloModuleProto::from_text_file` (xla crate / PJRT CPU) and serves
from them.

Interchange format is HLO TEXT, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).

Artifacts produced under artifacts/:
  manifest.json                 everything rust needs (see schema below)
  weights_<model>.bin           flat f32 LE weight/state leaves
  blocks/<model>_n<i>_b<B>.hlo.txt     per-node block, batch B in {1, 32}
  exits/<model>_e<i>_b<B>.hlo.txt      exit heads
  micro/<kind>_<j>.hlo.txt             single-layer latency microbenches
  data/test_x.bin, data/test_y.bin     eval set for rust-side accuracy

Block/exit artifacts take (activation, *weight_leaves) as arguments so the
HLO text stays small and weights are loaded once from weights_<model>.bin
(deploy-time weight loading, like a real serving system). Micro artifacts
bake their (synthetic) weights as constants.

The pallas (interpret=True) kernels are the lowered implementation; before
export, the pallas and pure-jnp paths are asserted numerically equal on a
sample batch.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import dataset, model as model_lib, nn, train
from .kernels import pallas_kernels, ref

# ---------------------------------------------------------------------------
# Configuration (env-overridable so CI / quick runs can shrink the budget)
# ---------------------------------------------------------------------------

EPOCHS = int(os.environ.get("CONTINUER_EPOCHS", "8"))
TRAIN_N = int(os.environ.get("CONTINUER_TRAIN_N", "1024"))
TEST_N = int(os.environ.get("CONTINUER_TEST_N", "512"))
EVAL_N = int(os.environ.get("CONTINUER_EVAL_N", "128"))   # per-epoch evals
RUST_EVAL_N = int(os.environ.get("CONTINUER_RUST_EVAL_N", "128"))
BATCH_SIZES = (1, 32)
SEED = int(os.environ.get("CONTINUER_SEED", "0"))
MODELS = [m for m in os.environ.get(
    "CONTINUER_MODELS", "resnet32,mobilenetv2").split(",") if m]
LR = {"resnet32": 1e-3, "mobilenetv2": 1e-3}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, arg_specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*arg_specs))


# ---------------------------------------------------------------------------
# Block / exit artifact export
# ---------------------------------------------------------------------------


def _leaves(tree):
    return nn.tree_flatten(tree)


def export_unit(out_path: Path, unit, params, state, in_shape, batch):
    """Lower one NodeBlock/ExitHead to HLO text; weights as arguments.

    Returns the ordered arg manifest: [(name, shape)] excluding the
    activation (arg 0).
    """
    p_leaves = _leaves(params)
    s_leaves = _leaves(state)

    def fn(act, *args):
        np_ = len(p_leaves)
        p = nn.tree_unflatten_like(params, iter(args[:np_]))
        s = nn.tree_unflatten_like(state, iter(args[np_:]))
        y, _ = unit.apply(pallas_kernels, p, s, act, train=False)
        return (y,)

    act_spec = jax.ShapeDtypeStruct((batch,) + tuple(in_shape), jnp.float32)
    arg_specs = [act_spec] + [
        jax.ShapeDtypeStruct(np.asarray(v).shape, jnp.float32)
        for _, v in p_leaves + s_leaves
    ]
    text = lower_fn(fn, arg_specs)
    out_path.write_text(text)
    return [(f"p:{k}", list(np.asarray(v).shape)) for k, v in p_leaves] + \
        [(f"s:{k}", list(np.asarray(v).shape)) for k, v in s_leaves]


def pack_weights(units_params_state) -> tuple[np.ndarray, dict]:
    """Flatten all (params, state) leaf arrays of all units into one f32
    buffer; return (buffer, {unit_key: [(name, shape, offset_floats)]})."""
    chunks, index = [], {}
    off = 0
    for key, (params, state) in units_params_state.items():
        entries = []
        for prefix, tree in (("p", params), ("s", state)):
            for name, v in _leaves(tree):
                arr = np.asarray(v, dtype=np.float32).ravel()
                entries.append({"name": f"{prefix}:{name}",
                                "shape": list(np.asarray(v).shape),
                                "offset": off})
                chunks.append(arr)
                off += arr.size
        index[key] = entries
    buf = np.concatenate(chunks) if chunks else np.zeros(0, np.float32)
    return buf, index


# ---------------------------------------------------------------------------
# Layer microbenches (latency-predictor training data, paper Table I)
# ---------------------------------------------------------------------------


def micro_configs():
    """Deterministic hyperparameter grids per layer type.

    Ranges cover everything that appears in the two DNNs (32x32 inputs,
    8..320 channels) so the latency model interpolates rather than
    extrapolates.
    """
    cfgs = []
    hws = [2, 4, 8, 16, 32]
    chans = [8, 16, 32, 64, 96, 128, 192]

    def add(kind, **kw):
        cfgs.append({"kind": kind, **kw})

    # conv: subsample the full grid deterministically
    i = 0
    for h in [4, 8, 16, 32]:
        for cin in [8, 16, 32, 64]:
            for cout in [16, 32, 64, 128]:
                for k in [1, 3]:
                    for s in [1, 2]:
                        if (i := i + 1) % 3 != 0:
                            add("conv", input_h=h, input_w=h, input_c=cin,
                                kernel=k, stride=s, filters=cout)
    for h in [4, 8, 16, 32]:
        for c in [8, 16, 48, 96, 192]:
            for s in [1, 2]:
                add("depthwise_conv", input_h=h, input_w=h, input_c=c,
                    kernel=3, stride=s, filters=c)
    for kind in ["batchnorm", "relu", "add", "dropout"]:
        for h in hws:
            for c in chans:
                add(kind, input_h=h, input_w=h, input_c=c)
    for din in [16, 32, 64, 128, 256, 512, 1024, 2048]:
        for dout in [10, 32, 64, 128]:
            add("dense", input_h=1, input_w=1, input_c=din, filters=dout)
    for kind in ["global_avg_pool", "global_max_pool"]:
        for h in hws:
            for c in [8, 16, 32, 64, 96, 192]:
                add(kind, input_h=h, input_w=h, input_c=c)
    for h in [4, 8, 16, 32]:
        for c in [8, 16, 32, 64, 96, 192]:
            add("max_pool", input_h=h, input_w=h, input_c=c, kernel=2,
                stride=2)
    return cfgs


def micro_fn(cfg, rng):
    """Build (fn, arg_specs) for one micro config (weights baked)."""
    kind = cfg["kind"]
    h, w, c = cfg["input_h"], cfg["input_w"], cfg["input_c"]
    B = 1
    if kind == "dense":
        x_spec = jax.ShapeDtypeStruct((B, c), jnp.float32)
    else:
        x_spec = jax.ShapeDtypeStruct((B, h, w, c), jnp.float32)
    pk = pallas_kernels
    if kind == "conv":
        wgt = jnp.asarray(rng.standard_normal(
            (cfg["kernel"], cfg["kernel"], c, cfg["filters"])) .astype(np.float32))
        return (lambda x: (pk.conv2d(x, wgt, stride=cfg["stride"]),), [x_spec])
    if kind == "depthwise_conv":
        wgt = jnp.asarray(rng.standard_normal(
            (cfg["kernel"], cfg["kernel"], c)).astype(np.float32))
        return (lambda x: (pk.depthwise_conv2d(x, wgt, stride=cfg["stride"]),),
                [x_spec])
    if kind == "dense":
        wgt = jnp.asarray(rng.standard_normal(
            (c, cfg["filters"])).astype(np.float32))
        b = jnp.asarray(rng.standard_normal((cfg["filters"],)).astype(np.float32))
        return (lambda x: (pk.dense(x, wgt, b),), [x_spec])
    if kind == "batchnorm":
        g, be, m, v = [jnp.asarray(rng.standard_normal((c,)).astype(np.float32))
                       for _ in range(4)]
        v = jnp.abs(v) + 0.5
        return (lambda x: (pk.batchnorm(x, g, be, m, v),), [x_spec])
    if kind == "relu":
        return (lambda x: (pk.relu(x),), [x_spec])
    if kind == "dropout":
        # inference-mode dropout == identity copy; profile it as such
        return (lambda x: (pk.add(x, jnp.zeros((), jnp.float32) * x),), [x_spec])
    if kind == "add":
        return (lambda x, y: (pk.add(x, y),), [x_spec, x_spec])
    if kind == "global_avg_pool":
        return (lambda x: (pk.global_avg_pool(x),), [x_spec])
    if kind == "global_max_pool":
        return (lambda x: (pk.global_max_pool(x),), [x_spec])
    if kind == "max_pool":
        return (lambda x: (pk.max_pool(x, cfg["kernel"], cfg["stride"]),),
                [x_spec])
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Verification: pallas path == ref path on real weights
# ---------------------------------------------------------------------------


def verify_model(m, params, state, x, tol=5e-4):
    """Compose per-node pallas forwards; must match the ref full forward."""
    act = x
    for node in m.nodes:
        key = str(node.index)
        act, _ = node.apply(pallas_kernels, params["nodes"][key],
                            state["nodes"][key], act, train=False)
    y_ref, _ = m.forward_full(ref, params, state, x)
    err = float(jnp.max(jnp.abs(act - y_ref)))
    assert err < tol, f"{m.name}: pallas/ref mismatch {err}"
    return err


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-micro", action="store_true")
    args = ap.parse_args()
    out = Path(args.out).resolve()
    for sub in ["blocks", "exits", "micro", "data", "weights"]:
        (out / sub).mkdir(parents=True, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", str(out / ".jax_cache"))

    t_start = time.time()
    (x_tr, y_tr), (x_te, y_te) = dataset.splits(TRAIN_N, TEST_N, seed=SEED)
    x_ev, y_ev = x_te[:EVAL_N], y_te[:EVAL_N]

    # Merge into an existing manifest so partial rebuilds (e.g.
    # CONTINUER_MODELS=mobilenetv2 or --skip-micro) keep earlier entries.
    manifest_path = out / "manifest.json"
    if manifest_path.exists():
        manifest = json.loads(manifest_path.read_text())
    else:
        manifest = {"models": {}, "micro": []}
    manifest.update({
        "seed": SEED,
        "epochs": EPOCHS,
        "train_n": TRAIN_N,
        "test_n": TEST_N,
        "eval_n": EVAL_N,
        "rust_eval_n": RUST_EVAL_N,
        "batch_sizes": list(BATCH_SIZES),
        "num_classes": dataset.NUM_CLASSES,
    })
    manifest.setdefault("models", {})
    manifest.setdefault("micro", [])

    # ---- eval data for rust ------------------------------------------------
    x_rust = np.ascontiguousarray(x_te[:RUST_EVAL_N], dtype=np.float32)
    y_rust = np.ascontiguousarray(y_te[:RUST_EVAL_N], dtype=np.int32)
    (out / "data" / "test_x.bin").write_bytes(x_rust.tobytes())
    (out / "data" / "test_y.bin").write_bytes(y_rust.tobytes())

    for name in MODELS:
        print(f"=== {name} ===", flush=True)
        m = model_lib.build(name)
        wpath = out / "weights" / f"{name}.npz"
        hpath = out / "weights" / f"{name}_history.json"
        if wpath.exists() and hpath.exists():
            print(f"loading cached weights {wpath}", flush=True)
            params, state = train.load_weights(wpath, m, seed=SEED)
            history = json.loads(hpath.read_text())
        else:
            params, state, history = train.train_model(
                m, (x_tr, y_tr), (x_ev, y_ev), epochs=EPOCHS, lr=LR[name],
                seed=SEED)
            train.save_weights(wpath, params, state)
            hpath.write_text(json.dumps(history))

        # final full-test variant accuracies
        eval_exits, skip_fns = train.make_eval_fns(m)
        final_acc = train.variant_accuracies(
            m, nn.tree_map(jnp.asarray, params), nn.tree_map(jnp.asarray, state),
            jnp.asarray(x_te), jnp.asarray(y_te), eval_exits, skip_fns)
        print(f"{name} final acc: full={final_acc['repartition']:.4f}",
              flush=True)

        # verify pallas == ref before export
        err = verify_model(m, params, state, jnp.asarray(x_te[:8]))
        print(f"{name} pallas-vs-ref maxerr={err:.2e}", flush=True)

        # pack weights
        units = {}
        for node in m.nodes:
            key = str(node.index)
            units[f"n{node.index}"] = (params["nodes"][key],
                                       state["nodes"][key])
        for e in m.exits:
            key = str(e.after_node)
            units[f"e{e.after_node}"] = (params["exits"][key],
                                         state["exits"][key])
        buf, windex = pack_weights(units)
        (out / f"weights_{name}.bin").write_bytes(buf.tobytes())

        # export node/exit HLO artifacts
        shapes = m.boundary_shapes()
        blocks_info = {}
        for node in m.nodes:
            key = str(node.index)
            in_shape = shapes[node.index]
            arts = {}
            for B in BATCH_SIZES:
                p = out / "blocks" / f"{name}_n{node.index}_b{B}.hlo.txt"
                export_unit(p, node, params["nodes"][key],
                            state["nodes"][key], in_shape, B)
                arts[str(B)] = str(p.relative_to(out))
            _, out_shape = node.specs(in_shape)
            blocks_info[str(node.index)] = {
                "in_shape": list(in_shape),
                "out_shape": list(out_shape),
                "skippable": node.skippable,
                "artifacts": arts,
                "weights": windex[f"n{node.index}"],
            }
            print(f"  exported node {node.index}", flush=True)
        exits_info = {}
        for e in m.exits:
            key = str(e.after_node)
            in_shape = shapes[e.after_node + 1]
            arts = {}
            for B in BATCH_SIZES:
                p = out / "exits" / f"{name}_e{e.after_node}_b{B}.hlo.txt"
                export_unit(p, e, params["exits"][key], state["exits"][key],
                            in_shape, B)
                arts[str(B)] = str(p.relative_to(out))
            exits_info[str(e.after_node)] = {
                "in_shape": list(in_shape),
                "artifacts": arts,
                "weights": windex[f"e{e.after_node}"],
            }
            print(f"  exported exit {e.after_node}", flush=True)

        manifest["models"][name] = {
            "nodes": blocks_info,
            "exits": exits_info,
            "num_nodes": len(m.nodes),
            "skippable_nodes": m.skippable_nodes(),
            "exit_nodes": m.exit_nodes(),
            "node_layers": {str(k): v for k, v in m.node_specs().items()},
            "exit_layers": {str(k): v for k, v in m.exit_specs().items()},
            "weights_file": f"weights_{name}.bin",
            "final_accuracy": final_acc,
            "history": history,
            "pallas_ref_maxerr": err,
        }

    # ---- layer microbenches ------------------------------------------------
    if not args.skip_micro:
        rng = np.random.Generator(np.random.PCG64(SEED + 77))
        cfgs = micro_configs()
        print(f"exporting {len(cfgs)} micro artifacts", flush=True)
        manifest["micro"] = []
        for j, cfg in enumerate(cfgs):
            fn, specs = micro_fn(cfg, rng)
            p = out / "micro" / f"{cfg['kind']}_{j}.hlo.txt"
            p.write_text(lower_fn(fn, specs))
            manifest["micro"].append({**cfg, "artifact": str(p.relative_to(out))})
            if (j + 1) % 50 == 0:
                print(f"  micro {j + 1}/{len(cfgs)}", flush=True)

    manifest_path.write_text(json.dumps(manifest, indent=1))
    # content hash over inputs for make-level no-op detection
    print(f"AOT done in {time.time() - t_start:.0f}s -> {out}", flush=True)


if __name__ == "__main__":
    main()
