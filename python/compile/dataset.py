"""SynthCIFAR: deterministic procedurally-generated CIFAR-10 substitute.

The paper trains ResNet-32 / MobileNetV2 on CIFAR-10. Downloading CIFAR-10
is not possible in this environment, so we generate a 10-class 32x32x3
dataset whose classes are separable by *learned convolutional features* but
not by trivial statistics:

  class k = oriented grating (angle k*18 deg, class-specific frequency)
          + class-colored Gaussian blob at a random position
          + per-image random phase/position/contrast + Gaussian noise.

A small CNN reaches high accuracy; shallow exits see only coarse features
and lose accuracy, which preserves the early-exit accuracy-vs-depth
trade-off the CONTINUER scheduler relies on (DESIGN.md §1.1).

Everything is a pure function of (seed, n) via numpy's PCG64 so the python
and rust sides can agree on the exact bytes.
"""

from __future__ import annotations

import numpy as np

NUM_CLASSES = 10
IMAGE_SHAPE = (32, 32, 3)

# Class palettes for the blob (RGB in [0,1]) - spread over the color cube.
_PALETTE = np.array(
    [
        [0.9, 0.1, 0.1],
        [0.1, 0.9, 0.1],
        [0.1, 0.1, 0.9],
        [0.9, 0.9, 0.1],
        [0.9, 0.1, 0.9],
        [0.1, 0.9, 0.9],
        [0.8, 0.5, 0.2],
        [0.2, 0.5, 0.8],
        [0.6, 0.6, 0.6],
        [0.3, 0.8, 0.5],
    ],
    dtype=np.float32,
)


def synth_cifar(n: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Generate `n` images. Returns (images f32 [n,32,32,3], labels i32 [n])."""
    rng = np.random.Generator(np.random.PCG64(seed))
    labels = rng.integers(0, NUM_CLASSES, size=n).astype(np.int32)
    h, w, _ = IMAGE_SHAPE
    yy, xx = np.meshgrid(np.arange(h, dtype=np.float32),
                         np.arange(w, dtype=np.float32), indexing="ij")
    images = np.empty((n,) + IMAGE_SHAPE, dtype=np.float32)
    for i in range(n):
        k = int(labels[i])
        angle = k * np.pi / NUM_CLASSES + rng.normal(0, 0.06)
        freq = 0.28 + 0.05 * (k % 5) + rng.normal(0, 0.01)
        phase = rng.uniform(0, 2 * np.pi)
        contrast = rng.uniform(0.6, 1.0)
        grating = 0.5 + 0.5 * contrast * np.sin(
            freq * (np.cos(angle) * xx + np.sin(angle) * yy) * 2 * np.pi / 8.0
            + phase
        )
        cx, cy = rng.uniform(8, 24, size=2)
        sigma = rng.uniform(3.0, 5.0)
        blob = np.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2) / (2 * sigma**2)))
        color = _PALETTE[k]
        img = (
            0.55 * grating[..., None]
            + 0.45 * blob[..., None] * color[None, None, :]
            + rng.normal(0, 0.08, size=IMAGE_SHAPE)
        )
        images[i] = np.clip(img, 0.0, 1.0)
    # Normalize like CIFAR pipelines do (mean/std per channel, fixed consts
    # so train/test and the rust loader agree).
    mean = np.array([0.5, 0.5, 0.5], dtype=np.float32)
    std = np.array([0.25, 0.25, 0.25], dtype=np.float32)
    images = (images - mean) / std
    return images, labels


def splits(n_train: int, n_test: int, seed: int = 0):
    """Disjoint train/test sets (different PCG streams)."""
    x_tr, y_tr = synth_cifar(n_train, seed=seed * 2 + 1)
    x_te, y_te = synth_cifar(n_test, seed=seed * 2 + 2)
    return (x_tr, y_tr), (x_te, y_te)
