"""Build-time training for the CONTINUER DNNs (L2).

Trains one joint model per DNN: the base network plus all early-exit heads
with the weighted-sum loss of paper §IV-A-2 (L_T = sum_i w_i L_i + L_final).
All three techniques are evaluated against this single set of weights so
the deployed per-node artifacts form one consistent network (the paper
trains separate models per technique; DESIGN.md §1 documents the
substitution).

Besides the weights, training records the raw material for the two
prediction models:
  - per-epoch, per-variant accuracies on an eval subset (accuracy labels),
  - per-epoch, per-node weight statistics (mean/std/percentiles, following
    Unterthiner et al. [23] as the paper does),
  - per-epoch train accuracy / loss (paper Table III parameters).

Pure-jnp kernels (ref backend) are used for the training path — the Pallas
interpret-mode kernels compute the identical function (asserted in pytest
and at AOT time) but are far too slow to train through on CPU.
"""

from __future__ import annotations

import functools
import time

import numpy as np
import jax
import jax.numpy as jnp

from . import nn
from .kernels import ref

EXIT_LOSS_WEIGHT = 0.3


# ---------------------------------------------------------------------------
# Adam (no optax offline)
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {"m": nn.tree_map(zeros, params), "v": nn.tree_map(zeros, params),
            "t": jnp.zeros((), dtype=jnp.int32)}


def adam_update(params, grads, opt, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = opt["t"] + 1
    m = nn.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = nn.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2**t.astype(jnp.float32))
    new_params = nn.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale)
        / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


def make_train_step(model, lr):
    def loss_fn(params, state, x, y):
        outs, new_state = model.forward_all_exits(ref, params, state, x,
                                                  train=True)
        loss = cross_entropy(outs["final"], y)
        for e in model.exit_nodes():
            loss = loss + EXIT_LOSS_WEIGHT * cross_entropy(outs[str(e)], y)
        acc = accuracy(outs["final"], y)
        return loss, (new_state, acc)

    @jax.jit
    def step(params, state, opt, x, y):
        (loss, (new_state, acc)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, state, x, y)
        params, opt = adam_update(params, grads, opt, lr)
        return params, new_state, opt, loss, acc

    return step


def calibrate_bn(model, params, state, x_tr, batch=64, passes=2):
    """Refresh batchnorm moving statistics under the *final* weights.

    During training the EMA lags the rapidly-moving weights; a few
    forward-only passes (no gradient updates) re-centre the moving
    mean/variance before the weights are frozen into artifacts.
    """

    @jax.jit
    def refresh(params, state, xb):
        _, new_state = model.forward_all_exits(ref, params, state, xb,
                                               train=True)
        return new_state

    n = x_tr.shape[0]
    for _ in range(passes):
        for i in range(0, n - batch + 1, batch):
            state = refresh(params, state, x_tr[i:i + batch])
    return state


def make_eval_fns(model):
    """Jitted inference-mode forwards: all-exits-and-final, and per-skip."""

    @jax.jit
    def eval_exits(params, state, x):
        outs, _ = model.forward_all_exits(ref, params, state, x, train=False)
        return outs

    skip_fns = {}
    for k in model.skippable_nodes():
        @functools.partial(jax.jit, static_argnames=())
        def eval_skip(params, state, x, _k=k):
            y, _ = model.forward_skip(ref, params, state, x, _k, train=False)
            return y
        skip_fns[k] = eval_skip
    return eval_exits, skip_fns


def variant_accuracies(model, params, state, x, y, eval_exits, skip_fns,
                       batch=128):
    """Accuracy of every technique variant on (x, y).

    Returns dict: {"repartition": a, "exit": {node: a}, "skip": {node: a}}.
    """
    n = x.shape[0]
    sums = {"final": 0.0}
    sums.update({f"e{e}": 0.0 for e in model.exit_nodes()})
    sums.update({f"s{k}": 0.0 for k in skip_fns})
    for i in range(0, n, batch):
        xb, yb = x[i:i + batch], y[i:i + batch]
        outs = eval_exits(params, state, xb)
        w = xb.shape[0]
        sums["final"] += float(accuracy(outs["final"], yb)) * w
        for e in model.exit_nodes():
            sums[f"e{e}"] += float(accuracy(outs[str(e)], yb)) * w
        for k, fn in skip_fns.items():
            sums[f"s{k}"] += float(accuracy(fn(params, state, xb), yb)) * w
    return {
        "repartition": sums["final"] / n,
        "exit": {e: sums[f"e{e}"] / n for e in model.exit_nodes()},
        "skip": {k: sums[f"s{k}"] / n for k in skip_fns},
    }


# ---------------------------------------------------------------------------
# Weight statistics (accuracy-prediction features, paper §IV-B-ii / [23])
# ---------------------------------------------------------------------------


def node_weight_stats(model, params):
    """Per-node (and per-exit) weight statistics.

    Returns {"n<idx>": stats, "e<idx>": stats} where stats =
    [count, mean, std, q0, q25, q50, q75, q100].
    """
    out = {}

    def stats_of(tree):
        leaves = [np.asarray(v).ravel() for _, v in nn.tree_flatten(tree)]
        w = np.concatenate(leaves) if leaves else np.zeros(1, np.float32)
        qs = np.percentile(w, [0, 25, 50, 75, 100])
        return [float(w.size), float(w.mean()), float(w.std())] + \
            [float(q) for q in qs]

    for n in model.nodes:
        out[f"n{n.index}"] = stats_of(params["nodes"][str(n.index)])
    for e in model.exits:
        out[f"e{e.after_node}"] = stats_of(params["exits"][str(e.after_node)])
    return out


# ---------------------------------------------------------------------------
# Training loop
# ---------------------------------------------------------------------------


def train_model(model, train_data, eval_data, *, epochs, lr, batch=64,
                seed=0, log=print):
    """Train; returns (params, state, history).

    history: list of per-epoch dicts with train_loss, train_acc,
    variant accuracies (eval subset) and per-node weight stats.
    """
    x_tr, y_tr = train_data
    x_ev, y_ev = eval_data
    params, state = model.init(seed)
    params = nn.tree_map(jnp.asarray, params)
    state = nn.tree_map(jnp.asarray, state)
    opt = adam_init(params)
    step = make_train_step(model, lr)
    eval_exits, skip_fns = make_eval_fns(model)
    rng = np.random.RandomState(seed)
    n = x_tr.shape[0]
    history = []
    x_tr = jnp.asarray(x_tr)
    y_tr = jnp.asarray(y_tr)
    x_ev = jnp.asarray(x_ev)
    y_ev = jnp.asarray(y_ev)
    for epoch in range(epochs):
        t0 = time.time()
        perm = rng.permutation(n)
        losses, accs = [], []
        for i in range(0, n - batch + 1, batch):
            idx = perm[i:i + batch]
            params, state, opt, loss, acc = step(
                params, state, opt, x_tr[idx], y_tr[idx])
            losses.append(float(loss))
            accs.append(float(acc))
        var_acc = variant_accuracies(model, params, state, x_ev, y_ev,
                                     eval_exits, skip_fns)
        rec = {
            "epoch": epoch,
            "lr": lr,
            "train_loss": float(np.mean(losses)),
            "train_acc": float(np.mean(accs)),
            "variant_acc": var_acc,
            "weight_stats": node_weight_stats(model, params),
        }
        history.append(rec)
        log(f"[{model.name}] epoch {epoch + 1}/{epochs} "
            f"loss={rec['train_loss']:.3f} acc={rec['train_acc']:.3f} "
            f"full={var_acc['repartition']:.3f} ({time.time() - t0:.1f}s)")
    state = calibrate_bn(model, params, state, x_tr, batch=batch)
    return params, state, history


# ---------------------------------------------------------------------------
# Weight (de)serialisation — flat .npz keyed by tree path.
# ---------------------------------------------------------------------------


def save_weights(path, params, state):
    flat = {}
    for k, v in nn.tree_flatten(params):
        flat[f"p:{k}"] = np.asarray(v)
    for k, v in nn.tree_flatten(state):
        flat[f"s:{k}"] = np.asarray(v)
    np.savez_compressed(path, **flat)


def load_weights(path, model, seed=0):
    params, state = model.init(seed)
    data = np.load(path)
    pleaves = iter([data[f"p:{k}"] for k, _ in nn.tree_flatten(params)])
    sleaves = iter([data[f"s:{k}"] for k, _ in nn.tree_flatten(state)])
    return (nn.tree_unflatten_like(params, pleaves),
            nn.tree_unflatten_like(state, sleaves))
