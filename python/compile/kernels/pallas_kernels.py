"""L1: Pallas kernels for the DNN compute hot-spots.

These kernels implement every layer type the CONTINUER DNNs need (paper
Table I): convolution, depthwise convolution, dense, batch normalisation,
ReLU / ReLU6, residual add, global average / max pooling and spatial max
pooling.

Design notes (TPU-shaped, interpret-run):
  - All kernels are written for the TPU memory model: BlockSpecs express the
    HBM->VMEM schedule, matmul-bearing kernels (dense, conv-as-matmul) use
    the canonical MXU tiling (grid over (M, N, K) tiles with the K axis
    innermost and an accumulator block revisited across the K loop), and
    elementwise kernels are flat VPU maps.
  - They are *lowered with interpret=True*: the CPU PJRT plugin cannot run
    Mosaic custom-calls, so interpret mode is the correctness (and AOT)
    path. Real-TPU performance is estimated from VMEM footprint + MXU
    utilisation in EXPERIMENTS.md §Perf.
  - Convolution is expressed as kh*kw shifted matmuls over the channel
    dimension (an implicit im2col): for each kernel tap (dh, dw) the
    spatially-shifted input plane (H_out*W_out, C_in) is multiplied with
    the tap's weight matrix (C_in, C_out) and accumulated. Each tap is an
    MXU-friendly matmul; padding is applied by the wrapper so the kernel
    body only handles VALID convolutions.

Numerical contract: identical (up to float summation order) to the pure-jnp
oracle in ref.py; pytest sweeps shapes/strides/dtypes and asserts allclose.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Interpret mode is mandatory on CPU (see module docstring).
INTERPRET = True

# MXU-shaped tile defaults. On a real TPU these would stay (128, 128); the
# wrappers clamp them to the problem size so tiny CIFAR shapes do not pad
# excessively under interpret mode.
TILE_M = 128
TILE_N = 128
TILE_K = 128


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _pad_axis(x, axis: int, multiple: int):
    """Zero-pad `axis` of x up to a multiple of `multiple`."""
    size = x.shape[axis]
    target = _ceil_div(size, multiple) * multiple
    if target == size:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - size)
    return jnp.pad(x, pad)


# ---------------------------------------------------------------------------
# Tiled matmul — the MXU workhorse shared by dense and convolution.
# ---------------------------------------------------------------------------


def _matmul_kernel(x_ref, w_ref, o_ref):
    """Grid = (M/bm, N/bn, K/bk); K is innermost and sequential.

    The output block index map is constant in K, so o_ref is revisited
    across the K loop and acts as the VMEM accumulator (standard Pallas
    matmul idiom).
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )


def matmul(x, w, *, tile_m: int = TILE_M, tile_n: int = TILE_N,
           tile_k: int = TILE_K):
    """(M, K) @ (K, N) -> (M, N) via the tiled Pallas kernel."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"matmul inner dims mismatch: {k} vs {k2}"
    bm, bn, bk = min(tile_m, m), min(tile_n, n), min(tile_k, k)
    xp = _pad_axis(_pad_axis(x, 0, bm), 1, bk)
    wp = _pad_axis(_pad_axis(w, 0, bk), 1, bn)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=INTERPRET,
    )(xp, wp)
    return out[:m, :n]


def dense(x, w, b=None):
    """Fully connected layer: (n, d_in) @ (d_in, d_out) + b."""
    out = matmul(x, w)
    if b is not None:
        out = out + b
    return out


# ---------------------------------------------------------------------------
# Convolution — kh*kw shifted matmuls (implicit im2col), one image per grid
# step along the batch axis so the working set fits VMEM.
# ---------------------------------------------------------------------------


def _conv2d_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int, stride: int,
                   h_out: int, w_out: int):
    """x_ref: (1, Hp, Wp, Cin) padded input; w_ref: (kh, kw, Cin, Cout);
    o_ref: (1, h_out, w_out, Cout). VALID convolution with stride."""
    x = x_ref[0]
    acc = jnp.zeros(o_ref.shape[1:], dtype=o_ref.dtype)
    for dh in range(kh):
        for dw in range(kw):
            # Strided spatial window for this kernel tap:
            # rows dh, dh+s, ..., dh+(h_out-1)*s  (static slice with step).
            patch = jax.lax.slice(
                x,
                (dh, dw, 0),
                (dh + (h_out - 1) * stride + 1,
                 dw + (w_out - 1) * stride + 1,
                 x.shape[2]),
                (stride, stride, 1),
            )  # (h_out, w_out, Cin)
            tap = w_ref[dh, dw]  # (Cin, Cout)
            acc += jnp.dot(
                patch.reshape(h_out * w_out, -1),
                tap,
                preferred_element_type=o_ref.dtype,
            ).reshape(h_out, w_out, -1)
    o_ref[0] = acc


def _same_pad(size: int, stride: int, k: int) -> tuple[int, int]:
    """TF/XLA SAME padding amounts for one spatial dim."""
    out = _ceil_div(size, stride)
    pad = max((out - 1) * stride + k - size, 0)
    return pad // 2, pad - pad // 2


def conv2d(x, w, b=None, stride: int = 1, padding: str = "SAME"):
    """2-D convolution, NHWC x HWIO -> NHWC (Pallas kernel)."""
    n, h, wd, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2, f"conv2d channel mismatch: {cin} vs {cin2}"
    if padding == "SAME":
        (pt, pb), (plft, prgt) = _same_pad(h, stride, kh), _same_pad(wd, stride, kw)
        xp = jnp.pad(x, ((0, 0), (pt, pb), (plft, prgt), (0, 0)))
    elif padding == "VALID":
        xp = x
    else:  # explicit ((top, bottom), (left, right))
        (pt, pb), (plft, prgt) = padding
        xp = jnp.pad(x, ((0, 0), (pt, pb), (plft, prgt), (0, 0)))
    hp, wp_ = xp.shape[1], xp.shape[2]
    h_out = (hp - kh) // stride + 1
    w_out = (wp_ - kw) // stride + 1
    out = pl.pallas_call(
        functools.partial(
            _conv2d_kernel, kh=kh, kw=kw, stride=stride,
            h_out=h_out, w_out=w_out,
        ),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, hp, wp_, cin), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, cout), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h_out, w_out, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h_out, w_out, cout), x.dtype),
        interpret=INTERPRET,
    )(xp, w)
    if b is not None:
        out = out + b
    return out


def _depthwise_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int, stride: int,
                      h_out: int, w_out: int):
    """x_ref: (1, Hp, Wp, C); w_ref: (kh, kw, C); o_ref: (1, h_out, w_out, C).
    Per-channel (VPU, elementwise-multiply) convolution."""
    x = x_ref[0]
    acc = jnp.zeros(o_ref.shape[1:], dtype=o_ref.dtype)
    for dh in range(kh):
        for dw in range(kw):
            patch = jax.lax.slice(
                x,
                (dh, dw, 0),
                (dh + (h_out - 1) * stride + 1,
                 dw + (w_out - 1) * stride + 1,
                 x.shape[2]),
                (stride, stride, 1),
            )
            acc += patch * w_ref[dh, dw]  # broadcast over (h_out, w_out, C)
    o_ref[0] = acc


def depthwise_conv2d(x, w, b=None, stride: int = 1, padding: str = "SAME"):
    """Depthwise 2-D convolution, NHWC x (kh, kw, C) -> NHWC (Pallas)."""
    n, h, wd, c = x.shape
    kh, kw, c2 = w.shape
    assert c == c2, f"depthwise channel mismatch: {c} vs {c2}"
    if padding == "SAME":
        (pt, pb), (plft, prgt) = _same_pad(h, stride, kh), _same_pad(wd, stride, kw)
        xp = jnp.pad(x, ((0, 0), (pt, pb), (plft, prgt), (0, 0)))
    else:
        xp = x
    hp, wp_ = xp.shape[1], xp.shape[2]
    h_out = (hp - kh) // stride + 1
    w_out = (wp_ - kw) // stride + 1
    out = pl.pallas_call(
        functools.partial(
            _depthwise_kernel, kh=kh, kw=kw, stride=stride,
            h_out=h_out, w_out=w_out,
        ),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, hp, wp_, c), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((kh, kw, c), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h_out, w_out, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h_out, w_out, c), x.dtype),
        interpret=INTERPRET,
    )(xp, w)
    if b is not None:
        out = out + b
    return out


# ---------------------------------------------------------------------------
# Elementwise kernels (VPU maps): batchnorm, relu, relu6, residual add.
# All operate on a flattened (rows, C) view, one batch row-block per grid
# step.
# ---------------------------------------------------------------------------


def _bn_kernel(x_ref, scale_ref, shift_ref, o_ref):
    o_ref[...] = x_ref[...] * scale_ref[...] + shift_ref[...]


def batchnorm(x, gamma, beta, mean, var, eps: float = 1e-3):
    """Inference-mode batchnorm over the trailing channel axis (Pallas)."""
    c = x.shape[-1]
    inv = gamma * jax.lax.rsqrt(var + eps)
    shift = beta - mean * inv
    flat = x.reshape(-1, c)
    rows = flat.shape[0]
    br = min(rows, 1024)
    flat = _pad_axis(flat, 0, br)
    out = pl.pallas_call(
        _bn_kernel,
        grid=(flat.shape[0] // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, x.dtype),
        interpret=INTERPRET,
    )(flat, inv, shift)
    return out[:rows].reshape(x.shape)


def _relu_kernel(x_ref, o_ref):
    o_ref[...] = jnp.maximum(x_ref[...], 0.0)


def _relu6_kernel(x_ref, o_ref):
    o_ref[...] = jnp.clip(x_ref[...], 0.0, 6.0)


def _add_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] + y_ref[...]


def _elementwise1(kernel, x):
    flat = x.reshape(-1)
    size = flat.shape[0]
    bs = min(size, 64 * 1024)
    flat = _pad_axis(flat, 0, bs)
    out = pl.pallas_call(
        kernel,
        grid=(flat.shape[0] // bs,),
        in_specs=[pl.BlockSpec((bs,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, x.dtype),
        interpret=INTERPRET,
    )(flat)
    return out[:size].reshape(x.shape)


def relu(x):
    return _elementwise1(_relu_kernel, x)


def relu6(x):
    return _elementwise1(_relu6_kernel, x)


def add(x, y):
    """Residual element-wise addition (Pallas)."""
    assert x.shape == y.shape, f"add shape mismatch: {x.shape} vs {y.shape}"
    xf, yf = x.reshape(-1), y.reshape(-1)
    size = xf.shape[0]
    bs = min(size, 64 * 1024)
    xf, yf = _pad_axis(xf, 0, bs), _pad_axis(yf, 0, bs)
    out = pl.pallas_call(
        _add_kernel,
        grid=(xf.shape[0] // bs,),
        in_specs=[
            pl.BlockSpec((bs,), lambda i: (i,)),
            pl.BlockSpec((bs,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=INTERPRET,
    )(xf, yf)
    return out[:size].reshape(x.shape)


# ---------------------------------------------------------------------------
# Pooling kernels.
# ---------------------------------------------------------------------------


def _gap_kernel(x_ref, o_ref, *, hw: int):
    # x_ref: (1, H*W, C) -> o_ref: (1, C). Mean over the spatial axis.
    o_ref[0] = jnp.sum(x_ref[0], axis=0) / hw


def global_avg_pool(x):
    """NHWC -> (n, c): spatial mean (Pallas reduction)."""
    n, h, w, c = x.shape
    flat = x.reshape(n, h * w, c)
    return pl.pallas_call(
        functools.partial(_gap_kernel, hw=h * w),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, h * w, c), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), x.dtype),
        interpret=INTERPRET,
    )(flat)


def _gmp_kernel(x_ref, o_ref):
    o_ref[0] = jnp.max(x_ref[0], axis=0)


def global_max_pool(x):
    """NHWC -> (n, c): spatial max (Pallas reduction)."""
    n, h, w, c = x.shape
    flat = x.reshape(n, h * w, c)
    return pl.pallas_call(
        _gmp_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, h * w, c), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), x.dtype),
        interpret=INTERPRET,
    )(flat)


def _max_pool_kernel(x_ref, o_ref, *, window: int, stride: int,
                     h_out: int, w_out: int):
    x = x_ref[0]
    acc = None
    for dh in range(window):
        for dw in range(window):
            patch = jax.lax.slice(
                x,
                (dh, dw, 0),
                (dh + (h_out - 1) * stride + 1,
                 dw + (w_out - 1) * stride + 1,
                 x.shape[2]),
                (stride, stride, 1),
            )
            acc = patch if acc is None else jnp.maximum(acc, patch)
    o_ref[0] = acc


def max_pool(x, window: int = 2, stride: int = 2):
    """Spatial max pooling (VALID), NHWC (Pallas)."""
    n, h, w, c = x.shape
    h_out = (h - window) // stride + 1
    w_out = (w - window) // stride + 1
    return pl.pallas_call(
        functools.partial(
            _max_pool_kernel, window=window, stride=stride,
            h_out=h_out, w_out=w_out,
        ),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, h_out, w_out, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h_out, w_out, c), x.dtype),
        interpret=INTERPRET,
    )(x)


def softmax(x, axis: int = -1):
    """Softmax is left to XLA (a fused stable reduction already)."""
    return jax.nn.softmax(x, axis=axis)
