"""Pure-jnp reference oracle for the Pallas kernels (L1).

Every kernel in this package has a reference implementation here written
only with `jax.numpy` / `jax.lax` primitives. The pytest suite checks the
Pallas kernels (interpret=True) against these references over swept shapes
and dtypes; the JAX models (L2) can be built against either implementation
(``use_pallas`` flag) and the two paths must agree numerically, which is
also asserted at AOT time.

Conventions (match the kernels):
  - activations are NHWC: (batch, height, width, channels)
  - conv weights are HWIO: (kh, kw, c_in, c_out)
  - depthwise weights are (kh, kw, c)
  - dense weights are (d_in, d_out)
  - batchnorm is inference-mode: y = gamma * (x - mean) / sqrt(var + eps) + beta
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d(x, w, b=None, stride: int = 1, padding: str = "SAME"):
    """2-D convolution, NHWC x HWIO -> NHWC."""
    out = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        out = out + b
    return out


def depthwise_conv2d(x, w, b=None, stride: int = 1, padding: str = "SAME"):
    """Depthwise 2-D convolution, NHWC x (kh,kw,c) -> NHWC.

    Implemented as kh*kw shifted elementwise multiply-accumulates rather
    than `lax.conv` with `feature_group_count`: XLA's CPU backward pass for
    grouped convolutions is extremely slow single-core, while the backward
    of shifted elementwise ops is cheap. Numerically identical (same
    accumulation order as the Pallas kernel).
    """
    n, h, wd, c = x.shape
    kh, kw, c2 = w.shape
    assert c == c2
    if padding == "SAME":
        out_h, out_w = -(-h // stride), -(-wd // stride)
        pad_h = max((out_h - 1) * stride + kh - h, 0)
        pad_w = max((out_w - 1) * stride + kw - wd, 0)
        x = jnp.pad(x, ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
                        (pad_w // 2, pad_w - pad_w // 2), (0, 0)))
    else:
        out_h = (h - kh) // stride + 1
        out_w = (wd - kw) // stride + 1
    acc = None
    for dh in range(kh):
        for dw in range(kw):
            patch = jax.lax.slice(
                x,
                (0, dh, dw, 0),
                (n, dh + (out_h - 1) * stride + 1,
                 dw + (out_w - 1) * stride + 1, c),
                (1, stride, stride, 1),
            )
            term = patch * w[dh, dw]
            acc = term if acc is None else acc + term
    if b is not None:
        acc = acc + b
    return acc


def dense(x, w, b=None):
    """Fully connected layer: (n, d_in) x (d_in, d_out) -> (n, d_out)."""
    out = jnp.dot(x, w)
    if b is not None:
        out = out + b
    return out


def batchnorm(x, gamma, beta, mean, var, eps: float = 1e-3):
    """Inference-mode batch normalisation over the channel axis."""
    inv = gamma * jax.lax.rsqrt(var + eps)
    return x * inv + (beta - mean * inv)


def relu(x):
    return jnp.maximum(x, 0.0)


def relu6(x):
    return jnp.clip(x, 0.0, 6.0)


def add(x, y):
    """Residual element-wise addition."""
    return x + y


def global_avg_pool(x):
    """NHWC -> (n, c): mean over spatial dims."""
    return jnp.mean(x, axis=(1, 2))


def global_max_pool(x):
    """NHWC -> (n, c): max over spatial dims."""
    return jnp.max(x, axis=(1, 2))


def max_pool(x, window: int = 2, stride: int = 2):
    """Spatial max pooling (VALID), NHWC."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, window, window, 1),
        window_strides=(1, stride, stride, 1),
        padding="VALID",
    )


def softmax(x, axis: int = -1):
    return jax.nn.softmax(x, axis=axis)
