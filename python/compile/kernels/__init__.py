"""L1 kernel package.

`pallas_kernels` holds the Pallas implementations (interpret=True); `ref`
holds the pure-jnp oracle. Both expose the same API so L2 model code can be
built against either via `get_backend(use_pallas)`.
"""

from . import pallas_kernels, ref

__all__ = ["pallas_kernels", "ref", "get_backend"]


def get_backend(use_pallas: bool):
    """Return the kernel namespace for model construction."""
    return pallas_kernels if use_pallas else ref
