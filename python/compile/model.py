"""L2: the CONTINUER DNNs as distributable node-block pipelines.

A `ModelDef` mirrors the paper's deployment model (§III-A): the DNN is a
sequence of *blocks*, each placed on one edge node. The three recovery
techniques are expressed as forward variants:

  - repartition : full pipeline (base accuracy, full latency)
  - early-exit e: nodes 1..e, then exit head e
  - skip k      : all nodes except k (k must be identity-skippable)

`NodeBlock.apply` runs one node's computation; `forward*` compose them, so
the python training/eval path and the rust per-node artifacts compute the
exact same functions.

ResNet-32 (paper §II-C): stem conv(3x3,16)+BN+ReLU, 15 residual blocks
(5 per stage, 16/32/64 channels, stride-2 projections at stages 2 and 3),
GAP, dense(10). 14 nodes: n1 = stem+rb1, n2..n13 = rb2..rb13,
n14 = rb14+rb15+head. Exits after nodes 1..13 (13 exit points, paper
Fig. 3a); skippable nodes = those hosting only identity blocks =
{2,3,4,5,7,8,9,10,12,13} — exactly the paper's 10 skip connections.

MobileNetV2 (CIFAR-adapted, §II-C): stem conv(3x3,32s)+BN+ReLU6, 17
inverted-residual blocks (t=6 except the first, width multiplier
configurable; strides adapted for 32x32 input), 1x1 conv, GAP, dense(10).
11 nodes with boundaries after blocks 2,4,5,7,8,9,11,12,14,15 so that the
10 exit points land after nodes n1..n10 (paper Fig. 3b).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from . import nn
from .kernels import get_backend

NUM_CLASSES = 10
INPUT_SHAPE = (32, 32, 3)


@dataclass
class NodeBlock:
    """One edge node's share of the DNN: a Sequential of units."""

    index: int  # 1-based node id, matching the paper's n_i
    seq: nn.Sequential
    skippable: bool  # every hosted residual unit has an identity shortcut

    def init(self, rng):
        return self.seq.init(rng)

    def init_state(self):
        return self.seq.init_state()

    def apply(self, bk, params, state, x, train=False):
        return self.seq.apply(bk, params, state, x, train)

    def specs(self, in_shape):
        return self.seq.specs(in_shape)


@dataclass
class ExitHead:
    """Early-exit classifier attached after a node (paper §IV-A-2)."""

    after_node: int  # exit i sits after node n_i
    seq: nn.Sequential

    def init(self, rng):
        return self.seq.init(rng)

    def init_state(self):
        return self.seq.init_state()

    def apply(self, bk, params, state, x, train=False):
        return self.seq.apply(bk, params, state, x, train)

    def specs(self, in_shape):
        return self.seq.specs(in_shape)


@dataclass
class ModelDef:
    name: str
    nodes: list  # list[NodeBlock]
    exits: list  # list[ExitHead]
    input_shape: tuple = INPUT_SHAPE

    # ----- parameter / state trees --------------------------------------
    def init(self, seed: int = 0):
        rng = np.random.RandomState(seed)
        params = {
            "nodes": {str(n.index): n.init(rng) for n in self.nodes},
            "exits": {str(e.after_node): e.init(rng) for e in self.exits},
        }
        state = {
            "nodes": {str(n.index): n.init_state() for n in self.nodes},
            "exits": {str(e.after_node): e.init_state() for e in self.exits},
        }
        return params, state

    # ----- forward variants (the three techniques) ----------------------
    def forward(self, bk, params, state, x, train=False, upto=None,
                skip=None):
        """Run nodes 1..upto (default all), optionally skipping node `skip`.

        Returns (activation, new_state). `activation` is logits if the head
        node ran, else the boundary activation.
        """
        new_nodes_state = {}
        for n in self.nodes:
            key = str(n.index)
            if skip is not None and n.index == skip:
                assert n.skippable, f"node {n.index} is not skippable"
                new_nodes_state[key] = state["nodes"][key]
                continue
            if upto is not None and n.index > upto:
                new_nodes_state[key] = state["nodes"][key]
                continue
            x, s = n.apply(bk, params["nodes"][key], state["nodes"][key], x,
                           train)
            new_nodes_state[key] = s
        return x, {"nodes": new_nodes_state, "exits": state["exits"]}

    def forward_full(self, bk, params, state, x, train=False):
        return self.forward(bk, params, state, x, train=train)

    def forward_exit(self, bk, params, state, x, exit_at: int, train=False):
        """Early-exit at exit head `exit_at` (after node n_{exit_at})."""
        act, st = self.forward(bk, params, state, x, train=train,
                               upto=exit_at)
        head = self.exit_by_node(exit_at)
        key = str(exit_at)
        logits, es = head.apply(bk, params["exits"][key],
                                state["exits"][key], act, train)
        st["exits"] = dict(st["exits"])
        st["exits"][key] = es
        return logits, st

    def forward_skip(self, bk, params, state, x, skip_node: int,
                     train=False):
        return self.forward(bk, params, state, x, train=train,
                            skip=skip_node)

    def forward_all_exits(self, bk, params, state, x, train=False):
        """All exit logits + final logits (joint training, paper §IV-A-2)."""
        outs = {}
        new_nodes_state = {}
        new_exits_state = dict(state["exits"])
        act = x
        exits_by_node = {e.after_node: e for e in self.exits}
        for n in self.nodes:
            key = str(n.index)
            act, s = n.apply(bk, params["nodes"][key], state["nodes"][key],
                             act, train)
            new_nodes_state[key] = s
            if n.index in exits_by_node:
                e = exits_by_node[n.index]
                ekey = str(n.index)
                logits, es = e.apply(bk, params["exits"][ekey],
                                     state["exits"][ekey], act, train)
                outs[ekey] = logits
                new_exits_state[ekey] = es
        outs["final"] = act
        return outs, {"nodes": new_nodes_state, "exits": new_exits_state}

    # ----- introspection --------------------------------------------------
    def exit_by_node(self, node_idx: int) -> ExitHead:
        for e in self.exits:
            if e.after_node == node_idx:
                return e
        raise KeyError(f"no exit after node {node_idx}")

    def skippable_nodes(self) -> list:
        """Interior nodes whose blocks are all identity-skippable."""
        last = self.nodes[-1].index
        return [n.index for n in self.nodes
                if n.skippable and 1 < n.index < last]

    def exit_nodes(self) -> list:
        return [e.after_node for e in self.exits]

    def boundary_shapes(self):
        """Activation shape entering each node (node_idx -> shape)."""
        shapes = {}
        shape = self.input_shape
        for n in self.nodes:
            shapes[n.index] = shape
            _, shape = n.specs(shape)
        shapes["output"] = shape
        return shapes

    def node_specs(self):
        """Per-node layer hyperparameter records (paper Table I)."""
        out = {}
        shape = self.input_shape
        for n in self.nodes:
            recs, shape = n.specs(shape)
            out[n.index] = recs
        return out

    def exit_specs(self):
        out = {}
        shapes = self.boundary_shapes()
        for e in self.exits:
            # exit input = activation *after* node e.after_node = input of
            # the next node.
            nxt = e.after_node + 1
            recs, _ = e.specs(shapes[nxt])
            out[e.after_node] = recs
        return out


# ---------------------------------------------------------------------------
# ResNet-32
# ---------------------------------------------------------------------------


def _resnet_block(cin, cout, stride):
    main = nn.Sequential([
        nn.Conv(cin, cout, kernel=3, stride=stride),
        nn.BatchNorm(cout),
        nn.ReLU(),
        nn.Conv(cout, cout, kernel=3, stride=1),
        nn.BatchNorm(cout),
    ])
    if stride != 1 or cin != cout:
        shortcut = nn.Sequential([
            nn.Conv(cin, cout, kernel=1, stride=stride),
            nn.BatchNorm(cout),
        ])
    else:
        shortcut = None
    return nn.Residual(main, shortcut)


def _resnet_exit_head(in_shape):
    """Paper §IV-A-2: conv(32, k3, s2) + maxpool + BN + dense(64) + dense(10)."""
    h, w, c = in_shape
    conv = nn.Conv(c, 32, kernel=3, stride=2)
    ho, wo = -(-h // 2), -(-w // 2)
    pool_w = 2 if min(ho, wo) >= 2 else 1
    layers = [conv, nn.BatchNorm(32), nn.ReLU()]
    if pool_w == 2:
        layers.append(nn.MaxPool(2, 2))
        ho, wo = (ho - 2) // 2 + 1, (wo - 2) // 2 + 1
    layers += [
        nn.Flatten(),
        nn.Dense(ho * wo * 32, 64),
        nn.ReLU(),
        nn.Dropout(0.2),
        nn.Dense(64, NUM_CLASSES),
    ]
    return nn.Sequential(layers)


def resnet32() -> ModelDef:
    """ResNet-32 for 32x32 inputs, distributed over 14 nodes."""
    # 15 residual blocks: stage channel/stride plan.
    plan = []  # (cin, cout, stride)
    cin = 16
    for stage, cout in enumerate([16, 32, 64]):
        for i in range(5):
            stride = 2 if (stage > 0 and i == 0) else 1
            plan.append((cin, cout, stride))
            cin = cout
    stem = [nn.Conv(3, 16, kernel=3, stride=1), nn.BatchNorm(16), nn.ReLU()]
    rbs = [_resnet_block(*p) for p in plan]
    head = [nn.GlobalAvgPool(), nn.Dense(64, NUM_CLASSES)]

    nodes = []
    # n1 = stem + rb1
    nodes.append(NodeBlock(1, nn.Sequential(stem + [rbs[0]]),
                           skippable=False))
    # n2..n13 = rb2..rb13
    for i in range(2, 14):
        rb = rbs[i - 1]
        nodes.append(NodeBlock(i, nn.Sequential([rb]),
                               skippable=rb.is_identity))
    # n14 = rb14 + rb15 + head
    nodes.append(NodeBlock(14, nn.Sequential([rbs[13], rbs[14]] + head),
                           skippable=False))

    model = ModelDef("resnet32", nodes, exits=[])
    shapes = model.boundary_shapes()
    exits = [ExitHead(i, _resnet_exit_head(shapes[i + 1]))
             for i in range(1, 14)]
    model.exits = exits
    return model


# ---------------------------------------------------------------------------
# MobileNetV2 (CIFAR-adapted)
# ---------------------------------------------------------------------------


def _mbv2_block(cin, cout, stride, expand):
    """Inverted residual: 1x1 expand -> 3x3 depthwise -> 1x1 project."""
    mid = cin * expand
    layers = []
    if expand != 1:
        layers += [nn.Conv(cin, mid, kernel=1, stride=1),
                   nn.BatchNorm(mid), nn.ReLU(six=True)]
    layers += [
        nn.DepthwiseConv(mid, kernel=3, stride=stride),
        nn.BatchNorm(mid), nn.ReLU(six=True),
        nn.Conv(mid, cout, kernel=1, stride=1),
        nn.BatchNorm(cout),
    ]
    main = nn.Sequential(layers)
    if stride == 1 and cin == cout:
        return nn.Residual(main, None, final_relu=False)
    # Non-identity inverted residuals have *no* shortcut in MobileNetV2;
    # model that as a plain Sequential (not skippable).
    return main


def _mbv2_exit_head(in_shape, conv_filters):
    """Paper §IV-A-2 MobileNetV2 exits: BN + conv(s) + global max pool +
    dense(64) + dense(10). `conv_filters` is a list of conv filter counts
    (the paper uses [96], [160, 80] or [320] depending on the block)."""
    h, w, c = in_shape
    layers = [nn.BatchNorm(c)]
    cin = c
    for f in conv_filters:
        layers += [nn.Conv(cin, f, kernel=3, stride=1), nn.ReLU()]
        cin = f
    layers += [
        nn.GlobalMaxPool(),
        nn.Dense(cin, 64),
        nn.ReLU(),
        nn.Dropout(0.2),
        nn.Dense(64, NUM_CLASSES),
    ]
    return nn.Sequential(layers)


def _round_ch(c: float) -> int:
    return max(8, int(round(c / 8.0)) * 8)


def mobilenetv2(width: float = 1.0) -> ModelDef:
    """MobileNetV2 for 32x32 inputs, 17 blocks over 11 nodes.

    `width` scales channel counts (default 0.5 to fit the single-core CPU
    training budget; DESIGN.md §1.1). Node boundaries sit after blocks
    2,4,5,7,8,9,11,12,14,15 so the 10 exits match the paper's Fig. 3b.
    """
    cfg = [  # (expand, c, n, s) CIFAR-adapted; downsampling schedule tuned
        # to fit the single-core CPU training budget while keeping 8x8
        # spatial resolution through the middle of the network
        # (DESIGN.md §1.1)
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 1),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ]
    stem_c = _round_ch(32 * width)
    blocks = []  # list[(layer, skippable)]
    cin = stem_c
    for expand, c, n, s in cfg:
        cout = _round_ch(c * width)
        for i in range(n):
            stride = s if i == 0 else 1
            blk = _mbv2_block(cin, cout, stride, expand)
            blocks.append((blk, isinstance(blk, nn.Residual)
                           and blk.is_identity))
            cin = cout
    assert len(blocks) == 17
    last_c = _round_ch(1280 * width / 4)  # reduced final conv for CIFAR
    stem = [nn.Conv(3, stem_c, kernel=3, stride=1), nn.BatchNorm(stem_c),
            nn.ReLU(six=True)]
    tail = [nn.Conv(cin, last_c, kernel=1, stride=1), nn.BatchNorm(last_c),
            nn.ReLU(six=True), nn.GlobalAvgPool(),
            nn.Dense(last_c, NUM_CLASSES)]

    # Node boundaries after these (1-based) block indices:
    bounds = [2, 4, 5, 7, 8, 9, 11, 12, 14, 15]
    nodes = []
    start = 1
    for ni, end in enumerate(bounds, start=1):
        units = [blocks[b - 1][0] for b in range(start, end + 1)]
        skippable = all(blocks[b - 1][1] for b in range(start, end + 1))
        if ni == 1:
            units = stem + units
            skippable = False
        nodes.append(NodeBlock(ni, nn.Sequential(units), skippable))
        start = end + 1
    # n11 = blocks 16,17 + tail
    units = [blocks[15][0], blocks[16][0]] + tail
    nodes.append(NodeBlock(11, nn.Sequential(units), skippable=False))

    model = ModelDef("mobilenetv2", nodes, exits=[])
    shapes = model.boundary_shapes()

    def filters_for(after_node: int) -> list:
        # Paper's per-block exit conv filters, scaled by width.
        blk = bounds[after_node - 1]
        if blk == 2:
            fs = [96]
        elif blk in (4, 5):
            fs = [160, 80]
        elif blk in (7, 8, 9, 11, 12):
            fs = [320]
        else:  # 14, 15
            fs = [160]
        return [_round_ch(f * width) for f in fs]

    model.exits = [ExitHead(i, _mbv2_exit_head(shapes[i + 1], filters_for(i)))
                   for i in range(1, 11)]
    return model


def build(name: str, **kw) -> ModelDef:
    if name == "resnet32":
        return resnet32(**kw)
    if name == "mobilenetv2":
        return mobilenetv2(**kw)
    raise ValueError(f"unknown model {name}")


__all__ = ["ModelDef", "NodeBlock", "ExitHead", "resnet32", "mobilenetv2",
           "build", "get_backend", "NUM_CLASSES", "INPUT_SHAPE"]
