"""Minimal functional NN module system for the L2 models.

Each layer object provides:
  - ``init(rng) -> params``             (dict of arrays; may be empty)
  - ``apply(bk, params, state, x, train) -> (y, new_state)``
      `bk` is the kernel backend (pallas_kernels or ref — same API),
      `state` holds batchnorm moving statistics.
  - ``init_state() -> state``
  - ``specs(in_shape) -> (list[dict], out_shape)``
      layer hyperparameter records matching paper Table I, consumed by the
      rust latency predictor (kind, input shape/channels, kernel, stride,
      filters).

Shapes are NHWC without the batch dim (e.g. (32, 32, 3)).

BatchNorm is the only stateful layer: in training it normalises with batch
statistics and updates moving averages; at inference (and in every AOT
artifact) it uses the moving averages through the backend's fused
inference-mode kernel.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def _he_init(rng, shape, fan_in):
    return (rng.standard_normal(shape) * np.sqrt(2.0 / fan_in)).astype(np.float32)


class Layer:
    """Base layer: stateless, paramless, identity."""

    name = "layer"

    def init(self, rng):
        return {}

    def init_state(self):
        return {}

    def apply(self, bk, params, state, x, train):
        raise NotImplementedError

    def specs(self, in_shape):
        raise NotImplementedError


class Conv(Layer):
    name = "conv"

    def __init__(self, cin, cout, kernel=3, stride=1, use_bias=False,
                 padding="SAME"):
        self.cin, self.cout, self.kernel = cin, cout, kernel
        self.stride, self.use_bias, self.padding = stride, use_bias, padding

    def init(self, rng):
        k = self.kernel
        p = {"w": _he_init(rng, (k, k, self.cin, self.cout), k * k * self.cin)}
        if self.use_bias:
            p["b"] = np.zeros((self.cout,), dtype=np.float32)
        return p

    def apply(self, bk, params, state, x, train):
        return (
            bk.conv2d(x, params["w"], params.get("b"), stride=self.stride,
                      padding=self.padding),
            state,
        )

    def specs(self, in_shape):
        h, w, _ = in_shape
        ho = -(-h // self.stride) if self.padding == "SAME" else (h - self.kernel) // self.stride + 1
        wo = -(-w // self.stride) if self.padding == "SAME" else (w - self.kernel) // self.stride + 1
        rec = {
            "kind": "conv",
            "input_h": h, "input_w": w, "input_c": self.cin,
            "kernel": self.kernel, "stride": self.stride,
            "filters": self.cout,
        }
        return [rec], (ho, wo, self.cout)


class DepthwiseConv(Layer):
    name = "depthwise_conv"

    def __init__(self, c, kernel=3, stride=1, padding="SAME"):
        self.c, self.kernel, self.stride, self.padding = c, kernel, stride, padding

    def init(self, rng):
        k = self.kernel
        return {"w": _he_init(rng, (k, k, self.c), k * k)}

    def apply(self, bk, params, state, x, train):
        return (
            bk.depthwise_conv2d(x, params["w"], stride=self.stride,
                                padding=self.padding),
            state,
        )

    def specs(self, in_shape):
        h, w, _ = in_shape
        ho = -(-h // self.stride)
        wo = -(-w // self.stride)
        rec = {
            "kind": "depthwise_conv",
            "input_h": h, "input_w": w, "input_c": self.c,
            "kernel": self.kernel, "stride": self.stride,
            "filters": self.c,
        }
        return [rec], (ho, wo, self.c)


class BatchNorm(Layer):
    name = "batchnorm"
    MOMENTUM = 0.9
    EPS = 1e-3

    def __init__(self, c):
        self.c = c

    def init(self, rng):
        return {
            "gamma": np.ones((self.c,), dtype=np.float32),
            "beta": np.zeros((self.c,), dtype=np.float32),
        }

    def init_state(self):
        return {
            "mean": np.zeros((self.c,), dtype=np.float32),
            "var": np.ones((self.c,), dtype=np.float32),
        }

    def apply(self, bk, params, state, x, train):
        if train:
            axes = tuple(range(x.ndim - 1))
            mean = jnp.mean(x, axis=axes)
            var = jnp.var(x, axis=axes)
            y = (x - mean) * jax.lax.rsqrt(var + self.EPS)
            y = y * params["gamma"] + params["beta"]
            new_state = {
                "mean": self.MOMENTUM * state["mean"] + (1 - self.MOMENTUM) * mean,
                "var": self.MOMENTUM * state["var"] + (1 - self.MOMENTUM) * var,
            }
            return y, new_state
        return (
            bk.batchnorm(x, params["gamma"], params["beta"], state["mean"],
                         state["var"], eps=self.EPS),
            state,
        )

    def specs(self, in_shape):
        rec = {"kind": "batchnorm", "input_h": in_shape[0],
               "input_w": in_shape[1] if len(in_shape) > 1 else 1,
               "input_c": in_shape[-1]}
        return [rec], in_shape


class ReLU(Layer):
    name = "relu"

    def __init__(self, six=False):
        self.six = six

    def apply(self, bk, params, state, x, train):
        return (bk.relu6(x) if self.six else bk.relu(x)), state

    def specs(self, in_shape):
        rec = {"kind": "relu", "input_h": in_shape[0],
               "input_w": in_shape[1] if len(in_shape) > 1 else 1,
               "input_c": in_shape[-1]}
        return [rec], in_shape


class Dropout(Layer):
    """Inference-time identity; kept so Table I/II cover the dropout type.

    Training applies inverted dropout with a fold-in seed; AOT artifacts are
    always inference mode.
    """

    name = "dropout"

    def __init__(self, rate=0.2):
        self.rate = rate
        self._seed = 0  # set per-step by the trainer

    def apply(self, bk, params, state, x, train):
        if train and self.rate > 0.0:
            key = jax.random.PRNGKey(self._seed)
            keep = jax.random.bernoulli(key, 1.0 - self.rate, x.shape)
            return jnp.where(keep, x / (1.0 - self.rate), 0.0), state
        return x, state

    def specs(self, in_shape):
        rec = {"kind": "dropout", "input_h": in_shape[0],
               "input_w": in_shape[1] if len(in_shape) > 1 else 1,
               "input_c": in_shape[-1]}
        return [rec], in_shape


class Dense(Layer):
    name = "dense"

    def __init__(self, din, dout, use_bias=True):
        self.din, self.dout, self.use_bias = din, dout, use_bias

    def init(self, rng):
        p = {"w": _he_init(rng, (self.din, self.dout), self.din)}
        if self.use_bias:
            p["b"] = np.zeros((self.dout,), dtype=np.float32)
        return p

    def apply(self, bk, params, state, x, train):
        return bk.dense(x, params["w"], params.get("b")), state

    def specs(self, in_shape):
        rec = {"kind": "dense", "input_h": 1, "input_w": 1,
               "input_c": self.din, "filters": self.dout}
        return [rec], (self.dout,)


class GlobalAvgPool(Layer):
    name = "global_avg_pool"

    def apply(self, bk, params, state, x, train):
        return bk.global_avg_pool(x), state

    def specs(self, in_shape):
        rec = {"kind": "global_avg_pool", "input_h": in_shape[0],
               "input_w": in_shape[1], "input_c": in_shape[2]}
        return [rec], (in_shape[2],)


class GlobalMaxPool(Layer):
    name = "global_max_pool"

    def apply(self, bk, params, state, x, train):
        return bk.global_max_pool(x), state

    def specs(self, in_shape):
        rec = {"kind": "global_max_pool", "input_h": in_shape[0],
               "input_w": in_shape[1], "input_c": in_shape[2]}
        return [rec], (in_shape[2],)


class MaxPool(Layer):
    name = "max_pool"

    def __init__(self, window=2, stride=2):
        self.window, self.stride = window, stride

    def apply(self, bk, params, state, x, train):
        return bk.max_pool(x, self.window, self.stride), state

    def specs(self, in_shape):
        h, w, c = in_shape
        ho = (h - self.window) // self.stride + 1
        wo = (w - self.window) // self.stride + 1
        rec = {"kind": "max_pool", "input_h": h, "input_w": w, "input_c": c,
               "kernel": self.window, "stride": self.stride}
        return [rec], (ho, wo, c)


class Flatten(Layer):
    name = "flatten"

    def apply(self, bk, params, state, x, train):
        return x.reshape(x.shape[0], -1), state

    def specs(self, in_shape):
        size = 1
        for d in in_shape:
            size *= d
        return [], (size,)


class Sequential(Layer):
    """Composite of layers; params/state keyed by layer index."""

    name = "sequential"

    def __init__(self, layers):
        self.layers = layers

    def init(self, rng):
        return {str(i): l.init(rng) for i, l in enumerate(self.layers)}

    def init_state(self):
        return {str(i): l.init_state() for i, l in enumerate(self.layers)}

    def apply(self, bk, params, state, x, train):
        new_state = {}
        for i, l in enumerate(self.layers):
            x, s = l.apply(bk, params[str(i)], state[str(i)], x, train)
            new_state[str(i)] = s
        return x, new_state

    def specs(self, in_shape):
        recs = []
        for l in self.layers:
            r, in_shape = l.specs(in_shape)
            recs.extend(r)
        return recs, in_shape


class Residual(Layer):
    """y = relu(main(x) + shortcut(x)); the Add goes through the backend."""

    name = "residual"

    def __init__(self, main, shortcut=None, final_relu=True, relu6=False):
        self.main = main
        self.shortcut = shortcut  # None => identity
        self.final_relu = final_relu
        self.relu6 = relu6

    @property
    def is_identity(self) -> bool:
        """True when the shortcut is the identity (skippable at runtime)."""
        return self.shortcut is None

    def init(self, rng):
        p = {"main": self.main.init(rng)}
        if self.shortcut is not None:
            p["shortcut"] = self.shortcut.init(rng)
        return p

    def init_state(self):
        s = {"main": self.main.init_state()}
        if self.shortcut is not None:
            s["shortcut"] = self.shortcut.init_state()
        return s

    def apply(self, bk, params, state, x, train):
        y, sm = self.main.apply(bk, params["main"], state["main"], x, train)
        new_state = {"main": sm}
        if self.shortcut is not None:
            sc, ss = self.shortcut.apply(
                bk, params["shortcut"], state["shortcut"], x, train)
            new_state["shortcut"] = ss
        else:
            sc = x
        out = bk.add(y, sc)
        if self.final_relu:
            out = bk.relu6(out) if self.relu6 else bk.relu(out)
        return out, new_state

    def specs(self, in_shape):
        recs, out_shape = self.main.specs(in_shape)
        if self.shortcut is not None:
            sc_recs, _ = self.shortcut.specs(in_shape)
            recs.extend(sc_recs)
        recs.append({"kind": "add", "input_h": out_shape[0],
                     "input_w": out_shape[1], "input_c": out_shape[2]})
        if self.final_relu:
            recs.append({"kind": "relu", "input_h": out_shape[0],
                         "input_w": out_shape[1], "input_c": out_shape[2]})
        return recs, out_shape


# ---------------------------------------------------------------------------
# Param tree helpers (no optax / flax available offline).
# ---------------------------------------------------------------------------


def tree_map(fn, *trees):
    t0 = trees[0]
    if isinstance(t0, dict):
        return {k: tree_map(fn, *[t[k] for t in trees]) for k in t0}
    return fn(*trees)


def tree_flatten(tree, prefix=""):
    """Deterministic (sorted-key) flatten -> list[(path, leaf)]."""
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree.keys()):
            out.extend(tree_flatten(tree[k], f"{prefix}/{k}" if prefix else k))
        return out
    return [(prefix, tree)]


def tree_unflatten_like(tree, leaves_iter):
    if isinstance(tree, dict):
        return {k: tree_unflatten_like(tree[k], leaves_iter)
                for k in sorted(tree.keys())}
    return next(leaves_iter)
