//! # CONTINUER — maintaining distributed DNN services during edge failures
//!
//! Rust reproduction of *CONTINUER* (Abdul Majeed et al., 2022): a
//! coordinator that keeps a distributed DNN inference service alive when an
//! edge node fails by selecting, per failure, one of three recovery
//! techniques — **repartitioning**, **early-exit** or **skip-connection** —
//! from predicted accuracy, predicted end-to-end latency and empirical
//! downtime under user-defined objective weights.
//!
//! Architecture (DESIGN.md):
//! - [`runtime`] loads AOT-compiled HLO-text artifacts (produced once by
//!   the python/JAX/Pallas build path) via the PJRT C API and executes
//!   them; python never runs at request time.
//! - [`cluster`] simulates the edge cluster: nodes hosting per-block
//!   executables, links with a latency/bandwidth model, failure injection.
//! - [`dnn`] holds model/layer metadata mirroring the python definitions.
//! - [`predict`] is a from-scratch gradient-boosted-tree library providing
//!   the paper's Latency Prediction Model and Accuracy Prediction Model.
//! - [`coordinator`] is the CONTINUER framework itself: the offline
//!   profiler phase and the runtime scheduler / failover machinery plus
//!   the serving pipeline (router, batcher, service).
//! - [`workload`], [`baselines`], [`exper`] support the evaluation: load
//!   generators, comparison policies and one driver per paper table/figure.

pub mod baselines;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod dnn;
pub mod exper;
pub mod predict;
pub mod runtime;
pub mod util;
pub mod workload;

pub use config::Config;
