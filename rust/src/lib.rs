//! # CONTINUER — maintaining distributed DNN services during edge failures
//!
//! Rust reproduction of *CONTINUER* (Abdul Majeed et al., 2022): a
//! coordinator that keeps a distributed DNN inference service alive when an
//! edge node fails by selecting, per failure, one of three recovery
//! techniques — **repartitioning**, **early-exit** or **skip-connection** —
//! from predicted accuracy, predicted end-to-end latency and empirical
//! downtime under user-defined objective weights.
//!
//! Architecture (DESIGN.md):
//! - [`runtime`] loads AOT-compiled HLO-text artifacts (produced once by
//!   the python/JAX/Pallas build path) via the PJRT C API and executes
//!   them; python never runs at request time.
//! - [`cluster`] simulates the edge cluster: nodes hosting per-block
//!   executables, links with a latency/bandwidth model, ground-truth
//!   failure injection (crashes, recoveries, gray-failure slowdowns),
//!   and per-stage execution primitives the serving engine schedules
//!   around.
//! - [`health`] is the node-health subsystem: a simulated heartbeat
//!   channel (jitter/loss/blackouts), fixed-timeout and phi-accrual
//!   failure detectors that can be late or wrong (false positives
//!   trigger unnecessary failovers the engine later rolls back), and a
//!   quarantine gate that holds flapping nodes out of the path until
//!   they stay stable.
//! - [`dnn`] holds model/layer metadata mirroring the python definitions.
//! - [`predict`] is a from-scratch gradient-boosted-tree library providing
//!   the paper's Latency Prediction Model and Accuracy Prediction Model.
//! - [`coordinator`] is the CONTINUER framework plus the serving stack:
//!   the offline profiler phase; the runtime decision machinery
//!   (estimator → [`coordinator::RecoveryPolicy`] → failover); and the
//!   event-driven serving engine — stage-level pipelining (up to
//!   `pipeline_depth` batches in flight per replica, throughput set by
//!   the bottleneck stage) across `R` pipeline replicas behind a
//!   fleet-aware router (round-robin, join-shortest-queue, and — for
//!   heterogeneous fleets with per-replica
//!   `EngineConfig::speed_factors` — smooth weighted round-robin and
//!   speed-weighted JSQ, which ranks replicas by expected drain time so
//!   a degraded replica sheds load before failover trips), with
//!   per-replica failure injection and failover. Repartitioning is a first-class, time-costed
//!   deployment ([`coordinator::DeploymentConfig`]): re-hosted blocks pay
//!   weight transfer over link bandwidth plus warm-up, served either
//!   break-before-make (dispatch stalls through the window, and the
//!   scheduler prices that stall into the decision) or
//!   make-before-break (a repartition-free fallback keeps serving until
//!   an atomic cut-over — zero stall, nothing requeued); the
//!   instantaneous legacy swap remains the byte-compatible default. The
//!   engine's steady-state hot path is
//!   allocation-free: step plans are cached (`PlanCache`, `Arc<[Step]>`),
//!   in-flight batches live in a generational slab with free-list slot
//!   reuse, synthetic activations are shape-only handles (the real PJRT
//!   path materializes batches in one gather), and latency metrics
//!   stream into a log-bucketed histogram + online moments so run memory
//!   is O(1) in request count (exact per-request records return behind
//!   `EngineConfig::record_completions`). The event core itself is
//!   pluggable (`EngineConfig::event_queue`): the `BinaryHeap` reference
//!   or — the default — an adaptive calendar queue
//!   ([`util::eventq`]) with amortized O(1) push/pop at million-event
//!   rates; both pop in exact `(time, seq)` order, so same-seed reports
//!   are byte-identical whichever queue runs. Under
//!   `EngineConfig::execution: Sharded(workers)` the event loop itself
//!   shards per replica onto real threads — each shard owns its event
//!   queue, slab, plan cache and streaming metrics; arrivals are positionally
//!   pre-split (round-robin / weighted round-robin) or JSQ-fed over
//!   atomic load counters and shard-published speed estimates; live-routed
//!   shards can additionally steal queued work from each other through
//!   per-shard injector pools (`EngineConfig::steal`); per-shard reports
//!   merge (exact histogram adds, Welford pairwise moments) into one
//!   `ServiceReport` that is bucket-identical to the sequential
//!   reference on the same seed for the positional policies.
//! - [`obs`] is the observability layer: the engine emits a typed event
//!   stream (arrivals, batch dispatches, stage spans, condition changes,
//!   failover/recovery detections, quarantine windows, drops,
//!   completions) into an [`obs::EventSink`] it is generic over — the
//!   default [`obs::NoopSink`] monomorphizes every emission away, so
//!   observability costs nothing unless a recording sink is plugged in;
//!   sharded runs stream events over a bounded channel drained on the
//!   caller thread ([`obs::ChannelSink`]) instead of buffering whole
//!   shards. On top of the stream sit a Chrome `trace_event` exporter
//!   ([`obs::trace`], `continuer trace`, opens in Perfetto /
//!   `chrome://tracing`) and a modular report pipeline
//!   ([`obs::report::ReportModule`]) that folds one replayed stream
//!   through pluggable analyses (drop attribution, downtime/failover
//!   summary, latency summary, event counts).
//! - [`workload`], [`baselines`], [`exper`] support the evaluation: load
//!   generators (with per-replica stream helpers), comparison policies
//!   (all implementing the same [`coordinator::RecoveryPolicy`] trait
//!   CONTINUER uses, so they run inside the identical engine) and one
//!   driver per paper table/figure.

pub mod baselines;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod dnn;
pub mod exper;
pub mod health;
pub mod obs;
pub mod predict;
pub mod runtime;
pub mod util;
pub mod workload;

pub use config::Config;
