//! Technique variants: the candidate recovery actions CONTINUER chooses
//! among when a node fails (paper §II-D).

use super::model::ModelMeta;

/// One candidate recovery technique for a specific failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Technique {
    /// Repartition the full DNN over the surviving nodes.
    Repartition,
    /// Terminate requests at the exit head after node `.0` (the node just
    /// before the failed one).
    EarlyExit(usize),
    /// Bypass failed node `.0` via its identity skip connection.
    SkipConnection(usize),
}

impl Technique {
    pub fn kind_name(&self) -> &'static str {
        match self {
            Technique::Repartition => "repartition",
            Technique::EarlyExit(_) => "early-exit",
            Technique::SkipConnection(_) => "skip-connection",
        }
    }

    pub fn label(&self) -> String {
        match self {
            Technique::Repartition => "repartition".into(),
            Technique::EarlyExit(e) => format!("early-exit@{e}"),
            Technique::SkipConnection(k) => format!("skip@{k}"),
        }
    }
}

/// Enumerate the feasible techniques when `failed` fails (1-based node id).
///
/// - Repartitioning is always feasible (the DNN redeploys over survivors).
/// - Early-exit is feasible iff an exit head exists after node failed-1.
/// - Skip-connection is feasible iff the failed node is identity-skippable
///   (paper Fig. 6 red stars mark the impossible positions).
///
/// Failure of the *first* node is unrecoverable by exit/skip; failure of
/// the last node can still exit at the last exit head.
pub fn candidates(model: &ModelMeta, failed: usize) -> Vec<Technique> {
    let mut out = vec![Technique::Repartition];
    if failed >= 2 && model.exit_nodes.contains(&(failed - 1)) {
        out.push(Technique::EarlyExit(failed - 1));
    }
    if model.is_skippable(failed) {
        out.push(Technique::SkipConnection(failed));
    }
    out
}

/// Nodes whose failure the evaluation sweeps (all interior failures the
/// paper's figures iterate: 2..=num_nodes, i.e. every node that has a
/// predecessor).
pub fn failure_sweep(model: &ModelMeta) -> Vec<usize> {
    (2..=model.num_nodes).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::model::test_fixtures::tiny_model;

    #[test]
    fn candidates_interior_skippable() {
        let m = tiny_model();
        let c = candidates(&m, 3);
        assert!(c.contains(&Technique::Repartition));
        assert!(c.contains(&Technique::EarlyExit(2)));
        assert!(c.contains(&Technique::SkipConnection(3)));
    }

    #[test]
    fn candidates_first_node() {
        let m = tiny_model();
        // node 1 failing: no exit before it, not skippable
        assert_eq!(candidates(&m, 1), vec![Technique::Repartition]);
    }

    #[test]
    fn candidates_last_node() {
        let m = tiny_model();
        let c = candidates(&m, 5);
        assert!(c.contains(&Technique::EarlyExit(4)));
        assert!(!c.iter().any(|t| matches!(t, Technique::SkipConnection(_))));
    }

    #[test]
    fn sweep_covers_interior() {
        let m = tiny_model();
        assert_eq!(failure_sweep(&m), vec![2, 3, 4, 5]);
    }

    #[test]
    fn labels() {
        assert_eq!(Technique::Repartition.label(), "repartition");
        assert_eq!(Technique::EarlyExit(3).label(), "early-exit@3");
        assert_eq!(Technique::SkipConnection(7).label(), "skip@7");
        assert_eq!(Technique::SkipConnection(7).kind_name(), "skip-connection");
    }
}
