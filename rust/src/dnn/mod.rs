//! DNN metadata: layer hyperparameters (paper Table I), model/node/exit
//! descriptions mirrored from the AOT manifest, the repartition planner and
//! technique-variant enumeration.

pub mod layers;
pub mod model;
pub mod partition;
pub mod variants;

pub use layers::{LayerKind, LayerSpec};
pub use model::{EpochRecord, ExitMeta, ModelMeta, NodeMeta, VariantAccuracies, WeightEntry};
pub use variants::Technique;
