//! Model metadata parsed from the AOT manifest: the rust-side mirror of
//! the python `ModelDef` (nodes, exits, skippable set, boundary shapes,
//! layer specs, training history, measured accuracies).

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use super::layers::{parse_layers, LayerSpec};
use crate::util::json::Json;

/// A packed weight-leaf entry inside weights_<model>.bin.
#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    /// Offset in f32 elements into the model's weight file.
    pub offset: usize,
}

impl WeightEntry {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(v: &Json) -> Result<WeightEntry> {
        Ok(WeightEntry {
            name: v
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("weight entry missing name"))?
                .to_string(),
            shape: v
                .get("shape")
                .and_then(Json::as_usize_vec)
                .ok_or_else(|| anyhow!("weight entry missing shape"))?,
            offset: v
                .get("offset")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("weight entry missing offset"))?,
        })
    }
}

/// One node's block of the distributed DNN.
#[derive(Debug, Clone)]
pub struct NodeMeta {
    pub index: usize,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub skippable: bool,
    /// batch size -> artifact path (relative to artifacts dir)
    pub artifacts: BTreeMap<usize, String>,
    pub weights: Vec<WeightEntry>,
    pub layers: Vec<LayerSpec>,
}

impl NodeMeta {
    /// Bytes of the activation leaving this node (batch 1, f32).
    pub fn out_bytes(&self) -> usize {
        4 * self.out_shape.iter().product::<usize>()
    }

    pub fn flops(&self) -> usize {
        self.layers.iter().map(|l| l.flops()).sum()
    }

    /// Serialized size of this node's weights (f32), bytes — what a
    /// repartition deployment must move to re-host the block.
    pub fn weight_bytes(&self) -> usize {
        weight_bytes(&self.weights)
    }
}

/// Total f32 payload of a weight-entry list, bytes.
fn weight_bytes(weights: &[WeightEntry]) -> usize {
    weights.iter().map(|w| 4 * w.elems()).sum()
}

/// One early-exit head.
#[derive(Debug, Clone)]
pub struct ExitMeta {
    pub after_node: usize,
    pub in_shape: Vec<usize>,
    pub artifacts: BTreeMap<usize, String>,
    pub weights: Vec<WeightEntry>,
    pub layers: Vec<LayerSpec>,
}

impl ExitMeta {
    /// Serialized size of this exit head's weights (f32), bytes.
    pub fn weight_bytes(&self) -> usize {
        weight_bytes(&self.weights)
    }
}

/// Final (full-test-set) accuracies measured at build time.
#[derive(Debug, Clone, Default)]
pub struct VariantAccuracies {
    pub repartition: f64,
    pub exit: BTreeMap<usize, f64>,
    pub skip: BTreeMap<usize, f64>,
}

impl VariantAccuracies {
    fn from_json(v: &Json) -> Result<VariantAccuracies> {
        let mut out = VariantAccuracies {
            repartition: v
                .get("repartition")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("missing repartition accuracy"))?,
            ..Default::default()
        };
        for (field, map) in [("exit", &mut out.exit), ("skip", &mut out.skip)] {
            if let Some(obj) = v.get(field).and_then(Json::as_obj) {
                for (k, val) in obj {
                    map.insert(
                        k.parse()
                            .map_err(|_| anyhow!("bad {field} key '{k}'"))?,
                        val.as_f64().ok_or_else(|| anyhow!("bad {field} value"))?,
                    );
                }
            }
        }
        Ok(out)
    }
}

/// One epoch of the training history (accuracy-predictor raw material).
#[derive(Debug, Clone)]
pub struct EpochRecord {
    pub epoch: usize,
    pub lr: f64,
    pub train_loss: f64,
    pub train_acc: f64,
    pub variant_acc: VariantAccuracies,
    /// "n<idx>" / "e<idx>" -> [count, mean, std, q0, q25, q50, q75, q100]
    pub weight_stats: BTreeMap<String, Vec<f64>>,
}

/// Full metadata for one model from the manifest.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub num_nodes: usize,
    pub nodes: Vec<NodeMeta>,
    pub exits: Vec<ExitMeta>,
    pub skippable_nodes: Vec<usize>,
    pub exit_nodes: Vec<usize>,
    pub weights_file: String,
    pub final_accuracy: VariantAccuracies,
    pub history: Vec<EpochRecord>,
}

impl ModelMeta {
    pub fn from_json(name: &str, v: &Json) -> Result<ModelMeta> {
        let nodes_obj = v
            .get("nodes")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest model missing nodes"))?;
        let node_layers = v
            .get("node_layers")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("missing node_layers"))?;
        let mut nodes = Vec::new();
        for (k, nv) in nodes_obj {
            let index: usize = k.parse().map_err(|_| anyhow!("bad node key '{k}'"))?;
            let layers = parse_layers(
                node_layers
                    .get(k)
                    .ok_or_else(|| anyhow!("missing layers for node {k}"))?,
            )?;
            nodes.push(NodeMeta {
                index,
                in_shape: nv
                    .get("in_shape")
                    .and_then(Json::as_usize_vec)
                    .ok_or_else(|| anyhow!("node {k}: missing in_shape"))?,
                out_shape: nv
                    .get("out_shape")
                    .and_then(Json::as_usize_vec)
                    .ok_or_else(|| anyhow!("node {k}: missing out_shape"))?,
                skippable: nv
                    .get("skippable")
                    .and_then(Json::as_bool)
                    .unwrap_or(false),
                artifacts: parse_artifacts(nv.get("artifacts"))?,
                weights: parse_weights(nv.get("weights"))?,
                layers,
            });
        }
        nodes.sort_by_key(|n| n.index);

        let exit_layers = v
            .get("exit_layers")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("missing exit_layers"))?;
        let mut exits = Vec::new();
        if let Some(exits_obj) = v.get("exits").and_then(Json::as_obj) {
            for (k, ev) in exits_obj {
                let after_node: usize =
                    k.parse().map_err(|_| anyhow!("bad exit key '{k}'"))?;
                exits.push(ExitMeta {
                    after_node,
                    in_shape: ev
                        .get("in_shape")
                        .and_then(Json::as_usize_vec)
                        .ok_or_else(|| anyhow!("exit {k}: missing in_shape"))?,
                    artifacts: parse_artifacts(ev.get("artifacts"))?,
                    weights: parse_weights(ev.get("weights"))?,
                    layers: parse_layers(
                        exit_layers
                            .get(k)
                            .ok_or_else(|| anyhow!("missing layers for exit {k}"))?,
                    )?,
                });
            }
        }
        exits.sort_by_key(|e| e.after_node);

        let mut history = Vec::new();
        if let Some(arr) = v.get("history").and_then(Json::as_arr) {
            for h in arr {
                history.push(EpochRecord {
                    epoch: h.get("epoch").and_then(Json::as_usize).unwrap_or(0),
                    lr: h.get("lr").and_then(Json::as_f64).unwrap_or(0.0),
                    train_loss: h.get("train_loss").and_then(Json::as_f64).unwrap_or(0.0),
                    train_acc: h.get("train_acc").and_then(Json::as_f64).unwrap_or(0.0),
                    variant_acc: VariantAccuracies::from_json(
                        h.get("variant_acc")
                            .ok_or_else(|| anyhow!("history missing variant_acc"))?,
                    )?,
                    weight_stats: h
                        .get("weight_stats")
                        .and_then(Json::as_obj)
                        .map(|m| {
                            m.iter()
                                .filter_map(|(k, v)| {
                                    v.as_f64_vec().map(|fv| (k.clone(), fv))
                                })
                                .collect()
                        })
                        .unwrap_or_default(),
                });
            }
        }

        Ok(ModelMeta {
            name: name.to_string(),
            num_nodes: v
                .get("num_nodes")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("missing num_nodes"))?,
            nodes,
            exits,
            skippable_nodes: v
                .get("skippable_nodes")
                .and_then(Json::as_usize_vec)
                .unwrap_or_default(),
            exit_nodes: v
                .get("exit_nodes")
                .and_then(Json::as_usize_vec)
                .unwrap_or_default(),
            weights_file: v
                .get("weights_file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("missing weights_file"))?
                .to_string(),
            final_accuracy: VariantAccuracies::from_json(
                v.get("final_accuracy")
                    .ok_or_else(|| anyhow!("missing final_accuracy"))?,
            )?,
            history,
        })
    }

    pub fn node(&self, index: usize) -> Result<&NodeMeta> {
        self.nodes
            .iter()
            .find(|n| n.index == index)
            .ok_or_else(|| anyhow!("{}: no node {index}", self.name))
    }

    pub fn exit(&self, after_node: usize) -> Result<&ExitMeta> {
        self.exits
            .iter()
            .find(|e| e.after_node == after_node)
            .ok_or_else(|| anyhow!("{}: no exit after node {after_node}", self.name))
    }

    pub fn is_skippable(&self, node: usize) -> bool {
        self.skippable_nodes.contains(&node)
    }

    pub fn has_exit_before(&self, failed: usize) -> bool {
        failed >= 2 && self.exit_nodes.contains(&(failed - 1))
    }

    /// All layer specs on the full path (every node, in order).
    pub fn all_layers(&self) -> Vec<&LayerSpec> {
        self.nodes.iter().flat_map(|n| n.layers.iter()).collect()
    }
}

fn parse_artifacts(v: Option<&Json>) -> Result<BTreeMap<usize, String>> {
    let obj = v
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow!("missing artifacts map"))?;
    let mut out = BTreeMap::new();
    for (k, path) in obj {
        out.insert(
            k.parse::<usize>()
                .map_err(|_| anyhow!("bad batch key '{k}'"))?,
            path.as_str()
                .ok_or_else(|| anyhow!("artifact path not a string"))?
                .to_string(),
        );
    }
    Ok(out)
}

fn parse_weights(v: Option<&Json>) -> Result<Vec<WeightEntry>> {
    v.and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing weights array"))?
        .iter()
        .map(WeightEntry::from_json)
        .collect()
}

#[cfg(test)]
pub mod test_fixtures {
    use super::*;
    use crate::dnn::layers::LayerKind;

    /// A small synthetic 5-node model for unit tests (no artifacts).
    pub fn tiny_model() -> ModelMeta {
        let mk_node = |index: usize, skippable: bool, c: usize| NodeMeta {
            index,
            in_shape: vec![8, 8, c],
            out_shape: vec![8, 8, c],
            skippable,
            artifacts: BTreeMap::new(),
            weights: Vec::new(),
            layers: vec![LayerSpec {
                kind: LayerKind::Conv,
                input_h: 8,
                input_w: 8,
                input_c: c,
                kernel: 3,
                stride: 1,
                filters: c,
            }],
        };
        let mk_exit = |after: usize| ExitMeta {
            after_node: after,
            in_shape: vec![8, 8, 4],
            artifacts: BTreeMap::new(),
            weights: Vec::new(),
            layers: vec![LayerSpec {
                kind: LayerKind::Dense,
                input_h: 1,
                input_w: 1,
                input_c: 256,
                kernel: 0,
                stride: 0,
                filters: 10,
            }],
        };
        let mut final_accuracy = VariantAccuracies {
            repartition: 0.9,
            ..Default::default()
        };
        for e in 1..=4 {
            final_accuracy.exit.insert(e, 0.5 + 0.1 * e as f64);
        }
        for s in [2, 3, 4] {
            final_accuracy.skip.insert(s, 0.85);
        }
        ModelMeta {
            name: "tiny".into(),
            num_nodes: 5,
            nodes: (1..=5).map(|i| mk_node(i, (2..=4).contains(&i), 4)).collect(),
            exits: (1..=4).map(mk_exit).collect(),
            skippable_nodes: vec![2, 3, 4],
            exit_nodes: vec![1, 2, 3, 4],
            weights_file: "none".into(),
            final_accuracy,
            history: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::test_fixtures::tiny_model;
    use super::*;

    #[test]
    fn tiny_model_lookups() {
        let m = tiny_model();
        assert_eq!(m.node(3).unwrap().index, 3);
        assert!(m.node(9).is_err());
        assert_eq!(m.exit(2).unwrap().after_node, 2);
        assert!(m.is_skippable(3));
        assert!(!m.is_skippable(1));
        assert!(m.has_exit_before(3));
        assert!(!m.has_exit_before(1));
    }

    #[test]
    fn parse_minimal_model_json() {
        let j = Json::parse(
            r#"{
              "num_nodes": 1,
              "nodes": {"1": {"in_shape": [32,32,3], "out_shape": [10],
                        "skippable": false,
                        "artifacts": {"1": "blocks/m_n1_b1.hlo.txt"},
                        "weights": [{"name": "p:0/w", "shape": [3,3,3,8], "offset": 0}]}},
              "exits": {},
              "node_layers": {"1": [{"kind": "conv", "input_h": 32, "input_w": 32,
                               "input_c": 3, "kernel": 3, "stride": 1, "filters": 8}]},
              "exit_layers": {},
              "skippable_nodes": [],
              "exit_nodes": [],
              "weights_file": "weights_m.bin",
              "final_accuracy": {"repartition": 0.8, "exit": {}, "skip": {}},
              "history": []
            }"#,
        )
        .unwrap();
        let m = ModelMeta::from_json("m", &j).unwrap();
        assert_eq!(m.nodes.len(), 1);
        assert_eq!(m.nodes[0].weights[0].elems(), 3 * 3 * 3 * 8);
        assert_eq!(m.nodes[0].out_bytes(), 40);
        assert_eq!(m.final_accuracy.repartition, 0.8);
    }
}
