//! Layer metadata: the hyperparameters of paper Table I, plus the feature
//! extraction used by the Latency Prediction Model and analytic FLOPs /
//! bytes estimates used by the partition planner and the perf analysis.

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// Layer types profiled by the paper (Table I) plus the two pooling types
/// our exit heads add.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LayerKind {
    BatchNorm,
    Conv,
    Relu,
    Dense,
    Add,
    Dropout,
    DepthwiseConv,
    GlobalAvgPool,
    GlobalMaxPool,
    MaxPool,
}

impl LayerKind {
    pub fn parse(s: &str) -> Result<LayerKind> {
        Ok(match s {
            "batchnorm" => LayerKind::BatchNorm,
            "conv" => LayerKind::Conv,
            "relu" => LayerKind::Relu,
            "dense" => LayerKind::Dense,
            "add" => LayerKind::Add,
            "dropout" => LayerKind::Dropout,
            "depthwise_conv" => LayerKind::DepthwiseConv,
            "global_avg_pool" => LayerKind::GlobalAvgPool,
            "global_max_pool" => LayerKind::GlobalMaxPool,
            "max_pool" => LayerKind::MaxPool,
            other => return Err(anyhow!("unknown layer kind '{other}'")),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            LayerKind::BatchNorm => "batchnorm",
            LayerKind::Conv => "conv",
            LayerKind::Relu => "relu",
            LayerKind::Dense => "dense",
            LayerKind::Add => "add",
            LayerKind::Dropout => "dropout",
            LayerKind::DepthwiseConv => "depthwise_conv",
            LayerKind::GlobalAvgPool => "global_avg_pool",
            LayerKind::GlobalMaxPool => "global_max_pool",
            LayerKind::MaxPool => "max_pool",
        }
    }

    pub const ALL: [LayerKind; 10] = [
        LayerKind::BatchNorm,
        LayerKind::Conv,
        LayerKind::Relu,
        LayerKind::Dense,
        LayerKind::Add,
        LayerKind::Dropout,
        LayerKind::DepthwiseConv,
        LayerKind::GlobalAvgPool,
        LayerKind::GlobalMaxPool,
        LayerKind::MaxPool,
    ];
}

/// One layer instance with its hyperparameters (paper Table I rows).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerSpec {
    pub kind: LayerKind,
    pub input_h: usize,
    pub input_w: usize,
    pub input_c: usize,
    /// kernel size (conv / depthwise / max_pool); 0 otherwise
    pub kernel: usize,
    /// stride; 0 for non-spatial layers
    pub stride: usize,
    /// output channels (conv), units (dense); 0 otherwise
    pub filters: usize,
}

impl LayerSpec {
    pub fn from_json(v: &Json) -> Result<LayerSpec> {
        let kind = LayerKind::parse(
            v.get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("layer record missing 'kind'"))?,
        )?;
        let g = |k: &str| v.get(k).and_then(Json::as_usize).unwrap_or(0);
        Ok(LayerSpec {
            kind,
            input_h: g("input_h"),
            input_w: g("input_w"),
            input_c: g("input_c"),
            kernel: g("kernel"),
            stride: g("stride"),
            filters: g("filters"),
        })
    }

    /// Feature vector for the per-kind latency model. The paper's features:
    /// input shape, input channel (+ kernel, stride, filter where
    /// applicable); we add derived FLOPs/bytes which greatly helps a small
    /// tree ensemble generalise.
    pub fn features(&self) -> Vec<f64> {
        vec![
            self.input_h as f64,
            self.input_w as f64,
            self.input_c as f64,
            self.kernel as f64,
            self.stride.max(1) as f64,
            self.filters as f64,
            (self.input_h * self.input_w * self.input_c) as f64, // input volume
            self.flops() as f64,
            self.output_elems() as f64,
        ]
    }

    pub const FEATURE_NAMES: [&'static str; 9] = [
        "input_h", "input_w", "input_c", "kernel", "stride", "filters",
        "input_volume", "flops", "output_elems",
    ];

    /// Output spatial size for strided spatial ops (SAME padding).
    fn out_hw(&self) -> (usize, usize) {
        let s = self.stride.max(1);
        match self.kind {
            LayerKind::MaxPool => {
                // VALID pooling
                let k = self.kernel.max(1);
                (
                    (self.input_h.saturating_sub(k)) / s + 1,
                    (self.input_w.saturating_sub(k)) / s + 1,
                )
            }
            LayerKind::Conv | LayerKind::DepthwiseConv => (
                (self.input_h + s - 1) / s,
                (self.input_w + s - 1) / s,
            ),
            _ => (self.input_h, self.input_w),
        }
    }

    pub fn output_elems(&self) -> usize {
        let (ho, wo) = self.out_hw();
        match self.kind {
            LayerKind::Conv => ho * wo * self.filters,
            LayerKind::DepthwiseConv => ho * wo * self.input_c,
            LayerKind::Dense => self.filters,
            LayerKind::GlobalAvgPool | LayerKind::GlobalMaxPool => self.input_c,
            LayerKind::MaxPool => ho * wo * self.input_c,
            _ => self.input_h * self.input_w * self.input_c,
        }
    }

    /// Multiply-accumulate-based FLOPs estimate (2 flops per MAC).
    pub fn flops(&self) -> usize {
        let (ho, wo) = self.out_hw();
        let vol_in = self.input_h * self.input_w * self.input_c;
        match self.kind {
            LayerKind::Conv => 2 * ho * wo * self.filters * self.kernel * self.kernel * self.input_c,
            LayerKind::DepthwiseConv => 2 * ho * wo * self.input_c * self.kernel * self.kernel,
            LayerKind::Dense => 2 * self.input_c * self.filters,
            LayerKind::BatchNorm => 2 * vol_in,
            LayerKind::Relu | LayerKind::Add | LayerKind::Dropout => vol_in,
            LayerKind::GlobalAvgPool | LayerKind::GlobalMaxPool => vol_in,
            LayerKind::MaxPool => ho * wo * self.input_c * self.kernel * self.kernel,
        }
    }

    /// Parameter bytes (f32) moved for this layer.
    pub fn param_bytes(&self) -> usize {
        4 * match self.kind {
            LayerKind::Conv => self.kernel * self.kernel * self.input_c * self.filters,
            LayerKind::DepthwiseConv => self.kernel * self.kernel * self.input_c,
            LayerKind::Dense => self.input_c * self.filters + self.filters,
            LayerKind::BatchNorm => 4 * self.input_c,
            _ => 0,
        }
    }
}

/// Parse a manifest layer-record array.
pub fn parse_layers(v: &Json) -> Result<Vec<LayerSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("expected layer array"))?
        .iter()
        .map(LayerSpec::from_json)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv_spec() -> LayerSpec {
        LayerSpec {
            kind: LayerKind::Conv,
            input_h: 32,
            input_w: 32,
            input_c: 16,
            kernel: 3,
            stride: 1,
            filters: 16,
        }
    }

    #[test]
    fn kind_roundtrip() {
        for k in LayerKind::ALL {
            assert_eq!(LayerKind::parse(k.name()).unwrap(), k);
        }
        assert!(LayerKind::parse("bogus").is_err());
    }

    #[test]
    fn conv_flops() {
        let s = conv_spec();
        // 2 * 32*32*16 * 3*3*16
        assert_eq!(s.flops(), 2 * 32 * 32 * 16 * 9 * 16);
        assert_eq!(s.output_elems(), 32 * 32 * 16);
    }

    #[test]
    fn strided_conv_output() {
        let mut s = conv_spec();
        s.stride = 2;
        s.filters = 32;
        assert_eq!(s.output_elems(), 16 * 16 * 32);
    }

    #[test]
    fn dense_flops() {
        let s = LayerSpec {
            kind: LayerKind::Dense,
            input_h: 1,
            input_w: 1,
            input_c: 64,
            kernel: 0,
            stride: 0,
            filters: 10,
        };
        assert_eq!(s.flops(), 2 * 64 * 10);
        assert_eq!(s.output_elems(), 10);
    }

    #[test]
    fn from_json() {
        let v = Json::parse(
            r#"{"kind": "conv", "input_h": 8, "input_w": 8, "input_c": 4, "kernel": 3, "stride": 2, "filters": 8}"#,
        )
        .unwrap();
        let s = LayerSpec::from_json(&v).unwrap();
        assert_eq!(s.kind, LayerKind::Conv);
        assert_eq!(s.output_elems(), 4 * 4 * 8);
        assert_eq!(s.features().len(), LayerSpec::FEATURE_NAMES.len());
    }

    #[test]
    fn maxpool_valid_output() {
        let s = LayerSpec {
            kind: LayerKind::MaxPool,
            input_h: 16,
            input_w: 16,
            input_c: 32,
            kernel: 2,
            stride: 2,
            filters: 0,
        };
        assert_eq!(s.output_elems(), 8 * 8 * 32);
    }
}
