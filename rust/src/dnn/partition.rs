//! Repartition planner: redistributes the DNN's blocks over the surviving
//! nodes after a failure (paper technique 1, §II-B-1).
//!
//! Blocks must stay contiguous (the DNN is a chain); a *plan* assigns each
//! surviving node a contiguous range of blocks. The planner minimises the
//! end-to-end pipeline latency estimate:
//!
//!   sum_i compute(range_i)  +  sum over adjacent pairs transfer(boundary)
//!
//! using dynamic programming over (block index, node count). Compute costs
//! come from the latency model (or FLOPs as a proxy); transfer costs from
//! the boundary activation size and the link model. An optional per-node
//! capacity (max compute per node) models resource-limited edge nodes; the
//! DP also exposes the bottleneck (max stage) objective for pipelined
//! serving.

use anyhow::{bail, Result};

/// Objective for the planner.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Objective {
    /// Minimise total end-to-end latency (sum of stages + transfers):
    /// matches the paper's single-request latency metric.
    TotalLatency,
    /// Minimise the slowest stage (throughput-optimal for pipelining).
    Bottleneck,
}

/// A repartition plan: `assignment[i]` = contiguous block range (1-based,
/// inclusive) hosted by the i-th surviving node.
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    pub assignment: Vec<(usize, usize)>,
    /// Estimated end-to-end latency (ms) under the cost model.
    pub est_latency_ms: f64,
}

/// Plan a repartition of `n_blocks` blocks over `n_nodes` nodes.
///
/// `compute_ms[b]` is the estimated compute latency of block b+1;
/// `transfer_ms[b]` is the link cost of moving block b+1's *output* to the
/// next node (the cost paid iff a node boundary is placed after block b+1).
/// `capacity_ms` optionally caps per-node total compute.
pub fn plan(
    n_blocks: usize,
    n_nodes: usize,
    compute_ms: &[f64],
    transfer_ms: &[f64],
    objective: Objective,
    capacity_ms: Option<f64>,
) -> Result<Plan> {
    if n_blocks == 0 || n_nodes == 0 {
        bail!("plan: empty problem");
    }
    if compute_ms.len() != n_blocks || transfer_ms.len() != n_blocks {
        bail!("plan: cost arrays must have n_blocks entries");
    }
    let k = n_nodes.min(n_blocks);
    // prefix sums of compute
    let mut pre = vec![0.0; n_blocks + 1];
    for b in 0..n_blocks {
        pre[b + 1] = pre[b] + compute_ms[b];
    }
    let seg = |lo: usize, hi: usize| pre[hi] - pre[lo]; // blocks lo+1..=hi
    let fits = |lo: usize, hi: usize| match capacity_ms {
        Some(cap) => seg(lo, hi) <= cap,
        None => true,
    };

    // dp[j][b] = best objective using j nodes for the first b blocks.
    const INF: f64 = f64::INFINITY;
    let mut dp = vec![vec![INF; n_blocks + 1]; k + 1];
    let mut parent = vec![vec![0usize; n_blocks + 1]; k + 1];
    dp[0][0] = 0.0;
    for j in 1..=k {
        for b in j..=n_blocks {
            // last node hosts blocks p+1..=b
            for p in (j - 1)..b {
                if dp[j - 1][p] == INF || !fits(p, b) {
                    continue;
                }
                let stage = seg(p, b);
                // transfer paid after block p (boundary into this node)
                let trans = if p > 0 { transfer_ms[p - 1] } else { 0.0 };
                let cand = match objective {
                    Objective::TotalLatency => dp[j - 1][p] + stage + trans,
                    Objective::Bottleneck => dp[j - 1][p].max(stage + trans),
                };
                if cand < dp[j][b] {
                    dp[j][b] = cand;
                    parent[j][b] = p;
                }
            }
        }
    }
    // Prefer using all k nodes only if it helps; any j <= k is allowed.
    let (best_j, best) = (1..=k)
        .map(|j| (j, dp[j][n_blocks]))
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .unwrap();
    if best == INF {
        bail!("plan: infeasible under capacity constraint");
    }
    // Reconstruct.
    let mut ranges = Vec::new();
    let mut b = n_blocks;
    let mut j = best_j;
    while j > 0 {
        let p = parent[j][b];
        ranges.push((p + 1, b));
        b = p;
        j -= 1;
    }
    ranges.reverse();
    Ok(Plan {
        assignment: ranges,
        est_latency_ms: best,
    })
}

/// Validity check used by tests and the property suite.
pub fn is_valid(plan: &Plan, n_blocks: usize, n_nodes: usize) -> bool {
    if plan.assignment.is_empty() || plan.assignment.len() > n_nodes {
        return false;
    }
    let mut next = 1usize;
    for &(lo, hi) in &plan.assignment {
        if lo != next || hi < lo {
            return false;
        }
        next = hi + 1;
    }
    next == n_blocks + 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};
    use crate::util::rng::Rng;

    #[test]
    fn single_node_gets_everything() {
        let p = plan(4, 1, &[1.0; 4], &[0.5; 4], Objective::TotalLatency, None).unwrap();
        assert_eq!(p.assignment, vec![(1, 4)]);
        assert!((p.est_latency_ms - 4.0).abs() < 1e-9);
    }

    #[test]
    fn total_latency_avoids_transfers() {
        // With expensive transfers, the total-latency objective should use
        // as few boundaries as possible.
        let p = plan(4, 4, &[1.0; 4], &[100.0; 4], Objective::TotalLatency, None).unwrap();
        assert_eq!(p.assignment.len(), 1);
    }

    #[test]
    fn bottleneck_balances() {
        let p = plan(
            4,
            2,
            &[3.0, 1.0, 1.0, 3.0],
            &[0.0; 4],
            Objective::Bottleneck,
            None,
        )
        .unwrap();
        assert_eq!(p.assignment, vec![(1, 2), (3, 4)]);
        assert!((p.est_latency_ms - 4.0).abs() < 1e-9);
    }

    #[test]
    fn capacity_forces_split() {
        let p = plan(4, 4, &[1.0; 4], &[0.1; 4], Objective::TotalLatency, Some(1.5)).unwrap();
        assert_eq!(p.assignment.len(), 4, "capacity 1.5 allows 1 block/node");
        assert!(is_valid(&p, 4, 4));
    }

    #[test]
    fn capacity_infeasible() {
        assert!(plan(2, 1, &[5.0, 5.0], &[0.0; 2], Objective::TotalLatency, Some(1.0)).is_err());
    }

    #[test]
    fn prop_plans_always_valid_partitions() {
        check(200, 0xC0FFEE, |g| {
            let n_blocks = g.usize(1, 18);
            let n_nodes = g.usize(1, 14);
            let compute: Vec<f64> = (0..n_blocks).map(|_| g.f64(0.1, 5.0)).collect();
            let transfer: Vec<f64> = (0..n_blocks).map(|_| g.f64(0.0, 2.0)).collect();
            let obj = if g.bool() {
                Objective::TotalLatency
            } else {
                Objective::Bottleneck
            };
            let p = plan(n_blocks, n_nodes, &compute, &transfer, obj, None)
                .map_err(|e| e.to_string())?;
            prop_assert(is_valid(&p, n_blocks, n_nodes), "plan must be a valid partition")?;
            prop_assert(p.est_latency_ms.is_finite(), "finite latency")
        });
    }

    #[test]
    fn prop_total_latency_optimal_vs_bruteforce() {
        // For small instances compare the DP against brute force over all
        // contiguous partitions.
        fn brute(n_blocks: usize, n_nodes: usize, c: &[f64], t: &[f64]) -> f64 {
            fn go(
                start: usize,
                nodes_left: usize,
                c: &[f64],
                t: &[f64],
            ) -> f64 {
                let n = c.len();
                if start == n {
                    return 0.0;
                }
                if nodes_left == 0 {
                    return f64::INFINITY;
                }
                let mut best = f64::INFINITY;
                for end in start + 1..=n {
                    let stage: f64 = c[start..end].iter().sum();
                    let trans = if end < n { t[end - 1] } else { 0.0 };
                    let rest = go(end, nodes_left - 1, c, t);
                    best = best.min(stage + trans + rest);
                }
                best
            }
            go(0, n_nodes.min(n_blocks), c, t)
        }
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let n_blocks = 1 + rng.below(7);
            let n_nodes = 1 + rng.below(5);
            let c: Vec<f64> = (0..n_blocks).map(|_| rng.range(0.1, 4.0)).collect();
            let t: Vec<f64> = (0..n_blocks).map(|_| rng.range(0.0, 3.0)).collect();
            let p = plan(n_blocks, n_nodes, &c, &t, Objective::TotalLatency, None).unwrap();
            let b = brute(n_blocks, n_nodes, &c, &t);
            assert!(
                (p.est_latency_ms - b).abs() < 1e-9,
                "dp {} vs brute {b}",
                p.est_latency_ms
            );
        }
    }
}
