//! Workload generation for the serving experiments: request streams with
//! poisson, burst or fixed-interval arrivals, plus trace replay.

use crate::util::rng::Rng;

/// One inference request (payload is an index into the eval set).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    pub id: usize,
    /// Arrival time, ms since stream start.
    pub arrival_ms: f64,
    /// Index of the input image in the eval set.
    pub input_idx: usize,
}

/// Arrival process shapes.
#[derive(Debug, Clone, Copy)]
pub enum Arrival {
    /// Poisson arrivals at `rate_rps` requests/second.
    Poisson { rate_rps: f64 },
    /// Fixed inter-arrival gap.
    Uniform { gap_ms: f64 },
    /// Bursts of `size` back-to-back requests every `period_ms`.
    Burst { size: usize, period_ms: f64 },
}

/// Generate `n` requests with the given arrival process.
pub fn generate(n: usize, arrival: Arrival, pool_size: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    generate_with(n, arrival, pool_size, &mut rng, 0)
}

/// Generate one independent arrival stream per replica: replica `r`
/// draws from `Rng::new(seed).derive(r)`, so its schedule is a pure
/// function of `(seed, r)` — byte-identical whether the streams are
/// consumed interleaved by the sequential engine or each by its own
/// shard, and unchanged when the replica count changes. Each stream
/// carries `n_per_replica` requests (ids `r * n_per_replica ..`, globally
/// unique) with the arrival process applied per replica, i.e. total
/// offered load scales with the replica count.
pub fn generate_per_replica(
    n_per_replica: usize,
    arrival: Arrival,
    pool_size: usize,
    seed: u64,
    replicas: usize,
) -> Vec<Vec<Request>> {
    assert!(replicas > 0, "need >= 1 replica stream");
    let root = Rng::new(seed);
    (0..replicas)
        .map(|r| {
            let mut rng = root.derive(r as u64);
            generate_with(n_per_replica, arrival, pool_size, &mut rng, r * n_per_replica)
        })
        .collect()
}

fn generate_with(
    n: usize,
    arrival: Arrival,
    pool_size: usize,
    rng: &mut Rng,
    id_base: usize,
) -> Vec<Request> {
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0;
    match arrival {
        Arrival::Poisson { rate_rps } => {
            let rate_per_ms = rate_rps / 1e3;
            for id in 0..n {
                t += rng.exp(rate_per_ms.max(1e-9));
                out.push(Request {
                    id: id_base + id,
                    arrival_ms: t,
                    input_idx: rng.below(pool_size.max(1)),
                });
            }
        }
        Arrival::Uniform { gap_ms } => {
            for id in 0..n {
                t += gap_ms;
                out.push(Request {
                    id: id_base + id,
                    arrival_ms: t,
                    input_idx: rng.below(pool_size.max(1)),
                });
            }
        }
        Arrival::Burst { size, period_ms } => {
            let mut id = 0;
            while id < n {
                for _ in 0..size.min(n - id) {
                    out.push(Request {
                        id: id_base + id,
                        arrival_ms: t,
                        input_idx: rng.below(pool_size.max(1)),
                    });
                    id += 1;
                }
                t += period_ms;
            }
        }
    }
    out
}

/// Split one stream into `replicas` interleaved per-replica streams
/// (round-robin by position), preserving arrival order within each — the
/// offline counterpart of the engine's round-robin router, useful for
/// driving replicas with pre-partitioned workloads.
pub fn split_round_robin(reqs: &[Request], replicas: usize) -> Vec<Vec<Request>> {
    assert!(replicas > 0, "need >= 1 replica stream");
    let mut out: Vec<Vec<Request>> = vec![Vec::with_capacity(reqs.len() / replicas + 1); replicas];
    for (i, r) in reqs.iter().enumerate() {
        out[i % replicas].push(*r);
    }
    out
}

/// Split one stream into per-replica streams using an arbitrary
/// positional picker: `pick()` is called once per request, in stream
/// order, and names the replica that request joins. This is the
/// generalisation of [`split_round_robin`] the weighted-round-robin
/// sharded path needs — the engine passes the same smooth-WRR schedule
/// the sequential router walks, so both modes assign every request to
/// the same replica. Each output stream preserves arrival order.
pub fn split_with(
    reqs: &[Request],
    replicas: usize,
    mut pick: impl FnMut() -> usize,
) -> Vec<Vec<Request>> {
    assert!(replicas > 0, "need >= 1 replica stream");
    let mut out: Vec<Vec<Request>> = vec![Vec::new(); replicas];
    for r in reqs {
        let i = pick();
        assert!(i < replicas, "picker chose replica {i} of {replicas}");
        out[i].push(*r);
    }
    out
}

/// Merge per-replica streams back into one stream ordered by arrival time
/// (stable: equal timestamps keep lower-replica-first order).
pub fn merge_streams(streams: &[Vec<Request>]) -> Vec<Request> {
    let mut out: Vec<Request> = streams.iter().flatten().copied().collect();
    out.sort_by(|a, b| a.arrival_ms.total_cmp(&b.arrival_ms));
    out
}

/// Save/replay traces as a simple CSV (id,arrival_ms,input_idx).
pub fn to_trace(reqs: &[Request]) -> String {
    let mut s = String::from("id,arrival_ms,input_idx\n");
    for r in reqs {
        s.push_str(&format!("{},{},{}\n", r.id, r.arrival_ms, r.input_idx));
    }
    s
}

pub fn from_trace(text: &str) -> anyhow::Result<Vec<Request>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate().skip(1) {
        if line.trim().is_empty() {
            continue;
        }
        let mut parts = line.split(',');
        let mut parse = |name: &str| -> anyhow::Result<f64> {
            parts
                .next()
                .ok_or_else(|| anyhow::anyhow!("trace line {i}: missing {name}"))?
                .trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("trace line {i}: {name}: {e}"))
        };
        let id = parse("id")? as usize;
        let arrival_ms = parse("arrival_ms")?;
        let input_idx = parse("input_idx")? as usize;
        out.push(Request {
            id,
            arrival_ms,
            input_idx,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_roughly_matches() {
        let reqs = generate(2000, Arrival::Poisson { rate_rps: 100.0 }, 64, 1);
        let span_s = reqs.last().unwrap().arrival_ms / 1e3;
        let rate = 2000.0 / span_s;
        assert!((80.0..120.0).contains(&rate), "rate {rate}");
        assert!(reqs.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
    }

    #[test]
    fn uniform_gap() {
        let reqs = generate(10, Arrival::Uniform { gap_ms: 5.0 }, 8, 2);
        assert!((reqs[9].arrival_ms - 50.0).abs() < 1e-9);
    }

    #[test]
    fn burst_structure() {
        let reqs = generate(10, Arrival::Burst { size: 4, period_ms: 100.0 }, 8, 3);
        assert_eq!(reqs.len(), 10);
        assert_eq!(reqs[0].arrival_ms, reqs[3].arrival_ms);
        assert!(reqs[4].arrival_ms > reqs[3].arrival_ms);
    }

    #[test]
    fn trace_roundtrip() {
        let reqs = generate(20, Arrival::Poisson { rate_rps: 50.0 }, 16, 4);
        let parsed = from_trace(&to_trace(&reqs)).unwrap();
        assert_eq!(parsed.len(), reqs.len());
        assert_eq!(parsed[7].id, reqs[7].id);
        assert!((parsed[7].arrival_ms - reqs[7].arrival_ms).abs() < 1e-6);
    }

    #[test]
    fn input_indices_within_pool() {
        let reqs = generate(100, Arrival::Poisson { rate_rps: 10.0 }, 5, 5);
        assert!(reqs.iter().all(|r| r.input_idx < 5));
    }

    #[test]
    fn per_replica_streams_are_stable_under_replica_count() {
        let arrival = Arrival::Poisson { rate_rps: 200.0 };
        let two = generate_per_replica(50, arrival, 16, 9, 2);
        let four = generate_per_replica(50, arrival, 16, 9, 4);
        // Replica r's schedule (times + inputs) is a pure function of
        // (seed, r): growing the fleet never reshuffles existing streams.
        for r in 0..2 {
            let (a, b) = (&two[r], &four[r]);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.arrival_ms, y.arrival_ms);
                assert_eq!(x.input_idx, y.input_idx);
            }
        }
        // Streams are mutually independent and ids globally unique.
        assert_ne!(four[0][0].arrival_ms, four[1][0].arrival_ms);
        let mut ids: Vec<usize> = four.iter().flatten().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..200).collect::<Vec<_>>());
        // Each stream is arrival-ordered, like any generated stream.
        for s in &four {
            assert!(s.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
        }
    }

    #[test]
    fn per_replica_single_stream_matches_generate_shape() {
        // One replica's stream has the same statistical machinery as
        // generate() (same process, same pool bounds); ids start at 0.
        let s = generate_per_replica(30, Arrival::Uniform { gap_ms: 2.0 }, 8, 4, 1);
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].len(), 30);
        assert_eq!(s[0][0].id, 0);
        assert!((s[0][29].arrival_ms - 60.0).abs() < 1e-9);
        assert!(s[0].iter().all(|r| r.input_idx < 8));
    }

    #[test]
    fn split_with_round_robin_picker_matches_split_round_robin() {
        let reqs = generate(25, Arrival::Poisson { rate_rps: 80.0 }, 16, 8);
        let mut next = 0usize;
        let by_picker = split_with(&reqs, 3, || {
            let r = next % 3;
            next += 1;
            r
        });
        assert_eq!(by_picker, split_round_robin(&reqs, 3));
    }

    #[test]
    fn split_merge_roundtrip() {
        let reqs = generate(31, Arrival::Poisson { rate_rps: 50.0 }, 16, 6);
        let streams = split_round_robin(&reqs, 4);
        assert_eq!(streams.len(), 4);
        assert_eq!(streams.iter().map(Vec::len).sum::<usize>(), 31);
        // round-robin: stream r holds requests r, r+4, r+8, ...
        assert_eq!(streams[1][0].id, reqs[1].id);
        assert_eq!(streams[1][1].id, reqs[5].id);
        // each stream stays arrival-ordered
        for s in &streams {
            assert!(s.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
        }
        let merged = merge_streams(&streams);
        assert_eq!(merged.len(), reqs.len());
        assert!(merged.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
        let mut ids: Vec<usize> = merged.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..31).collect::<Vec<_>>());
    }
}
