//! Experiment harness: one driver per table/figure of the paper's
//! evaluation (§V), wired to `continuer exp <id>`. See DESIGN.md §4 for
//! the index. Drivers persist intermediate results under
//! `artifacts/results/*.json` so downstream experiments (e.g. Table VII)
//! reuse measured data instead of re-measuring.

pub mod accuracy_eval;
pub mod deploy_eval;
pub mod detection_eval;
pub mod drop_attribution;
pub mod e2e;
pub mod figures;
pub mod latency_eval;
pub mod table2;
pub mod table7;
pub mod table8;
pub mod trace_export;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::config::Config;
use crate::runtime::{ArtifactStore, Engine};
use crate::util::json::Json;

/// Shared context for experiment drivers.
pub struct ExpContext {
    pub engine: Engine,
    pub store: ArtifactStore,
    pub config: Config,
}

impl ExpContext {
    pub fn open(config: Config) -> Result<ExpContext> {
        let store = ArtifactStore::open(&config.artifacts_dir)?;
        let engine = Engine::cpu()?;
        Ok(ExpContext {
            engine,
            store,
            config,
        })
    }

    pub fn results_dir(&self) -> PathBuf {
        self.config.artifacts_dir.join("results")
    }

    pub fn save_result(&self, name: &str, value: &Json) -> Result<PathBuf> {
        let path = self.results_dir().join(format!("{name}.json"));
        crate::obs::emit::write_json(&path, value, false)?;
        Ok(path)
    }

    pub fn load_result(&self, name: &str) -> Result<Json> {
        let path = self.results_dir().join(format!("{name}.json"));
        Json::from_file(&path)
    }

    pub fn has_result(&self, name: &str) -> bool {
        self.results_dir().join(format!("{name}.json")).exists()
    }

    /// Model names to evaluate (all in the manifest).
    pub fn model_names(&self) -> Vec<String> {
        self.store.models.keys().cloned().collect()
    }
}

/// Registry: run an experiment by id.
pub fn run(id: &str, ctx: &ExpContext) -> Result<()> {
    match id {
        "fig2" => figures::fig2(ctx),
        "fig3" => figures::fig3(ctx),
        "fig4" => figures::fig4(ctx),
        "fig6" => figures::fig6(ctx),
        "table2" => table2::run(ctx),
        "table5" | "fig7" => latency_eval::run(ctx, id == "fig7"),
        "table6" | "fig8" => accuracy_eval::run(ctx, id == "fig8"),
        "table7" => table7::run(ctx),
        "table8" => table8::run(ctx),
        "e2e" => e2e::run_default(ctx),
        // Synthetic (artifact-free) drivers; also runnable without any
        // artifacts via `continuer detection-eval` / `drop-attribution`.
        "detection" => detection_eval::run(ctx),
        "deploy" => deploy_eval::run(ctx),
        "drops" => drop_attribution::run(ctx),
        "all" => {
            for id in [
                "fig2", "fig3", "fig4", "fig6", "table2", "table5", "fig7", "table6", "fig8",
                "table7", "table8", "e2e",
            ] {
                println!("\n###### experiment {id} ######");
                run(id, ctx)?;
            }
            Ok(())
        }
        other => Err(anyhow!(
            "unknown experiment '{other}' (try fig2 fig3 fig4 fig6 table2 table5 fig7 table6 fig8 table7 table8 e2e detection deploy drops all)"
        )),
    }
}

/// Shared helper: artifacts dir from env or default.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("CONTINUER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            // Prefer CARGO_MANIFEST_DIR (tests/examples) else cwd.
            let base = std::env::var("CARGO_MANIFEST_DIR")
                .map(PathBuf::from)
                .unwrap_or_else(|_| PathBuf::from("."));
            base.join("artifacts")
        })
}

/// Check the artifacts exist, with a helpful message.
pub fn require_artifacts(dir: &Path) -> Result<()> {
    if !dir.join("manifest.json").exists() {
        return Err(anyhow!(
            "no artifacts at {} — run `make artifacts` first",
            dir.display()
        ));
    }
    Ok(())
}
