//! Detection-aggressiveness sweep: the downtime-vs-false-failover
//! frontier.
//!
//! A detector threshold buys exactly one thing with exactly one
//! currency: react to real crashes sooner (shorter detection latency,
//! fewer stranded requests) at the price of failing over healthy nodes
//! on heartbeat noise (false positives, each a pointless downtime window
//! plus a rollback). This driver sweeps both detector families over the
//! same noisy channel and workload — a mid-run crash with recovery plus
//! a heavy gray-failure window — and reports, per configuration, the
//! true-crash detection latency, the number of false failovers, the
//! total decision downtime and the drop count. Fully synthetic (no
//! artifacts needed) and deterministic for a given seed.

use anyhow::Result;

use crate::cluster::failure::FailurePlan;
use crate::config::Objectives;
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::engine::{
    serve_with_sink, EngineConfig, Execution, HealthMode, SyntheticBackend,
};
use crate::coordinator::estimator::StaticMetrics;
use crate::coordinator::failover::Failover;
use crate::coordinator::router::RoutePolicy;
use crate::coordinator::service::ServiceReport;
use crate::health::{DetectorKind, HealthConfig, HeartbeatConfig};
use crate::obs::report::{Downtime, ReportModule};
use crate::obs::EventBuffer;
use crate::runtime::HostTensor;
use crate::util::bench::{f, Table};
use crate::util::json::{obj, Json};
use crate::workload::{generate, Arrival};

use super::ExpContext;

/// Ground truth every swept configuration faces: a real crash with
/// recovery and a heavy gray-failure window, on a 4-stage pipeline.
const CRASH_NODE: usize = 3;
const CRASH_AT_MS: f64 = 400.0;
const CRASH_DOWN_MS: f64 = 300.0;

fn scenario_plan() -> FailurePlan {
    FailurePlan::merge([
        FailurePlan::crash_recover(CRASH_NODE, CRASH_AT_MS, CRASH_DOWN_MS),
        FailurePlan::degraded(2, 1200.0, 4.0, 400.0),
    ])
}

/// One swept configuration's outcome.
pub struct SweepPoint {
    pub label: String,
    pub detection_ms: Option<f64>,
    pub false_failovers: usize,
    pub failovers: usize,
    pub downtime_ms: f64,
    pub dropped: usize,
    pub p99_ms: f64,
    pub throughput_rps: f64,
}

fn run_point(
    label: &str,
    detector: DetectorKind,
    seed: u64,
) -> Result<(SweepPoint, ServiceReport)> {
    run_point_with(label, detector, seed, 1.0, 0.05)
}

fn run_point_with(
    label: &str,
    detector: DetectorKind,
    seed: u64,
    jitter_ms: f64,
    loss_prob: f64,
) -> Result<(SweepPoint, ServiceReport)> {
    let health = HealthConfig {
        heartbeat: HeartbeatConfig {
            interval_ms: 10.0,
            jitter_ms,
            loss_prob,
            blackout: None,
        },
        detector,
        failover_slowdown: 3.0,
        quarantine_ms: 100.0,
        slowdown_window: 8,
        seed,
    };
    let cfg = EngineConfig {
        batcher: BatcherConfig::new(vec![1], 2.0, 1),
        health: HealthMode::Monitored(health),
        deadline_ms: Some(250.0),
        pipeline_depth: 2,
        route: RoutePolicy::RoundRobin,
        decision_ms_override: Some(2.0),
        // The sweep reads only aggregates — stream, keep no records.
        record_completions: false,
        speed_factors: Vec::new(),
        steal: false,
        event_queue: Default::default(),
        execution: Execution::Sequential,
        deployment: Default::default(),
    };
    let mut backends = vec![SyntheticBackend::uniform(4, 5.0, 1.0)];
    let mut failovers = vec![Failover::new(Objectives::default())];
    let requests = generate(600, Arrival::Poisson { rate_rps: 150.0 }, 16, seed);
    let inputs = HostTensor::zeros(vec![16, 4]);
    let mut sink = EventBuffer::default();
    let report = serve_with_sink(
        &mut backends,
        &StaticMetrics,
        &mut failovers,
        &cfg,
        &requests,
        &inputs,
        &[scenario_plan()],
        &mut sink,
    )?;
    // Failover accounting comes off the event stream via the shared
    // `Downtime` module; drop/latency/throughput aggregates still read
    // the report. Module-vs-report equivalence is asserted in tests.
    let mut downtime = Downtime::with_crash(CRASH_NODE, CRASH_AT_MS);
    for ev in &sink.events {
        downtime.on_event(ev);
    }
    let point = SweepPoint {
        label: label.to_string(),
        detection_ms: downtime.detection_ms(),
        false_failovers: downtime.false_failovers(),
        failovers: downtime.failovers(),
        downtime_ms: downtime.total_downtime_ms(),
        dropped: report.dropped.len(),
        p99_ms: report.latency.p99,
        throughput_rps: report.throughput_rps,
    };
    Ok((point, report))
}

/// Run the sweep; prints the frontier table and returns the JSON record.
pub fn sweep(seed: u64) -> Result<Json> {
    let mut cases: Vec<(String, DetectorKind)> = Vec::new();
    for timeout_ms in [15.0, 25.0, 50.0, 100.0] {
        cases.push((
            format!("fixed/{timeout_ms}ms"),
            DetectorKind::FixedTimeout { timeout_ms },
        ));
    }
    for threshold in [1.0, 3.0, 5.0, 8.0, 12.0] {
        cases.push((
            format!("phi/{threshold}"),
            DetectorKind::PhiAccrual {
                threshold,
                window: 48,
                min_std_ms: 0.5,
            },
        ));
    }

    let mut t = Table::new(
        "detection sweep — downtime vs false failovers (crash @400ms + 4x gray @1200ms, 5% loss)",
        &[
            "detector",
            "detect ms",
            "false fo",
            "failovers",
            "downtime ms",
            "dropped",
            "p99 ms",
            "rps",
        ],
    );
    let mut rows = Vec::new();
    for (label, kind) in &cases {
        let (p, _) = run_point(label, *kind, seed)?;
        t.row(&[
            p.label.clone(),
            p.detection_ms.map(|d| f(d, 1)).unwrap_or_else(|| "-".into()),
            p.false_failovers.to_string(),
            p.failovers.to_string(),
            f(p.downtime_ms, 2),
            p.dropped.to_string(),
            f(p.p99_ms, 1),
            f(p.throughput_rps, 1),
        ]);
        rows.push(obj(&[
            ("detector", p.label.clone().into()),
            (
                "detection_ms",
                p.detection_ms.map(Json::from).unwrap_or(Json::Null),
            ),
            ("false_failovers", p.false_failovers.into()),
            ("failovers", p.failovers.into()),
            ("downtime_ms", p.downtime_ms.into()),
            ("dropped", p.dropped.into()),
            ("p99_ms", p.p99_ms.into()),
            ("throughput_rps", p.throughput_rps.into()),
        ]));
    }
    t.print();
    println!(
        "frontier reading: aggressive detectors (low timeout / phi threshold) cut detection \
         latency but pay in false failovers; conservative ones strand traffic longer.\n"
    );
    Ok(obj(&[
        ("experiment", "detection_eval".into()),
        ("seed", (seed as usize).into()),
        ("crash_at_ms", CRASH_AT_MS.into()),
        ("crash_down_ms", CRASH_DOWN_MS.into()),
        ("requests", 600usize.into()),
        ("arrival", "poisson 150 rps".into()),
        ("deadline_ms", 250.0.into()),
        ("loss_prob", 0.05.into()),
        ("points", Json::Arr(rows)),
    ]))
}

/// Registry entry point: run and persist under the artifacts results dir.
pub fn run(ctx: &ExpContext) -> Result<()> {
    let out = sweep(ctx.config.seed)?;
    let path = ctx.save_result("detection_eval", &out)?;
    println!("wrote {}", path.display());
    Ok(())
}

/// Artifact-free entry point (`continuer detection-eval`): write the
/// JSON next to the working directory (or `--out`).
pub fn run_standalone(seed: u64, out: Option<&str>, pretty: bool) -> Result<()> {
    let record = sweep(seed)?;
    crate::obs::emit::emit_json(&record, "detection_eval.json", out, pretty)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The legacy detection-latency computation, recomputed from the
    /// report's failover windows: latency from the scenario's real
    /// crash to its first honest detection of the crashed node (None
    /// when the detector never attributed a failover to it).
    fn true_detection_latency(report: &ServiceReport) -> Option<f64> {
        report
            .failovers
            .iter()
            .filter(|w| w.node == CRASH_NODE && !w.false_positive && w.start_ms >= CRASH_AT_MS)
            .map(|w| w.start_ms - CRASH_AT_MS)
            .min_by(|a, b| a.total_cmp(b))
    }

    #[test]
    fn sweep_point_detects_the_real_crash() {
        // Clean channel: detection timing is analytic (last beat at 390,
        // checks every 10 ms, timeout 25 → failover at 420).
        let (p, _) = run_point_with(
            "fixed/25ms",
            DetectorKind::FixedTimeout { timeout_ms: 25.0 },
            3,
            0.0,
            0.0,
        )
        .unwrap();
        let d = p.detection_ms.expect("the real crash must be detected");
        assert!(d > 0.0 && d < 200.0, "detection latency {d}");
        assert_eq!(p.false_failovers, 0, "clean channel cannot false-positive");
        assert!(p.failovers >= 2, "crash + gray failure both fail over");
        assert!(p.throughput_rps > 0.0);
    }

    #[test]
    fn conservative_fixed_timeout_detects_later() {
        let fixed = |ms| DetectorKind::FixedTimeout { timeout_ms: ms };
        let (fast, _) = run_point_with("fixed/15ms", fixed(15.0), 3, 0.0, 0.0).unwrap();
        let (slow, _) = run_point_with("fixed/100ms", fixed(100.0), 3, 0.0, 0.0).unwrap();
        let df = fast.detection_ms.unwrap();
        let ds = slow.detection_ms.unwrap();
        assert!(df < ds, "aggressive timeout must detect sooner: {df} vs {ds}");
    }

    /// The `Downtime` event-stream module reproduces the numbers the
    /// legacy driver computed from `ServiceReport` fields, on the same
    /// seed and under heartbeat noise (false positives included).
    #[test]
    fn downtime_module_matches_report_accounting() {
        let phi = DetectorKind::PhiAccrual {
            threshold: 1.0,
            window: 48,
            min_std_ms: 0.5,
        };
        let (p, report) = run_point_with("phi/1", phi, 3, 1.0, 0.05).unwrap();
        assert_eq!(p.failovers, report.failovers.len());
        assert_eq!(p.false_failovers, report.false_failovers());
        assert!(
            (p.downtime_ms - report.total_downtime_ms()).abs() < 1e-9,
            "module downtime {} vs report {}",
            p.downtime_ms,
            report.total_downtime_ms()
        );
        assert_eq!(p.detection_ms, true_detection_latency(&report));
    }

    #[test]
    fn sweep_emits_every_point() {
        let out = sweep(3).unwrap();
        match out.get("points") {
            Some(Json::Arr(points)) => assert_eq!(points.len(), 9),
            other => panic!("points array missing: {other:?}"),
        }
    }
}
