//! `continuer trace`: record a synthetic serving run with failures and
//! export it as a Chrome `trace_event` JSON file for Perfetto.
//!
//! The scenario is artifact-free and exercises every marker the
//! exporter draws: per-(replica, node) stage spans, a real crash with
//! recovery (failover window + detection instant + quarantined
//! reintegration on replica 0), a gray-failure slowdown (replica 1),
//! and a request deadline so drops can appear. Deterministic for a
//! given seed — same seed, same bytes — which the `trace_export`
//! integration tests assert.

use anyhow::Result;

use crate::cluster::failure::FailurePlan;
use crate::config::Objectives;
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::engine::{
    serve_with_sink, EngineConfig, Execution, HealthMode, SyntheticBackend,
};
use crate::coordinator::estimator::StaticMetrics;
use crate::coordinator::failover::Failover;
use crate::coordinator::router::RoutePolicy;
use crate::health::{DetectorKind, HealthConfig, HeartbeatConfig};
use crate::obs::trace::chrome_trace;
use crate::obs::{EngineEvent, EngineEventKind, EventBuffer};
use crate::runtime::HostTensor;
use crate::workload::{generate, Arrival};

/// Per-replica failure plans: a crash with recovery on replica 0 (the
/// full failover → quarantine → reintegration arc) and a gray-failure
/// slowdown on replica 1. Further replicas cycle through the same two.
fn plan_for(replica: usize) -> FailurePlan {
    if replica % 2 == 0 {
        FailurePlan::crash_recover(3, 400.0, 300.0)
    } else {
        FailurePlan::degraded(2, 600.0, 4.0, 300.0)
    }
}

/// Record the demo scenario's event stream under the given execution
/// mode. Clean heartbeat channel (no jitter/loss) so detection timing —
/// and therefore the exported trace — is deterministic per seed.
pub fn record_with(
    requests: usize,
    replicas: usize,
    seed: u64,
    execution: Execution,
) -> Result<Vec<EngineEvent>> {
    let health = HealthConfig {
        heartbeat: HeartbeatConfig {
            interval_ms: 10.0,
            jitter_ms: 0.0,
            loss_prob: 0.0,
            blackout: None,
        },
        detector: DetectorKind::FixedTimeout { timeout_ms: 25.0 },
        failover_slowdown: 3.0,
        quarantine_ms: 100.0,
        slowdown_window: 8,
        seed,
    };
    let cfg = EngineConfig {
        batcher: BatcherConfig::new(vec![1], 2.0, 1),
        health: HealthMode::Monitored(health),
        deadline_ms: Some(250.0),
        pipeline_depth: 2,
        route: RoutePolicy::RoundRobin,
        decision_ms_override: Some(2.0),
        record_completions: false,
        speed_factors: Vec::new(),
        steal: false,
        event_queue: Default::default(),
        execution,
        deployment: Default::default(),
    };
    let mut backends: Vec<SyntheticBackend> = (0..replicas)
        .map(|_| SyntheticBackend::uniform(4, 5.0, 1.0))
        .collect();
    let mut failovers: Vec<Failover> = (0..replicas)
        .map(|_| Failover::new(Objectives::default()))
        .collect();
    let plans: Vec<FailurePlan> = (0..replicas).map(plan_for).collect();
    let reqs = generate(requests, Arrival::Poisson { rate_rps: 150.0 }, 16, seed);
    let inputs = HostTensor::zeros(vec![16, 4]);
    let mut sink = EventBuffer::default();
    serve_with_sink(
        &mut backends,
        &StaticMetrics,
        &mut failovers,
        &cfg,
        &reqs,
        &inputs,
        &plans,
        &mut sink,
    )?;
    Ok(sink.take_events())
}

/// `continuer trace` entry point: record, export, summarize.
pub fn run_standalone(
    requests: usize,
    replicas: usize,
    seed: u64,
    out: Option<&str>,
    pretty: bool,
) -> Result<()> {
    let events = record_with(requests, replicas, seed, Execution::Sequential)?;
    let stages = events
        .iter()
        .filter(|e| matches!(e.kind, EngineEventKind::StageStart { .. }))
        .count();
    let failovers = events
        .iter()
        .filter(|e| matches!(e.kind, EngineEventKind::Failover { .. }))
        .count();
    println!(
        "recorded {} events ({stages} stage spans, {failovers} failovers) over {replicas} replicas",
        events.len()
    );
    let doc = chrome_trace(&events);
    crate::obs::emit::emit_json(&doc, "trace.json", out, pretty)?;
    println!("open in https://ui.perfetto.dev or chrome://tracing");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_scenario_exercises_every_marker() {
        let events = record_with(300, 2, 7, Execution::Sequential).unwrap();
        let has = |pred: &dyn Fn(&EngineEventKind) -> bool| events.iter().any(|e| pred(&e.kind));
        assert!(has(&|k| matches!(k, EngineEventKind::StageStart { .. })));
        assert!(has(&|k| matches!(k, EngineEventKind::StageDone { .. })));
        assert!(has(&|k| matches!(k, EngineEventKind::Failover { .. })));
        assert!(
            has(&|k| matches!(k, EngineEventKind::QuarantineEnter { .. })),
            "crash_recover under a quarantine gate must produce a quarantine window"
        );
        assert!(has(&|k| matches!(k, EngineEventKind::Completion { .. })));
    }
}
