//! Table VII: quality of the Scheduler's selection — the paper's parameter
//! sweep over objective weights ω ∈ {0.1..0.9}³.
//!
//! For every (model, platform, failed node, weight combination): select a
//! technique using the *estimated* metrics, and compare against the ground
//! truth selected from the *measured* metrics (Tables V and VI data). The
//! quality is classification accuracy over all instances, as in the paper.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::coordinator::scheduler::{select, weight_sweep, CandidateMetrics};
use crate::dnn::variants::Technique;
use crate::util::bench::{pct, Table};
use crate::util::json::{obj, Json};

use super::{accuracy_eval, latency_eval, ExpContext};

/// Downtime constants (ms) used for the sweep: empirical magnitudes from
/// Table VIII's regime (prediction+selection cost; exit is cheapest).
fn downtime_for(kind: &str, reinstate_ms: f64) -> f64 {
    match kind {
        "early-exit" => 1.8,
        "repartition" => 3.5 + reinstate_ms,
        _ => 3.3 + reinstate_ms,
    }
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    let lat_points = latency_eval::evaluate(ctx)?;
    let acc_points = accuracy_eval::evaluate(ctx)?;
    let weights = weight_sweep(0.1, 0.9, 0.1);

    // Index the measured/predicted metrics per (platform, model, failed).
    type Key = (String, String, usize);
    let mut lat: BTreeMap<Key, Vec<(Technique, f64, f64)>> = BTreeMap::new();
    for p in &lat_points {
        lat.entry((p.platform.clone(), p.model.clone(), p.failed))
            .or_default()
            .push((p.technique, p.measured_ms, p.predicted_ms));
    }
    let mut acc: BTreeMap<(String, usize), Vec<(Technique, f64, f64)>> = BTreeMap::new();
    for p in &acc_points {
        acc.entry((p.model.clone(), p.failed))
            .or_default()
            .push((p.technique, p.measured, p.predicted));
    }

    let mut t = Table::new(
        "Table VII — Scheduler selection quality (classification accuracy)",
        &["DNN Model", "Platform 1", "Platform 2"],
    );
    let mut rows_json = Vec::new();
    for name in ctx.model_names() {
        let mut cells = vec![name.clone()];
        for platform in ["platform1", "platform2"] {
            let mut correct = 0usize;
            let mut total = 0usize;
            for ((plat, model, failed), lat_entries) in &lat {
                if plat != platform || model != &name {
                    continue;
                }
                let Some(acc_entries) = acc.get(&(model.clone(), *failed)) else {
                    continue;
                };
                // Join on technique.
                let mut est_c: Vec<CandidateMetrics> = Vec::new();
                let mut meas_c: Vec<CandidateMetrics> = Vec::new();
                for (tech, meas_ms, pred_ms) in lat_entries {
                    let Some((_, meas_acc, pred_acc)) =
                        acc_entries.iter().find(|(t2, _, _)| t2 == tech)
                    else {
                        continue;
                    };
                    let d = downtime_for(tech.kind_name(), ctx.config.reinstate_ms);
                    est_c.push(CandidateMetrics {
                        technique: *tech,
                        accuracy: *pred_acc,
                        latency_ms: *pred_ms,
                        downtime_ms: d,
                    });
                    meas_c.push(CandidateMetrics {
                        technique: *tech,
                        accuracy: *meas_acc,
                        latency_ms: *meas_ms,
                        downtime_ms: d,
                    });
                }
                if est_c.len() < 2 {
                    continue; // selection trivial with one candidate
                }
                for w in &weights {
                    let est_pick = select(&est_c, w)?.chosen;
                    let truth = select(&meas_c, w)?.chosen;
                    if est_pick == truth {
                        correct += 1;
                    }
                    total += 1;
                }
            }
            cells.push(if total == 0 {
                "-".into()
            } else {
                pct(100.0 * correct as f64 / total as f64, 2)
            });
            rows_json.push(obj(&[
                ("model", name.clone().into()),
                ("platform", platform.into()),
                ("instances", total.into()),
                (
                    "accuracy_pct",
                    if total == 0 {
                        Json::Null
                    } else {
                        (100.0 * correct as f64 / total as f64).into()
                    },
                ),
            ]));
            if total > 0 {
                println!(
                    "{name}/{platform}: {total} instances ({} failure cases x {} weight combos)",
                    total / weights.len(),
                    weights.len()
                );
            }
        }
        t.row(&cells);
    }
    t.print();
    let record = obj(&[
        ("experiment", "table7".into()),
        ("weights", weights.len().into()),
        ("rows", Json::Arr(rows_json)),
    ]);
    let path = ctx.save_result("table7", &record)?;
    println!("wrote {}", path.display());
    Ok(())
}
