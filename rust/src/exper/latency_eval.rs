//! Table V + Fig 7: measured vs predicted end-to-end latency for every
//! (platform, model, failed node, technique).
//!
//! Measured: the real pipeline executed on the cluster (batch 1), averaged
//! over reps; platform 2 scales the measured compute portion by the
//! slow-platform factor (network is platform-independent).
//! Predicted: the Estimator (per-layer GBDT sums + analytic link time).
//!
//! Persists `results/latency_eval.json` for Table VII.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::cluster::sim::EdgeCluster;
use crate::config::Platform;
use crate::coordinator::estimator::Estimator;
use crate::coordinator::profiler::{fit_platform, DowntimeTable};
use crate::dnn::variants::{candidates, failure_sweep, Technique};
use crate::predict::{AccuracyModel, GbdtParams};
use crate::util::bench::{f, pct, Table};
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;
use crate::util::stats::avg_pct_error;

use super::table2::layer_samples;
use super::ExpContext;

/// One evaluated point.
#[derive(Debug, Clone)]
pub struct LatencyPoint {
    pub platform: String,
    pub model: String,
    pub failed: usize,
    pub technique: Technique,
    pub measured_ms: f64,
    pub predicted_ms: f64,
}

fn tech_json(t: Technique) -> Json {
    obj(&[
        ("kind", t.kind_name().into()),
        (
            "index",
            match t {
                Technique::Repartition => 0usize.into(),
                Technique::EarlyExit(e) => e.into(),
                Technique::SkipConnection(k) => k.into(),
            },
        ),
    ])
}

pub fn tech_from_json(v: &Json) -> Result<Technique> {
    let kind = v
        .get("kind")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("missing technique kind"))?;
    let idx = v.get("index").and_then(Json::as_usize).unwrap_or(0);
    Ok(match kind {
        "repartition" => Technique::Repartition,
        "early-exit" => Technique::EarlyExit(idx),
        "skip-connection" => Technique::SkipConnection(idx),
        other => anyhow::bail!("bad technique kind {other}"),
    })
}

fn points_to_json(points: &[LatencyPoint]) -> Json {
    Json::Arr(
        points
            .iter()
            .map(|p| {
                obj(&[
                    ("platform", p.platform.as_str().into()),
                    ("model", p.model.as_str().into()),
                    ("failed", p.failed.into()),
                    ("technique", tech_json(p.technique)),
                    ("measured_ms", p.measured_ms.into()),
                    ("predicted_ms", p.predicted_ms.into()),
                ])
            })
            .collect(),
    )
}

pub fn points_from_json(v: &Json) -> Result<Vec<LatencyPoint>> {
    v.as_arr()
        .ok_or_else(|| anyhow::anyhow!("bad latency points"))?
        .iter()
        .map(|p| {
            Ok(LatencyPoint {
                platform: p
                    .get("platform")
                    .and_then(Json::as_str)
                    .unwrap_or("platform1")
                    .to_string(),
                model: p
                    .get("model")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
                failed: p.get("failed").and_then(Json::as_usize).unwrap_or(0),
                technique: tech_from_json(
                    p.get("technique")
                        .ok_or_else(|| anyhow::anyhow!("missing technique"))?,
                )?,
                measured_ms: p.get("measured_ms").and_then(Json::as_f64).unwrap_or(0.0),
                predicted_ms: p.get("predicted_ms").and_then(Json::as_f64).unwrap_or(0.0),
            })
        })
        .collect()
}

/// Compute (or load cached) every latency point.
pub fn evaluate(ctx: &ExpContext) -> Result<Vec<LatencyPoint>> {
    if ctx.has_result("latency_eval") {
        return points_from_json(&ctx.load_result("latency_eval")?);
    }
    let samples = layer_samples(ctx)?;
    let params = GbdtParams::default();
    let platforms = [Platform::Host, Platform::platform2()];
    let fitted: Vec<_> = platforms
        .iter()
        .map(|p| fit_platform(&samples, p.clone(), &params, ctx.config.seed))
        .collect::<Result<_>>()?;

    // Accuracy model only needed to satisfy the Estimator signature here;
    // fit it once (cheap) over all model histories.
    let metas: Vec<&crate::dnn::model::ModelMeta> = ctx.store.models.values().collect();
    let (acc_model, _) = AccuracyModel::fit(&metas, &params, ctx.config.seed)?;
    let downtime: DowntimeTable = DowntimeTable::new();

    let mut points = Vec::new();
    let reps = ctx.config.profile_reps.min(10);
    let mut rng = Rng::new(ctx.config.seed ^ 0x7A7A);
    let p2 = Platform::platform2();
    let (p2_factor, p2_noise) = match p2 {
        Platform::Scaled { factor, noise } => (factor, noise),
        _ => unreachable!(),
    };

    for name in ctx.model_names() {
        let meta = ctx.store.model(&name)?;
        let cluster = EdgeCluster::new(
            &ctx.engine,
            &ctx.store,
            meta,
            ctx.config.link.clone(),
            ctx.config.seed,
        );
        let (images, _) = ctx.store.test_set()?;
        let sample = images.slice0(0, 1)?;
        eprintln!("[latency_eval] {name}: measuring {} failure cases ...", failure_sweep(meta).len());
        for failed in failure_sweep(meta) {
            for tech in candidates(meta, failed) {
                let (comp_ms, net_ms) =
                    cluster.measure_latency_split(tech, Some(failed), &sample, reps)?;
                for (pi, fitted_p) in fitted.iter().enumerate() {
                    let est = Estimator::new(
                        meta,
                        &fitted_p.model,
                        &acc_model,
                        cluster.link(),
                        &downtime,
                        ctx.config.reinstate_ms,
                    );
                    let predicted = est.predict_latency_ms(tech, Some(failed));
                    let measured = if pi == 0 {
                        comp_ms + net_ms
                    } else {
                        // Platform 2: scale measured compute by the slow
                        // factor with bounded jitter; network unchanged.
                        comp_ms * p2_factor * (1.0 + p2_noise * rng.normal()) + net_ms
                    };
                    points.push(LatencyPoint {
                        platform: fitted_p.platform.name(),
                        model: name.clone(),
                        failed,
                        technique: tech,
                        measured_ms: measured,
                        predicted_ms: predicted,
                    });
                }
            }
        }
    }
    ctx.save_result("latency_eval", &points_to_json(&points))?;
    Ok(points)
}

/// Render Table V (avg % error per technique/platform/model) and
/// optionally the Fig 7 per-node series.
pub fn run(ctx: &ExpContext, fig7: bool) -> Result<()> {
    let points = evaluate(ctx)?;

    if fig7 {
        for platform in ["platform1", "platform2"] {
            for name in ctx.model_names() {
                let mut t = Table::new(
                    &format!("Fig 7 — measured vs predicted latency ({platform}, {name})"),
                    &["failed node", "technique", "measured ms", "predicted ms"],
                );
                for p in points
                    .iter()
                    .filter(|p| p.platform == platform && p.model == name)
                {
                    t.row(&[
                        format!("n{}", p.failed),
                        p.technique.label(),
                        f(p.measured_ms, 2),
                        f(p.predicted_ms, 2),
                    ]);
                }
                t.print();
            }
        }
    }

    // Table V: avg % error grouped by (technique kind, platform, model).
    let mut t = Table::new(
        "Table V — avg % error of latency estimation",
        &["Technique", "P1 resnet32", "P1 mobilenetv2", "P2 resnet32", "P2 mobilenetv2"],
    );
    for kind in ["repartition", "early-exit", "skip-connection"] {
        let mut cells = vec![kind.to_string()];
        for platform in ["platform1", "platform2"] {
            for name in ["resnet32", "mobilenetv2"] {
                let (pred, meas): (Vec<f64>, Vec<f64>) = points
                    .iter()
                    .filter(|p| {
                        p.platform == platform
                            && p.model == name
                            && p.technique.kind_name() == kind
                    })
                    .map(|p| (p.predicted_ms, p.measured_ms))
                    .unzip();
                cells.push(if pred.is_empty() {
                    "-".into()
                } else {
                    pct(avg_pct_error(&pred, &meas), 2)
                });
            }
        }
        t.row(&cells);
    }
    t.print();

    // Paper headline: max avg error (13.06% for early-exit in the paper).
    let mut worst: BTreeMap<&str, f64> = BTreeMap::new();
    for kind in ["repartition", "early-exit", "skip-connection"] {
        for platform in ["platform1", "platform2"] {
            for name in ctx.model_names() {
                let (pred, meas): (Vec<f64>, Vec<f64>) = points
                    .iter()
                    .filter(|p| {
                        p.platform == platform && p.model == name && p.technique.kind_name() == kind
                    })
                    .map(|p| (p.predicted_ms, p.measured_ms))
                    .unzip();
                if !pred.is_empty() {
                    let e = avg_pct_error(&pred, &meas);
                    let w = worst.entry(kind).or_insert(0.0);
                    *w = w.max(e);
                }
            }
        }
    }
    println!("worst avg %% error per technique: {worst:?}\n");
    Ok(())
}
