//! Drop attribution: which deadline drops are the failure's fault?
//!
//! Sweeps the request deadline and classifies every drop event as
//! *inside* or *outside* the ground-truth outage windows of the failure
//! plan (merged per-cluster intervals where any node is down; a drop
//! counts as inside when the request's waiting interval overlapped a
//! window). The classification itself lives in
//! [`crate::obs::report::DropAttribution`] — this driver is the thin
//! composition: run with a recording sink, fold the stream through the
//! module, print the table. A test asserts the module's numbers match
//! the legacy classification recomputed from `ServiceReport::dropped`.
//! Outside-window drops at a given deadline are pure overload — the
//! failure cannot be blamed for them — so the inside/outside split
//! separates "the deadline is too tight for this load" from "the outage
//! stranded this traffic". The scenario uses two *overlapping* failures
//! (the second lands while the first is still down) so no recovery
//! technique can route around both: the replica genuinely stalls until
//! the first recovery, which is what makes inside-window drops appear at
//! sane deadlines. Fully synthetic and deterministic.

use anyhow::Result;

use crate::cluster::failure::{Detector, FailurePlan, NodeCondition};
use crate::config::Objectives;
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::engine::{
    serve_with_sink, EngineConfig, Execution, HealthMode, SyntheticBackend,
};
use crate::coordinator::estimator::StaticMetrics;
use crate::coordinator::failover::Failover;
use crate::coordinator::router::RoutePolicy;
use crate::coordinator::service::ServiceReport;
use crate::obs::report::{DropAttribution, ReportModule};
use crate::obs::EventBuffer;
use crate::runtime::HostTensor;
use crate::util::bench::{f, Table};
use crate::util::json::{obj, Json};
use crate::workload::{generate, Arrival};

use super::ExpContext;

/// Merged intervals during which at least one node is down, from the
/// ground-truth plan. Open-ended outages close at `f64::INFINITY`.
pub fn outage_windows(plan: &FailurePlan) -> Vec<(f64, f64)> {
    // Per-node down intervals first.
    let mut intervals: Vec<(f64, f64)> = Vec::new();
    let mut nodes: Vec<usize> = plan.events.iter().map(|e| e.node).collect();
    nodes.sort_unstable();
    nodes.dedup();
    for node in nodes {
        let mut down_since: Option<f64> = None;
        for e in plan.events.iter().filter(|e| e.node == node) {
            match (down_since, e.condition) {
                (None, NodeCondition::Down) => down_since = Some(e.at_ms),
                (Some(s), NodeCondition::Up) | (Some(s), NodeCondition::Degraded(_)) => {
                    intervals.push((s, e.at_ms));
                    down_since = None;
                }
                _ => {}
            }
        }
        if let Some(s) = down_since {
            intervals.push((s, f64::INFINITY));
        }
    }
    // Merge overlaps.
    intervals.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut merged: Vec<(f64, f64)> = Vec::new();
    for (s, e) in intervals {
        match merged.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => merged.push((s, e)),
        }
    }
    merged
}

/// The swept scenario: node 3 down 500-900, node 2 down 520-920 — the
/// overlap makes every recovery path infeasible until 900.
fn scenario_plan() -> FailurePlan {
    FailurePlan::merge([
        FailurePlan::crash_recover(3, 500.0, 400.0),
        FailurePlan::crash_recover(2, 520.0, 400.0),
    ])
}

/// One deadline's outcome.
pub struct DeadlinePoint {
    pub deadline_ms: f64,
    pub completed: usize,
    pub dropped_inside: usize,
    pub dropped_outside: usize,
    pub dropped_degraded: usize,
    pub p99_ms: f64,
}

fn run_deadline(deadline_ms: f64, seed: u64) -> Result<(DeadlinePoint, ServiceReport)> {
    let cfg = EngineConfig {
        batcher: BatcherConfig::new(vec![1], 2.0, 1),
        health: HealthMode::Oracle(Detector::default()),
        deadline_ms: Some(deadline_ms),
        pipeline_depth: 2,
        route: RoutePolicy::RoundRobin,
        decision_ms_override: Some(2.0),
        // Drop classification reads `dropped` and counts — stream the
        // completions instead of recording them.
        record_completions: false,
        speed_factors: Vec::new(),
        steal: false,
        event_queue: Default::default(),
        execution: Execution::Sequential,
        deployment: Default::default(),
    };
    let mut backends = vec![SyntheticBackend::uniform(4, 5.0, 1.0)];
    let mut failovers = vec![Failover::new(Objectives::default())];
    let requests = generate(400, Arrival::Poisson { rate_rps: 120.0 }, 16, seed);
    let inputs = HostTensor::zeros(vec![16, 4]);
    let plan = scenario_plan();
    let windows = outage_windows(&plan);
    let mut sink = EventBuffer::default();
    let report = serve_with_sink(
        &mut backends,
        &StaticMetrics,
        &mut failovers,
        &cfg,
        &requests,
        &inputs,
        &[plan],
        &mut sink,
    )?;
    let mut module = DropAttribution::new(windows);
    for ev in &sink.events {
        module.on_event(ev);
    }
    let point = DeadlinePoint {
        deadline_ms,
        completed: module.completed(),
        dropped_inside: module.dropped_inside(),
        dropped_outside: module.dropped_outside(),
        dropped_degraded: module.dropped_degraded(),
        p99_ms: module.p99_ms(),
    };
    Ok((point, report))
}

/// Run the sweep; prints the table and returns the JSON record.
pub fn sweep(seed: u64) -> Result<Json> {
    let mut t = Table::new(
        "drop attribution — deadline sweep (overlapping outage 500-920ms, poisson 120 rps)",
        &[
            "deadline ms",
            "completed",
            "drops inside",
            "drops outside",
            "degraded drops",
            "p99 ms",
        ],
    );
    let mut rows = Vec::new();
    for deadline_ms in [25.0, 50.0, 100.0, 200.0, 400.0] {
        let (p, _) = run_deadline(deadline_ms, seed)?;
        t.row(&[
            f(p.deadline_ms, 0),
            p.completed.to_string(),
            p.dropped_inside.to_string(),
            p.dropped_outside.to_string(),
            p.dropped_degraded.to_string(),
            f(p.p99_ms, 1),
        ]);
        rows.push(obj(&[
            ("deadline_ms", p.deadline_ms.into()),
            ("completed", p.completed.into()),
            ("dropped_inside", p.dropped_inside.into()),
            ("dropped_outside", p.dropped_outside.into()),
            ("dropped_degraded", p.dropped_degraded.into()),
            ("p99_ms", p.p99_ms.into()),
        ]));
    }
    t.print();
    println!(
        "reading: inside-window drops are the outage's fault; outside-window drops mean the \
         deadline is too tight for the offered load even on a healthy pipeline.\n"
    );
    Ok(obj(&[
        ("experiment", "drop_attribution".into()),
        ("seed", (seed as usize).into()),
        ("outage_windows", "500-900 (node 3) overlapping 520-920 (node 2)".into()),
        ("requests", 400usize.into()),
        ("arrival", "poisson 120 rps".into()),
        ("points", Json::Arr(rows)),
    ]))
}

/// Registry entry point: run and persist under the artifacts results dir.
pub fn run(ctx: &ExpContext) -> Result<()> {
    let out = sweep(ctx.config.seed)?;
    let path = ctx.save_result("drop_attribution", &out)?;
    println!("wrote {}", path.display());
    Ok(())
}

/// Artifact-free entry point (`continuer drop-attribution`).
pub fn run_standalone(seed: u64, out: Option<&str>, pretty: bool) -> Result<()> {
    let record = sweep(seed)?;
    crate::obs::emit::emit_json(&record, "drop_attribution.json", out, pretty)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outage_windows_merge_overlaps() {
        let w = outage_windows(&scenario_plan());
        assert_eq!(w.len(), 1, "{w:?}");
        assert!((w[0].0 - 500.0).abs() < 1e-9);
        assert!((w[0].1 - 920.0).abs() < 1e-9);
    }

    #[test]
    fn outage_windows_handle_open_and_disjoint() {
        let plan = FailurePlan::merge([
            FailurePlan::crash_recover(1, 100.0, 50.0),
            FailurePlan::crash(4, 1000.0),
        ]);
        let w = outage_windows(&plan);
        assert_eq!(w.len(), 2, "{w:?}");
        assert_eq!(w[0], (100.0, 150.0));
        assert!((w[1].0 - 1000.0).abs() < 1e-9);
        assert!(w[1].1.is_infinite());
        // Degraded windows are not outages.
        let g = outage_windows(&FailurePlan::degraded(2, 10.0, 3.0, 100.0));
        assert!(g.is_empty(), "{g:?}");
    }

    #[test]
    fn tight_deadline_drops_inside_the_outage() {
        let (p, report) = run_deadline(100.0, 11).unwrap();
        assert_eq!(p.completed + p.dropped_inside + p.dropped_outside, 400);
        assert!(
            p.dropped_inside > 0,
            "a 420 ms un-routable outage must strand 100 ms-deadline traffic: {report:?}"
        );
    }

    /// Acceptance criterion: the event-stream module reproduces the
    /// legacy classification recomputed from `ServiceReport::dropped`
    /// on the same seed, field for field.
    #[test]
    fn module_attribution_matches_legacy_classification() {
        use crate::obs::report::overlaps_outage;
        let (p, report) = run_deadline(100.0, 11).unwrap();
        let windows = outage_windows(&scenario_plan());
        let inside = report
            .dropped
            .iter()
            .filter(|d| overlaps_outage(d.arrival_ms, d.dropped_at_ms, &windows))
            .count();
        assert_eq!(p.completed, report.completed_count);
        assert_eq!(p.dropped_inside, inside);
        assert_eq!(p.dropped_outside, report.dropped.len() - inside);
        assert_eq!(p.dropped_degraded, report.degraded_drops());
        assert!(
            (p.p99_ms - report.latency.p99).abs() < 1e-9,
            "module p99 {} vs report p99 {}",
            p.p99_ms,
            report.latency.p99
        );
    }

    #[test]
    fn conservation_across_the_sweep() {
        for deadline in [25.0, 200.0] {
            let (p, _) = run_deadline(deadline, 11).unwrap();
            assert_eq!(
                p.completed + p.dropped_inside + p.dropped_outside,
                400,
                "deadline {deadline}"
            );
        }
    }
}
