//! Figures 2, 3, 4 and 6: architecture enumerations and the accuracy of
//! exit points / skip connections.

use anyhow::Result;

use crate::util::bench::{f, Table};

use super::ExpContext;

/// Fig. 2: partition points — how blocks map onto nodes.
pub fn fig2(ctx: &ExpContext) -> Result<()> {
    for name in ctx.model_names() {
        let m = ctx.store.model(&name)?;
        let mut t = Table::new(
            &format!("Fig 2 — partition points: {name} ({} nodes)", m.num_nodes),
            &["node", "in_shape", "out_shape", "layers", "kflops", "skippable"],
        );
        for n in &m.nodes {
            t.row(&[
                format!("n{}", n.index),
                format!("{:?}", n.in_shape),
                format!("{:?}", n.out_shape),
                n.layers.len().to_string(),
                (n.flops() / 1000).to_string(),
                if n.skippable { "yes".into() } else { "no".into() },
            ]);
        }
        t.print();
    }
    Ok(())
}

/// Fig. 3: exit-point placement.
pub fn fig3(ctx: &ExpContext) -> Result<()> {
    for name in ctx.model_names() {
        let m = ctx.store.model(&name)?;
        let mut t = Table::new(
            &format!("Fig 3 — exit points: {name} ({} exits)", m.exits.len()),
            &["exit", "after node", "input shape", "head layers"],
        );
        for e in &m.exits {
            t.row(&[
                format!("E{}", e.after_node),
                format!("n{}", e.after_node),
                format!("{:?}", e.in_shape),
                e.layers.len().to_string(),
            ]);
        }
        t.print();
    }
    Ok(())
}

/// Fig. 4: accuracy of each early exit point (build-time measured on the
/// full test set; paper Fig. 4).
pub fn fig4(ctx: &ExpContext) -> Result<()> {
    for name in ctx.model_names() {
        let m = ctx.store.model(&name)?;
        let mut t = Table::new(
            &format!("Fig 4 — early-exit accuracy: {name}"),
            &["exit", "accuracy %"],
        );
        for (&e, &acc) in &m.final_accuracy.exit {
            t.row(&[format!("E{e}"), f(acc * 100.0, 2)]);
        }
        t.row(&["full".into(), f(m.final_accuracy.repartition * 100.0, 2)]);
        t.print();
    }
    Ok(())
}

/// Fig. 6: accuracy of each skip connection; impossible positions (paper's
/// red stars) are reported as such.
pub fn fig6(ctx: &ExpContext) -> Result<()> {
    for name in ctx.model_names() {
        let m = ctx.store.model(&name)?;
        let mut t = Table::new(
            &format!("Fig 6 — skip-connection accuracy: {name}"),
            &["node skipped", "accuracy %"],
        );
        for n in &m.nodes {
            if n.index == 1 || n.index == m.num_nodes {
                continue;
            }
            match m.final_accuracy.skip.get(&n.index) {
                Some(&acc) => t.row(&[format!("n{}", n.index), f(acc * 100.0, 2)]),
                None => t.row(&[format!("n{}", n.index), "* (not possible)".into()]),
            }
        }
        t.row(&["none (full)".into(), f(m.final_accuracy.repartition * 100.0, 2)]);
        t.print();
    }
    Ok(())
}
