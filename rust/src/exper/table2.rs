//! Table II: quality (MSE, R²) of the per-layer-type latency prediction
//! models, for both platforms. Also persists the measured layer samples
//! (`results/layer_samples.json`) for reuse by Table V / Fig 7.

use anyhow::Result;

use crate::config::Platform;
use crate::coordinator::profiler::{fit_platform, LayerProfiler};
use crate::dnn::layers::{LayerKind, LayerSpec};
use crate::predict::{GbdtParams, LayerSample};
use crate::util::bench::{f, Table};
use crate::util::json::{obj, Json};

use super::ExpContext;

/// Serialize layer samples for the results cache.
fn samples_to_json(samples: &[LayerSample]) -> Json {
    Json::Arr(
        samples
            .iter()
            .map(|s| {
                obj(&[
                    ("kind", s.spec.kind.name().into()),
                    ("input_h", s.spec.input_h.into()),
                    ("input_w", s.spec.input_w.into()),
                    ("input_c", s.spec.input_c.into()),
                    ("kernel", s.spec.kernel.into()),
                    ("stride", s.spec.stride.into()),
                    ("filters", s.spec.filters.into()),
                    ("latency_ms", s.latency_ms.into()),
                ])
            })
            .collect(),
    )
}

fn samples_from_json(v: &Json) -> Result<Vec<LayerSample>> {
    v.as_arr()
        .ok_or_else(|| anyhow::anyhow!("bad samples json"))?
        .iter()
        .map(|s| {
            Ok(LayerSample {
                spec: LayerSpec::from_json(s)?,
                latency_ms: s
                    .get("latency_ms")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow::anyhow!("missing latency_ms"))?,
            })
        })
        .collect()
}

/// Measure (or load cached) platform-1 layer samples.
pub fn layer_samples(ctx: &ExpContext) -> Result<Vec<LayerSample>> {
    if ctx.has_result("layer_samples") {
        return samples_from_json(&ctx.load_result("layer_samples")?);
    }
    let profiler = LayerProfiler {
        engine: &ctx.engine,
        store: &ctx.store,
    };
    eprintln!(
        "profiling {} layer micro-benchmarks x {} reps ...",
        ctx.store.micro.len(),
        ctx.config.profile_reps
    );
    let samples = profiler.profile_micro(ctx.config.profile_reps)?;
    ctx.save_result("layer_samples", &samples_to_json(&samples))?;
    Ok(samples)
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    let samples = layer_samples(ctx)?;
    let params = GbdtParams::default();
    let mut platforms_json = Vec::new();
    for platform in [Platform::Host, Platform::platform2()] {
        let fitted = fit_platform(&samples, platform.clone(), &params, ctx.config.seed)?;
        let mut t = Table::new(
            &format!(
                "Table II — latency predictor quality ({})",
                platform.name()
            ),
            &["Layer Type", "n", "MSE", "R2"],
        );
        for q in &fitted.quality {
            t.row(&[
                q.kind.name().to_string(),
                (q.n_train + q.n_test).to_string(),
                f(q.mse, 4),
                f(q.r2, 3),
            ]);
        }
        t.print();
        // paper's headline: R2 close to 1 for nearly all layer types
        let good = fitted.quality.iter().filter(|q| q.r2 > 0.8).count();
        println!(
            "{}/{} layer types with R2 > 0.8 (MSE on log-latency scale)\n",
            good,
            fitted.quality.len()
        );
        let rows: Vec<Json> = fitted
            .quality
            .iter()
            .map(|q| {
                obj(&[
                    ("kind", q.kind.name().into()),
                    ("n", (q.n_train + q.n_test).into()),
                    ("mse", q.mse.into()),
                    ("r2", q.r2.into()),
                ])
            })
            .collect();
        platforms_json.push(obj(&[
            ("platform", platform.name().into()),
            ("quality", Json::Arr(rows)),
        ]));
    }
    let record = obj(&[
        ("experiment", "table2".into()),
        ("platforms", Json::Arr(platforms_json)),
    ]);
    let path = ctx.save_result("table2", &record)?;
    println!("wrote {}", path.display());
    let _ = LayerKind::ALL; // referenced for doc completeness
    Ok(())
}
