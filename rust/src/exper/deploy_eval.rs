//! Deployment-cost comparison: what repartitioning *actually* costs once
//! weight movement is modeled, against the techniques that need no
//! deployment at all.
//!
//! Four arms over the same 4-stage pipeline, failure schedule and
//! request stream:
//!
//! - **repartition-bbm** — always repartition, break-before-make: the
//!   replica stalls while the re-hosted block's weights transfer and
//!   warm up, so the deployment window is pure downtime.
//! - **repartition-mbb** — always repartition, make-before-break: a
//!   repartition-free fallback keeps serving through the window and the
//!   cut-over is atomic, so the same transfer+warm-up span costs zero
//!   stall and drops nothing.
//! - **early-exit** / **skip** — the techniques that never move weights,
//!   as the no-deployment reference points.
//!
//! Fully synthetic (no artifacts), deterministic for a given seed, and
//! asserted in tests: make-before-break total downtime is strictly below
//! break-before-make, with zero requests dropped at cut-over.

use anyhow::Result;

use crate::baselines::{AlwaysEarlyExit, AlwaysRepartition, AlwaysSkip, RecoveryPolicy};
use crate::cluster::failure::FailurePlan;
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::engine::{
    serve, DeploymentConfig, EngineConfig, Execution, HealthMode, SyntheticBackend,
};
use crate::coordinator::estimator::MetricsSource;
use crate::coordinator::failover::Failover;
use crate::coordinator::router::RoutePolicy;
use crate::coordinator::scheduler::CandidateMetrics;
use crate::coordinator::service::{DeployMode, ServiceReport};
use crate::dnn::variants::Technique;
use crate::runtime::HostTensor;
use crate::util::bench::{f, Table};
use crate::util::json::{obj, Json};
use crate::workload::{generate, Arrival};

use super::ExpContext;

/// Shared scenario: node 3 of a 4-stage chain crashes mid-stream.
const NODES: usize = 4;
const CRASH_NODE: usize = 3;
const CRASH_AT_MS: f64 = 200.0;
const N_REQUESTS: usize = 400;
const RATE_RPS: f64 = 150.0;
/// 2 MB of weights per node over a 50 kB/ms deployment link: 40 ms to
/// re-host the failed node's block, plus warm-up below.
const WEIGHT_BYTES: usize = 2_000_000;
const DEPLOY_BYTES_PER_MS: f64 = 50_000.0;
const WARMUP_MS: f64 = 10.0;

/// Three-candidate metrics so selection (and the make-before-break
/// fallback) sees the full technique menu for the crash.
struct DeployEvalMetrics;

impl MetricsSource for DeployEvalMetrics {
    fn candidate_metrics(&self, failed: usize) -> Result<Vec<CandidateMetrics>> {
        Ok(vec![
            CandidateMetrics {
                technique: Technique::Repartition,
                accuracy: 90.0,
                latency_ms: 30.0,
                downtime_ms: 4.0,
            },
            CandidateMetrics {
                technique: Technique::EarlyExit(failed.saturating_sub(1).max(1)),
                accuracy: 70.0,
                latency_ms: 8.0,
                downtime_ms: 1.0,
            },
            CandidateMetrics {
                technique: Technique::SkipConnection(failed),
                accuracy: 85.0,
                latency_ms: 25.0,
                downtime_ms: 3.0,
            },
        ])
    }

    fn reinstate_ms(&self) -> f64 {
        1.0
    }
}

/// One arm's outcome.
pub struct Arm {
    pub label: &'static str,
    pub technique: String,
    pub deploy_mode: &'static str,
    /// Decision downtime from the failover windows, ms.
    pub decision_downtime_ms: f64,
    /// Dispatch stall from break-before-make deployments, ms.
    pub deploy_stall_ms: f64,
    /// Decision downtime + deployment stall: the comparison headline.
    pub total_downtime_ms: f64,
    pub deployments: usize,
    pub transfer_ms: f64,
    pub warmup_ms: f64,
    pub completed: usize,
    pub dropped: usize,
    pub p99_ms: f64,
    pub throughput_rps: f64,
}

fn run_arm(
    label: &'static str,
    policy: Box<dyn RecoveryPolicy>,
    mode: DeployMode,
    seed: u64,
) -> Result<(Arm, ServiceReport)> {
    let cfg = EngineConfig {
        batcher: BatcherConfig::new(vec![1], 2.0, 1),
        health: HealthMode::Oracle(Default::default()),
        // No deadline: conservation is exact, so the make-before-break
        // zero-drop claim is a property of the cut-over, not of luck.
        deadline_ms: None,
        pipeline_depth: 2,
        route: RoutePolicy::RoundRobin,
        decision_ms_override: Some(2.0),
        record_completions: false,
        speed_factors: Vec::new(),
        steal: false,
        event_queue: Default::default(),
        execution: Execution::Sequential,
        deployment: DeploymentConfig {
            mode,
            warmup_ms: WARMUP_MS,
        },
    };
    let backend = SyntheticBackend::uniform(NODES, 5.0, 1.0).with_deployment(
        vec![WEIGHT_BYTES; NODES + 1],
        DEPLOY_BYTES_PER_MS,
    );
    let mut backends = vec![backend];
    let mut failovers = vec![Failover::with_policy(policy)];
    let requests = generate(N_REQUESTS, Arrival::Poisson { rate_rps: RATE_RPS }, 16, seed);
    let inputs = HostTensor::zeros(vec![16, 4]);
    let report = serve(
        &mut backends,
        &DeployEvalMetrics,
        &mut failovers,
        &cfg,
        &requests,
        &inputs,
        &[FailurePlan::crash(CRASH_NODE, CRASH_AT_MS)],
    )?;
    let decision = report.total_downtime_ms();
    let stall = report.deploy_stall_ms();
    let arm = Arm {
        label,
        technique: report
            .failovers
            .first()
            .map(|w| w.technique.kind_name().to_string())
            .unwrap_or_else(|| "-".into()),
        deploy_mode: mode.as_str(),
        decision_downtime_ms: decision,
        deploy_stall_ms: stall,
        total_downtime_ms: decision + stall,
        deployments: report.deploy_windows.len(),
        transfer_ms: report
            .deploy_windows
            .iter()
            .map(|w| w.transfer_ms)
            .fold(0.0, f64::max),
        warmup_ms: report
            .deploy_windows
            .iter()
            .map(|w| w.warmup_ms)
            .fold(0.0, f64::max),
        completed: report.completed_count,
        dropped: report.dropped.len(),
        p99_ms: report.latency.p99,
        throughput_rps: report.throughput_rps,
    };
    Ok((arm, report))
}

fn arms(seed: u64) -> Result<Vec<(Arm, ServiceReport)>> {
    Ok(vec![
        run_arm(
            "repartition-bbm",
            Box::new(AlwaysRepartition),
            DeployMode::BreakBeforeMake,
            seed,
        )?,
        run_arm(
            "repartition-mbb",
            Box::new(AlwaysRepartition),
            DeployMode::MakeBeforeBreak,
            seed,
        )?,
        run_arm(
            "early-exit",
            Box::new(AlwaysEarlyExit),
            DeployMode::Instantaneous,
            seed,
        )?,
        run_arm(
            "skip",
            Box::new(AlwaysSkip),
            DeployMode::Instantaneous,
            seed,
        )?,
    ])
}

/// Run the comparison; prints the table and returns the JSON record.
pub fn compare(seed: u64) -> Result<Json> {
    let results = arms(seed)?;
    let mut t = Table::new(
        "deployment cost — repartition BBM vs MBB vs deployment-free techniques (crash @200ms)",
        &[
            "arm",
            "technique",
            "deploy mode",
            "decision ms",
            "stall ms",
            "total ms",
            "deploys",
            "dropped",
            "p99 ms",
            "rps",
        ],
    );
    let mut rows = Vec::new();
    for (a, _) in &results {
        t.row(&[
            a.label.to_string(),
            a.technique.clone(),
            a.deploy_mode.to_string(),
            f(a.decision_downtime_ms, 2),
            f(a.deploy_stall_ms, 2),
            f(a.total_downtime_ms, 2),
            a.deployments.to_string(),
            a.dropped.to_string(),
            f(a.p99_ms, 1),
            f(a.throughput_rps, 1),
        ]);
        rows.push(obj(&[
            ("arm", a.label.into()),
            ("technique", a.technique.clone().into()),
            ("deploy_mode", a.deploy_mode.into()),
            ("decision_downtime_ms", a.decision_downtime_ms.into()),
            ("deploy_stall_ms", a.deploy_stall_ms.into()),
            ("total_downtime_ms", a.total_downtime_ms.into()),
            ("deployments", a.deployments.into()),
            ("transfer_ms", a.transfer_ms.into()),
            ("warmup_ms", a.warmup_ms.into()),
            ("completed", a.completed.into()),
            ("dropped", a.dropped.into()),
            ("p99_ms", a.p99_ms.into()),
            ("throughput_rps", a.throughput_rps.into()),
        ]));
    }
    t.print();
    println!(
        "reading: both repartition arms pay the same modeled transfer+warm-up span; \
         break-before-make pays it as stall while make-before-break hides it behind a \
         fallback and cuts over atomically (zero drops at cut-over).\n"
    );
    Ok(obj(&[
        ("experiment", "deploy_eval".into()),
        ("seed", (seed as usize).into()),
        ("crash_node", CRASH_NODE.into()),
        ("crash_at_ms", CRASH_AT_MS.into()),
        ("requests", N_REQUESTS.into()),
        ("arrival", format!("poisson {RATE_RPS} rps").into()),
        ("weight_bytes_per_node", WEIGHT_BYTES.into()),
        ("deploy_bytes_per_ms", DEPLOY_BYTES_PER_MS.into()),
        ("warmup_ms", WARMUP_MS.into()),
        ("arms", Json::Arr(rows)),
    ]))
}

/// Registry entry point: run and persist under the artifacts results dir.
pub fn run(ctx: &ExpContext) -> Result<()> {
    let out = compare(ctx.config.seed)?;
    let path = ctx.save_result("deploy_eval", &out)?;
    println!("wrote {}", path.display());
    Ok(())
}

/// Artifact-free entry point (`continuer deploy-eval`): write the JSON
/// next to the working directory (or `--out`).
pub fn run_standalone(seed: u64, out: Option<&str>, pretty: bool) -> Result<()> {
    let record = compare(seed)?;
    crate::obs::emit::emit_json(&record, "deploy_eval.json", out, pretty)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mbb_downtime_strictly_below_bbm_with_zero_drops() {
        let results = arms(7).unwrap();
        let bbm = &results[0].0;
        let mbb = &results[1].0;
        assert_eq!(bbm.technique, "repartition");
        assert_eq!(mbb.technique, "repartition");
        assert_eq!(bbm.deployments, 1);
        assert_eq!(mbb.deployments, 1);
        // Identical modeled span, radically different cost: the BBM arm
        // stalls for transfer + warm-up, the MBB arm for nothing.
        assert!(
            bbm.deploy_stall_ms > 0.0,
            "break-before-make must stall: {}",
            bbm.deploy_stall_ms
        );
        assert_eq!(mbb.deploy_stall_ms, 0.0);
        assert!(
            mbb.total_downtime_ms < bbm.total_downtime_ms,
            "make-before-break must beat break-before-make: {} vs {}",
            mbb.total_downtime_ms,
            bbm.total_downtime_ms
        );
        // No deadline: nothing may drop anywhere, in particular nothing
        // at the make-before-break cut-over.
        assert_eq!(mbb.dropped, 0);
        assert_eq!(mbb.completed, N_REQUESTS);
    }

    #[test]
    fn bbm_stall_equals_modeled_transfer_plus_warmup() {
        let results = arms(7).unwrap();
        let (bbm, report) = &results[0];
        let expected = WEIGHT_BYTES as f64 / DEPLOY_BYTES_PER_MS + WARMUP_MS;
        assert!(
            (bbm.deploy_stall_ms - expected).abs() < 1e-9,
            "stall {} != modeled span {}",
            bbm.deploy_stall_ms,
            expected
        );
        let w = &report.deploy_windows[0];
        assert!(w.completed);
        assert!(w.fallback.is_none());
        assert!((w.duration_ms() - expected).abs() < 1e-9);
    }

    #[test]
    fn deployment_free_arms_deploy_nothing() {
        let results = arms(7).unwrap();
        for (a, report) in &results[2..] {
            assert_eq!(a.deployments, 0, "{} must not deploy", a.label);
            assert_eq!(a.deploy_stall_ms, 0.0);
            assert!(report.deploy_windows.is_empty());
        }
    }

    #[test]
    fn emits_all_four_arms() {
        let out = compare(7).unwrap();
        match out.get("arms") {
            Some(Json::Arr(rows)) => assert_eq!(rows.len(), 4),
            other => panic!("arms array missing: {other:?}"),
        }
    }
}
