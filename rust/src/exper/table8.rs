//! Table VIII: downtime incurred when selecting a technique — the measured
//! time to retrieve both model estimates and run the Scheduler, plus the
//! 0.99 ms reinstate constant for repartition / skip. Reported as the
//! maximum over failure cases, like the paper's "within 16.82 ms".

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use crate::cluster::link::LinkModel;
use crate::coordinator::estimator::Estimator;
use crate::coordinator::profiler::DowntimeTable;
use crate::coordinator::scheduler::select;
use crate::dnn::variants::{candidates, failure_sweep};
use crate::predict::{AccuracyModel, GbdtParams};
use crate::util::bench::{f, Table};
use crate::util::json::{obj, Json};

use super::table2::layer_samples;
use super::ExpContext;

pub fn run(ctx: &ExpContext) -> Result<()> {
    let samples = layer_samples(ctx)?;
    let params = GbdtParams::default();
    let (lat_model, _) =
        crate::predict::LatencyModel::fit(&samples, &params, ctx.config.seed)?;
    let metas: Vec<&crate::dnn::model::ModelMeta> = ctx.store.models.values().collect();
    let (acc_model, _) = AccuracyModel::fit(&metas, &params, ctx.config.seed)?;
    let link = LinkModel::new(ctx.config.link.clone());
    let downtime = DowntimeTable::new();

    let mut t = Table::new(
        "Table VIII — downtime when selecting a technique (ms, max over failures)",
        &["Technique", "resnet32", "mobilenetv2"],
    );
    let mut per_model: BTreeMap<(&str, String), f64> = BTreeMap::new();

    for name in ctx.model_names() {
        let meta = ctx.store.model(&name)?;
        let est = Estimator::new(
        meta,
        &lat_model,
        &acc_model,
        &link,
        &downtime,
        ctx.config.reinstate_ms,
    );
        for failed in failure_sweep(meta) {
            let cands = candidates(meta, failed);
            // Per-technique prediction cost.
            for tech in &cands {
                let t0 = Instant::now();
                let _a = est.predict_accuracy(*tech)?;
                let _l = est.predict_latency_ms(*tech, Some(failed));
                let predict_ms = t0.elapsed().as_secs_f64() * 1e3;

                // Selection cost over the full candidate set.
                let metrics = est.candidate_metrics(failed)?;
                let t1 = Instant::now();
                let _ = select(&metrics, &ctx.config.objectives)?;
                let select_ms = t1.elapsed().as_secs_f64() * 1e3;

                let reinstate = match tech.kind_name() {
                    "early-exit" => 0.0,
                    _ => ctx.config.reinstate_ms,
                };
                let total = predict_ms + select_ms + reinstate;
                let key = (tech.kind_name(), name.clone());
                let cur = per_model.entry(key).or_insert(0.0);
                *cur = cur.max(total);
            }
        }
    }
    let mut cells_json = Vec::new();
    for kind in ["repartition", "early-exit", "skip-connection"] {
        let mut cells = vec![kind.to_string()];
        for name in ["resnet32", "mobilenetv2"] {
            let v = per_model.get(&(kind, name.to_string()));
            cells.push(v.map(|v| f(*v, 2)).unwrap_or_else(|| "-".into()));
            cells_json.push(obj(&[
                ("technique", kind.into()),
                ("model", name.into()),
                ("downtime_ms", v.map_or(Json::Null, |v| (*v).into())),
            ]));
        }
        t.row(&cells);
    }
    t.print();
    let overall = per_model.values().cloned().fold(0.0, f64::max);
    println!("CONTINUER selects a technique within {overall:.2} ms of a node failure\n");
    let record = obj(&[
        ("experiment", "table8".into()),
        ("overall_max_ms", overall.into()),
        ("cells", Json::Arr(cells_json)),
    ]);
    let path = ctx.save_result("table8", &record)?;
    println!("wrote {}", path.display());
    Ok(())
}
