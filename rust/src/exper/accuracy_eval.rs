//! Table VI + Fig 8: measured vs predicted accuracy per failed node and
//! technique.
//!
//! Measured: the real per-block pipeline executed in rust over the eval
//! set (batch 32) — a genuine end-to-end measurement through the AOT
//! artifacts, independently of the python-side numbers.
//! Predicted: the Accuracy Prediction Model on the deployed weights'
//! statistics.
//!
//! Persists `results/accuracy_eval.json` for Table VII.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::cluster::sim::EdgeCluster;
use crate::coordinator::estimator::Estimator;
use crate::coordinator::profiler::DowntimeTable;
use crate::dnn::variants::{candidates, failure_sweep, Technique};
use crate::predict::{AccuracyModel, GbdtParams, LatencyModel, LayerSample};
use crate::util::bench::{f, pct, Table};
use crate::util::json::{obj, Json};
use crate::util::stats::avg_pct_error;

use super::latency_eval::tech_from_json;
use super::ExpContext;

#[derive(Debug, Clone)]
pub struct AccuracyPoint {
    pub model: String,
    pub failed: usize,
    pub technique: Technique,
    /// percent
    pub measured: f64,
    /// percent
    pub predicted: f64,
}

fn to_json(points: &[AccuracyPoint]) -> Json {
    Json::Arr(
        points
            .iter()
            .map(|p| {
                obj(&[
                    ("model", p.model.as_str().into()),
                    ("failed", p.failed.into()),
                    (
                        "technique",
                        obj(&[
                            ("kind", p.technique.kind_name().into()),
                            (
                                "index",
                                match p.technique {
                                    Technique::Repartition => 0usize.into(),
                                    Technique::EarlyExit(e) => e.into(),
                                    Technique::SkipConnection(k) => k.into(),
                                },
                            ),
                        ]),
                    ),
                    ("measured", p.measured.into()),
                    ("predicted", p.predicted.into()),
                ])
            })
            .collect(),
    )
}

pub fn points_from_json(v: &Json) -> Result<Vec<AccuracyPoint>> {
    v.as_arr()
        .ok_or_else(|| anyhow::anyhow!("bad accuracy points"))?
        .iter()
        .map(|p| {
            Ok(AccuracyPoint {
                model: p
                    .get("model")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
                failed: p.get("failed").and_then(Json::as_usize).unwrap_or(0),
                technique: tech_from_json(
                    p.get("technique")
                        .ok_or_else(|| anyhow::anyhow!("missing technique"))?,
                )?,
                measured: p.get("measured").and_then(Json::as_f64).unwrap_or(0.0),
                predicted: p.get("predicted").and_then(Json::as_f64).unwrap_or(0.0),
            })
        })
        .collect()
}

/// Compute (or load cached) every accuracy point.
pub fn evaluate(ctx: &ExpContext) -> Result<Vec<AccuracyPoint>> {
    if ctx.has_result("accuracy_eval") {
        return points_from_json(&ctx.load_result("accuracy_eval")?);
    }
    let params = GbdtParams::default();
    let metas: Vec<&crate::dnn::model::ModelMeta> = ctx.store.models.values().collect();
    let (acc_model, quality) = AccuracyModel::fit(&metas, &params, ctx.config.seed)?;
    println!(
        "accuracy model: {} train / {} test instances, MSE = {:.3}, R2 = {:.2}%",
        quality.n_train,
        quality.n_test,
        quality.mse,
        quality.r2 * 100.0
    );
    // Latency model irrelevant here; build a trivial one.
    let dummy_samples = vec![LayerSample {
        spec: crate::dnn::layers::LayerSpec {
            kind: crate::dnn::layers::LayerKind::Relu,
            input_h: 1,
            input_w: 1,
            input_c: 1,
            kernel: 0,
            stride: 0,
            filters: 0,
        },
        latency_ms: 0.01,
    }];
    let (lat_model, _) = LatencyModel::fit(&dummy_samples, &params, 0)?;
    let downtime = DowntimeTable::new();

    let mut points = Vec::new();
    let eval_batch = 32;
    for name in ctx.model_names() {
        let meta = ctx.store.model(&name)?;
        let cluster = EdgeCluster::new(
            &ctx.engine,
            &ctx.store,
            meta,
            ctx.config.link.clone(),
            ctx.config.seed,
        );
        let est = Estimator::new(
        meta,
        &lat_model,
        &acc_model,
        cluster.link(),
        &downtime,
        ctx.config.reinstate_ms,
    );
        let (images, labels) = ctx.store.test_set()?;
        // Measured accuracy depends only on the technique (not which node
        // triggered it): memoise per technique.
        let mut measured_cache: BTreeMap<String, f64> = BTreeMap::new();
        eprintln!("[accuracy_eval] {name}: evaluating techniques on {} images ...", images.shape[0]);
        for failed in failure_sweep(meta) {
            for tech in candidates(meta, failed) {
                let key = tech.label();
                let measured = match measured_cache.get(&key) {
                    Some(&m) => m,
                    None => {
                        let m = cluster.measure_accuracy(
                            tech,
                            Some(failed),
                            &images,
                            &labels,
                            eval_batch,
                        )? * 100.0;
                        measured_cache.insert(key, m);
                        m
                    }
                };
                let predicted = est.predict_accuracy(tech)?;
                points.push(AccuracyPoint {
                    model: name.clone(),
                    failed,
                    technique: tech,
                    measured,
                    predicted,
                });
            }
        }
    }
    ctx.save_result("accuracy_eval", &to_json(&points))?;
    Ok(points)
}

pub fn run(ctx: &ExpContext, fig8: bool) -> Result<()> {
    let points = evaluate(ctx)?;

    if fig8 {
        for name in ctx.model_names() {
            let mut t = Table::new(
                &format!("Fig 8 — measured vs predicted accuracy ({name})"),
                &["failed node", "technique", "measured %", "predicted %"],
            );
            for p in points.iter().filter(|p| p.model == name) {
                t.row(&[
                    format!("n{}", p.failed),
                    p.technique.label(),
                    f(p.measured, 2),
                    f(p.predicted, 2),
                ]);
            }
            t.print();
        }
    }

    let mut t = Table::new(
        "Table VI — avg % error of accuracy estimation",
        &["Technique", "resnet32", "mobilenetv2"],
    );
    for kind in ["repartition", "early-exit", "skip-connection"] {
        let mut cells = vec![kind.to_string()];
        for name in ["resnet32", "mobilenetv2"] {
            let (pred, meas): (Vec<f64>, Vec<f64>) = points
                .iter()
                .filter(|p| p.model == name && p.technique.kind_name() == kind)
                .map(|p| (p.predicted, p.measured))
                .unzip();
            cells.push(if pred.is_empty() {
                "-".into()
            } else {
                pct(avg_pct_error(&pred, &meas), 2)
            });
        }
        t.row(&cells);
    }
    t.print();
    Ok(())
}
