//! End-to-end serving driver (the mandated full-system validation): load a
//! model, serve a poisson request stream through the distributed pipeline
//! via the event-driven engine, inject a node failure mid-run, let
//! CONTINUER fail over, and report latency / throughput / downtime before
//! vs after. Supports replica sharding (`replicas`) and stage-level
//! pipelining (`pipeline_depth`); the defaults reproduce the paper's
//! single-pipeline, one-batch-in-flight deployment.

use anyhow::Result;

use crate::cluster::failure::{Detector, FailurePlan};
use crate::cluster::sim::EdgeCluster;
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::engine::{
    serve_sequential_with_sink, EngineConfig, Execution, HealthMode,
};
use crate::coordinator::estimator::Estimator;
use crate::coordinator::failover::Failover;
use crate::coordinator::profiler::DowntimeTable;
use crate::coordinator::router::RoutePolicy;
use crate::coordinator::service::{ServiceConfig, ServiceReport};
use crate::health::HealthConfig;
use crate::obs::report::{replay, EventCounts, ReportModule};
use crate::obs::{EngineEvent, EventBuffer, EventSink, NoopSink};
use crate::predict::{AccuracyModel, GbdtParams};
use crate::util::bench::{f, Table};
use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::workload::{generate, Arrival};

use super::table2::layer_samples;
use super::ExpContext;

pub struct E2eParams {
    pub model: String,
    pub n_requests: usize,
    pub rate_rps: f64,
    /// Node that fails (on replica 0; other replicas keep serving).
    pub fail_node: usize,
    pub fail_at_ms: f64,
    /// Number of independent pipeline replicas (1 = the paper's setup).
    pub replicas: usize,
    /// Max batches in flight per replica (1 = no pipelining).
    pub pipeline_depth: usize,
    /// Detect through the simulated heartbeat monitor (phi-accrual,
    /// false positives, quarantine) instead of the oracle detector.
    pub monitored: bool,
}

impl E2eParams {
    /// The seed deployment: one replica, one batch in flight.
    pub fn single(model: String, n_requests: usize, rate_rps: f64, fail_node: usize, fail_at_ms: f64) -> E2eParams {
        E2eParams {
            model,
            n_requests,
            rate_rps,
            fail_node,
            fail_at_ms,
            replicas: 1,
            pipeline_depth: 1,
            monitored: false,
        }
    }
}

pub fn run_e2e(ctx: &ExpContext, p: &E2eParams) -> Result<ServiceReport> {
    run_e2e_with_sink(ctx, p, &mut NoopSink)
}

/// [`run_e2e`] with the engine's observability stream recorded. The sink
/// never influences scheduling, so the report is identical to an
/// unrecorded run; the buffered events feed smoke summaries like the
/// per-kind counts `continuer serve` prints.
pub fn run_e2e_recorded(
    ctx: &ExpContext,
    p: &E2eParams,
) -> Result<(ServiceReport, Vec<EngineEvent>)> {
    let mut sink = EventBuffer::default();
    let report = run_e2e_with_sink(ctx, p, &mut sink)?;
    Ok((report, sink.take_events()))
}

fn run_e2e_with_sink<S: EventSink>(
    ctx: &ExpContext,
    p: &E2eParams,
    sink: &mut S,
) -> Result<ServiceReport> {
    anyhow::ensure!(p.replicas >= 1, "need >= 1 replica");
    let meta = ctx.store.model(&p.model)?;
    let samples = layer_samples(ctx)?;
    let params = GbdtParams::default();
    let (lat_model, _) = crate::predict::LatencyModel::fit(&samples, &params, ctx.config.seed)?;
    let metas: Vec<&crate::dnn::model::ModelMeta> = ctx.store.models.values().collect();
    let (acc_model, _) = AccuracyModel::fit(&metas, &params, ctx.config.seed)?;
    let downtime = DowntimeTable::new();

    let mut clusters: Vec<EdgeCluster> = (0..p.replicas)
        .map(|r| {
            EdgeCluster::new(
                &ctx.engine,
                &ctx.store,
                meta,
                ctx.config.link.clone(),
                ctx.config.seed ^ r as u64,
            )
        })
        .collect();
    eprintln!(
        "[e2e] preloading {} blocks x {} replica(s) ...",
        meta.num_nodes, p.replicas
    );
    for c in &clusters {
        c.preload(1, true)?;
    }

    let link = crate::cluster::link::LinkModel::new(ctx.config.link.clone());
    let est = Estimator::new(
        meta,
        &lat_model,
        &acc_model,
        &link,
        &downtime,
        ctx.config.reinstate_ms,
    );
    let mut failovers: Vec<Failover> = (0..p.replicas)
        .map(|_| Failover::new(ctx.config.objectives.clone()))
        .collect();
    let (images, _) = ctx.store.test_set()?;
    let requests = generate(
        p.n_requests,
        Arrival::Poisson { rate_rps: p.rate_rps },
        images.shape[0],
        ctx.config.seed,
    );
    // The failure hits replica 0; the remaining replicas stay healthy.
    let mut plans = vec![FailurePlan::crash(p.fail_node, p.fail_at_ms)];
    plans.extend((1..p.replicas).map(|_| FailurePlan::none()));
    let batcher = BatcherConfig::new(
        ctx.store.batch_sizes.clone(),
        ctx.config.batch_timeout_ms,
        ctx.config.max_batch,
    );
    eprintln!(
        "[e2e] serving {} requests at {} rps over {} replica(s) (depth {}); node {} fails at t={} ms",
        p.n_requests, p.rate_rps, p.replicas, p.pipeline_depth, p.fail_node, p.fail_at_ms
    );
    if p.replicas == 1 && p.pipeline_depth == 1 && !p.monitored {
        // The paper's deployment uses the seed-compatible single-pipeline
        // configuration (`ServiceConfig::engine_config`, exactly what
        // `service::run` drives) — gone through the sink-aware entry
        // point so recorded runs stay byte-identical to unrecorded ones.
        let scfg = ServiceConfig {
            batcher,
            detector: Detector::default(),
            deadline_ms: None,
        };
        return serve_sequential_with_sink(
            std::slice::from_mut(&mut clusters[0]),
            &est,
            std::slice::from_mut(&mut failovers[0]),
            &scfg.engine_config(),
            &requests,
            &images,
            std::slice::from_ref(&plans[0]),
            sink,
        );
    }
    let health = if p.monitored {
        HealthMode::Monitored(HealthConfig {
            seed: ctx.config.seed,
            ..HealthConfig::default()
        })
    } else {
        HealthMode::Oracle(Detector::default())
    };
    let cfg = EngineConfig {
        batcher,
        health,
        deadline_ms: None,
        pipeline_depth: p.pipeline_depth,
        route: RoutePolicy::JoinShortestQueue,
        decision_ms_override: None,
        // The report splits healthy vs degraded completions below, so
        // keep exact per-request records.
        record_completions: true,
        speed_factors: Vec::new(),
        steal: false,
        event_queue: Default::default(),
        // PJRT clusters hold RefCell caches and cannot cross threads.
        execution: Execution::Sequential,
        deployment: Default::default(),
    };
    serve_sequential_with_sink(
        &mut clusters,
        &est,
        &mut failovers,
        &cfg,
        &requests,
        &images,
        &plans,
        sink,
    )
}

pub fn print_report(p: &E2eParams, report: &ServiceReport) {
    let mut t = Table::new(
        &format!("E2E serving report — {}", p.model),
        &["metric", "value"],
    );
    t.row(&["requests completed".into(), report.completed_count.to_string()]);
    t.row(&[
        "requests dropped".into(),
        format!(
            "{} ({} while degraded)",
            report.dropped_count(),
            report.degraded_drops()
        ),
    ]);
    t.row(&["replicas / depth".into(), format!("{} / {}", p.replicas, p.pipeline_depth)]);
    t.row(&["peak batches in flight".into(), report.max_in_flight.to_string()]);
    t.row(&["throughput (rps)".into(), f(report.throughput_rps, 1)]);
    t.row(&["latency mean (ms)".into(), f(report.latency.mean, 2)]);
    t.row(&["latency p50 (ms)".into(), f(report.latency.p50, 2)]);
    t.row(&["latency p95 (ms)".into(), f(report.latency.p95, 2)]);
    t.row(&["latency p99 (ms)".into(), f(report.latency.p99, 2)]);
    t.row(&["sim span (ms)".into(), f(report.sim_span_ms, 0)]);
    for w in &report.failovers {
        t.row(&[
            "failover".into(),
            format!(
                "replica {} node {} t={:.1}ms downtime={:.2}ms -> {}{}",
                w.replica,
                w.node,
                w.start_ms,
                w.downtime_ms(),
                w.technique.label(),
                if w.false_positive { " (false positive)" } else { "" }
            ),
        ]);
    }
    if report.false_failovers() > 0 {
        t.row(&[
            "false failovers".into(),
            report.false_failovers().to_string(),
        ]);
    }
    for d in report.dropped.iter().take(5) {
        t.row(&[
            "dropped".into(),
            format!(
                "req {} (arrived {:.1}ms, {} mode)",
                d.id,
                d.arrival_ms,
                if d.degraded { "degraded" } else { "healthy" }
            ),
        ]);
    }
    t.print();

    // Before/after failure latency comparison.
    if let Some(w) = report.failovers.first() {
        let fail_t = w.start_ms;
        let before: Vec<f64> = report
            .completed
            .iter()
            .filter(|c| c.technique.is_none())
            .map(|c| c.latency_ms)
            .collect();
        let after: Vec<f64> = report
            .completed
            .iter()
            .filter(|c| c.technique.is_some())
            .map(|c| c.latency_ms)
            .collect();
        let b = Summary::of(&before);
        let a = Summary::of(&after);
        println!(
            "healthy (t<{fail_t:.0}ms or surviving replicas): n={} mean={:.2}ms | degraded: n={} mean={:.2}ms\n",
            b.n, b.mean, a.n, a.mean
        );
    }
}

pub fn run_default(ctx: &ExpContext) -> Result<()> {
    run_n(ctx, 60)
}

/// Like [`run_default`] but with the request count taken from the CLI
/// (`continuer serve --requests N`), so large request scales — up to the
/// million-request configuration — are reproducible end to end. Note the
/// e2e report keeps exact per-request records for its healthy/degraded
/// split (`record_completions` on, memory linear in N); the O(1)-memory
/// streaming regime at scale is exercised by `benches/engine_scale.rs`.
pub fn run_n(ctx: &ExpContext, n_requests: usize) -> Result<()> {
    let model = ctx.config.model.clone();
    let meta = ctx.store.model(&model)?;
    // Fail a mid-pipeline skippable node so all three techniques compete.
    let fail_node = meta
        .skippable_nodes
        .get(meta.skippable_nodes.len() / 2)
        .copied()
        .unwrap_or(meta.num_nodes / 2);
    let p = E2eParams::single(model, n_requests, 6.0, fail_node, 4000.0);
    let (report, events) = run_e2e_recorded(ctx, &p)?;
    print_report(&p, &report);
    // Live smoke signal: per-kind event counts over the recorded stream,
    // deployment events included — a scenario that promises a failover
    // (or a deployment) and produces zero such events fails loudly here.
    let mut modules: Vec<Box<dyn ReportModule>> = vec![Box::new(EventCounts::new())];
    let counts = replay(&events, &mut modules);
    if let Some(c) = counts.get("event_counts") {
        println!("event counts: {}", c.to_string());
    }
    for key in ["deploy_start", "transfer_done", "warmup_done", "cutover"] {
        if let Some(n) = counts.path(&format!("event_counts.{key}")).and_then(Json::as_usize) {
            println!("deployment: {key} x{n}");
        }
    }
    Ok(())
}
