//! End-to-end serving driver (the mandated full-system validation): load a
//! model, serve a poisson request stream through the distributed pipeline,
//! inject a node failure mid-run, let CONTINUER fail over, and report
//! latency / throughput / downtime before vs after.

use anyhow::Result;

use crate::cluster::failure::{Detector, FailurePlan};
use crate::cluster::sim::EdgeCluster;
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::estimator::Estimator;
use crate::coordinator::failover::Failover;
use crate::coordinator::profiler::DowntimeTable;
use crate::coordinator::service::{run as serve, ServiceConfig, ServiceReport};
use crate::predict::{AccuracyModel, GbdtParams};
use crate::util::bench::{f, Table};
use crate::util::stats::Summary;
use crate::workload::{generate, Arrival};

use super::table2::layer_samples;
use super::ExpContext;

pub struct E2eParams {
    pub model: String,
    pub n_requests: usize,
    pub rate_rps: f64,
    pub fail_node: usize,
    pub fail_at_ms: f64,
}

pub fn run_e2e(ctx: &ExpContext, p: &E2eParams) -> Result<ServiceReport> {
    let meta = ctx.store.model(&p.model)?;
    let samples = layer_samples(ctx)?;
    let params = GbdtParams::default();
    let (lat_model, _) = crate::predict::LatencyModel::fit(&samples, &params, ctx.config.seed)?;
    let metas: Vec<&crate::dnn::model::ModelMeta> = ctx.store.models.values().collect();
    let (acc_model, _) = AccuracyModel::fit(&metas, &params, ctx.config.seed)?;
    let downtime = DowntimeTable::new();

    let mut cluster = EdgeCluster::new(
        &ctx.engine,
        &ctx.store,
        meta,
        ctx.config.link.clone(),
        ctx.config.seed,
    );
    eprintln!("[e2e] preloading {} blocks ...", meta.num_nodes);
    cluster.preload(1, true)?;

    let link = crate::cluster::link::LinkModel::new(ctx.config.link.clone());
    let est = Estimator::new(
        meta,
        &lat_model,
        &acc_model,
        &link,
        &downtime,
        ctx.config.reinstate_ms,
    );
    let mut failover = Failover::new(ctx.config.objectives.clone());
    let (images, _) = ctx.store.test_set()?;
    let requests = generate(
        p.n_requests,
        Arrival::Poisson { rate_rps: p.rate_rps },
        images.shape[0],
        ctx.config.seed,
    );
    let plan = FailurePlan::crash(p.fail_node, p.fail_at_ms);
    let cfg = ServiceConfig {
        batcher: BatcherConfig::new(
            ctx.store.batch_sizes.clone(),
            ctx.config.batch_timeout_ms,
            ctx.config.max_batch,
        ),
        detector: Detector::default(),
        deadline_ms: None,
    };
    eprintln!(
        "[e2e] serving {} requests at {} rps; node {} fails at t={} ms",
        p.n_requests, p.rate_rps, p.fail_node, p.fail_at_ms
    );
    let report = serve(
        &mut cluster,
        &est,
        &mut failover,
        &cfg,
        &requests,
        &images,
        &plan,
    )?;
    Ok(report)
}

pub fn print_report(p: &E2eParams, report: &ServiceReport) {
    let mut t = Table::new(
        &format!("E2E serving report — {}", p.model),
        &["metric", "value"],
    );
    t.row(&["requests completed".into(), report.completed.len().to_string()]);
    t.row(&["requests dropped".into(), report.dropped.to_string()]);
    t.row(&["throughput (rps)".into(), f(report.throughput_rps, 1)]);
    t.row(&["latency mean (ms)".into(), f(report.latency.mean, 2)]);
    t.row(&["latency p50 (ms)".into(), f(report.latency.p50, 2)]);
    t.row(&["latency p95 (ms)".into(), f(report.latency.p95, 2)]);
    t.row(&["latency p99 (ms)".into(), f(report.latency.p99, 2)]);
    t.row(&["sim span (ms)".into(), f(report.sim_span_ms, 0)]);
    for (start, end, tech) in &report.failovers {
        t.row(&[
            "failover".into(),
            format!("t={:.1}ms downtime={:.2}ms -> {}", start, end - start, tech.label()),
        ]);
    }
    t.print();

    // Before/after failure latency comparison.
    if let Some((fail_t, _, _)) = report.failovers.first() {
        let before: Vec<f64> = report
            .completed
            .iter()
            .filter(|c| c.technique.is_none())
            .map(|c| c.latency_ms)
            .collect();
        let after: Vec<f64> = report
            .completed
            .iter()
            .filter(|c| c.technique.is_some())
            .map(|c| c.latency_ms)
            .collect();
        let b = Summary::of(&before);
        let a = Summary::of(&after);
        println!(
            "before failure (t<{fail_t:.0}ms): n={} mean={:.2}ms | after failover: n={} mean={:.2}ms\n",
            b.n, b.mean, a.n, a.mean
        );
    }
}

pub fn run_default(ctx: &ExpContext) -> Result<()> {
    let model = ctx.config.model.clone();
    let meta = ctx.store.model(&model)?;
    // Fail a mid-pipeline skippable node so all three techniques compete.
    let fail_node = meta
        .skippable_nodes
        .get(meta.skippable_nodes.len() / 2)
        .copied()
        .unwrap_or(meta.num_nodes / 2);
    let p = E2eParams {
        model,
        n_requests: 60,
        rate_rps: 6.0,
        fail_node,
        fail_at_ms: 4000.0,
    };
    let report = run_e2e(ctx, &p)?;
    print_report(&p, &report);
    Ok(())
}
