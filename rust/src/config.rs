//! Configuration system: a TOML-subset loader + typed config structs with
//! validation and defaults.
//!
//! The parser supports the subset of TOML the configs use: `[section]` and
//! `[section.sub]` headers, `key = value` with string / float / int / bool
//! / homogeneous-array values, and `#` comments. Unknown keys are rejected
//! by `Config::from_kv` so typos fail loudly.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

/// Flat key-value view of a TOML-subset document ("section.key" -> value).
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Float(f64),
    Int(i64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

pub fn parse_toml(text: &str) -> Result<BTreeMap<String, TomlValue>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                bail!("line {}: malformed section header", lineno + 1);
            }
            section = line[1..line.len() - 1].trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        out.insert(key, parse_value(v.trim(), lineno + 1)?);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // a '#' outside of quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str, lineno: usize) -> Result<TomlValue> {
    if v.starts_with('"') && v.ends_with('"') && v.len() >= 2 {
        return Ok(TomlValue::Str(v[1..v.len() - 1].to_string()));
    }
    if v == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if v == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if v.starts_with('[') && v.ends_with(']') {
        let inner = &v[1..v.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_value(part.trim(), lineno)?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    if let Ok(i) = v.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("line {lineno}: cannot parse value '{v}'")
}

// ---------------------------------------------------------------------------
// Typed configuration
// ---------------------------------------------------------------------------

/// User-defined objectives for the CONTINUER scheduler (paper Eq. 2): the
/// weight of each objective; 0 means "no threshold specified".
#[derive(Debug, Clone, PartialEq)]
pub struct Objectives {
    pub w_accuracy: f64,
    pub w_latency: f64,
    pub w_downtime: f64,
}

impl Default for Objectives {
    fn default() -> Self {
        Objectives {
            w_accuracy: 0.5,
            w_latency: 0.3,
            w_downtime: 0.2,
        }
    }
}

impl Objectives {
    pub fn new(w_accuracy: f64, w_latency: f64, w_downtime: f64) -> Objectives {
        Objectives {
            w_accuracy,
            w_latency,
            w_downtime,
        }
    }

    pub fn validate(&self) -> Result<()> {
        for (name, w) in [
            ("accuracy", self.w_accuracy),
            ("latency", self.w_latency),
            ("downtime", self.w_downtime),
        ] {
            if !(0.0..=1.0).contains(&w) {
                bail!("objective weight {name} = {w} outside [0, 1]");
            }
        }
        if self.w_accuracy + self.w_latency + self.w_downtime <= 0.0 {
            bail!("at least one objective weight must be positive");
        }
        Ok(())
    }
}

/// Simulated network link parameters (DESIGN.md §1.4).
#[derive(Debug, Clone)]
pub struct LinkConfig {
    /// One-way base latency per hop, milliseconds.
    pub latency_ms: f64,
    /// Bandwidth, megabytes/second.
    pub bandwidth_mbps: f64,
    /// Jitter fraction (uniform +- on the base latency).
    pub jitter: f64,
}

impl Default for LinkConfig {
    fn default() -> Self {
        LinkConfig {
            latency_ms: 0.2,
            bandwidth_mbps: 800.0,
            jitter: 0.05,
        }
    }
}

/// Platform latency model (DESIGN.md §1.2): Platform 1 is the measured
/// host; Platform 2 scales measured latencies per layer kind.
#[derive(Debug, Clone, PartialEq)]
pub enum Platform {
    /// The host CPU, measured through PJRT.
    Host,
    /// Deterministic slow-platform transform of host measurements.
    Scaled { factor: f64, noise: f64 },
}

impl Platform {
    pub fn name(&self) -> String {
        match self {
            Platform::Host => "platform1".into(),
            Platform::Scaled { .. } => "platform2".into(),
        }
    }

    pub fn platform2() -> Platform {
        // i7-8700 (3.2GHz) vs i5-8250U (1.6GHz): ~2x clock, plus modest
        // per-measurement noise.
        Platform::Scaled {
            factor: 2.1,
            noise: 0.04,
        }
    }
}

/// Top-level configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Directory holding manifest.json and the compiled artifacts.
    pub artifacts_dir: PathBuf,
    /// Model to serve ("resnet32" | "mobilenetv2").
    pub model: String,
    /// Scheduler objectives.
    pub objectives: Objectives,
    /// Network link model.
    pub link: LinkConfig,
    /// Empirical "reinstate connections" downtime for repartition/skip
    /// (paper §IV-B-iii, from NEUKONFIG), milliseconds.
    pub reinstate_ms: f64,
    /// Serving batcher: max batch size and max queue delay.
    pub max_batch: usize,
    pub batch_timeout_ms: f64,
    /// Worker threads for parallel sections.
    pub workers: usize,
    /// Seed for all simulation randomness.
    pub seed: u64,
    /// Latency-profiler repetitions per micro artifact.
    pub profile_reps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            artifacts_dir: PathBuf::from("artifacts"),
            model: "resnet32".into(),
            objectives: Objectives::default(),
            link: LinkConfig::default(),
            reinstate_ms: 0.99,
            max_batch: 8,
            batch_timeout_ms: 2.0,
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            seed: 0,
            profile_reps: 30,
        }
    }
}

impl Config {
    pub fn from_file(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        let kv = parse_toml(&text)?;
        Config::from_kv(&kv)
    }

    pub fn from_kv(kv: &BTreeMap<String, TomlValue>) -> Result<Config> {
        let mut cfg = Config::default();
        for (key, val) in kv {
            let get_f64 =
                || -> Result<f64> { val.as_f64().ok_or_else(|| anyhow!("{key}: expected number")) };
            let get_usize = || -> Result<usize> {
                val.as_usize()
                    .ok_or_else(|| anyhow!("{key}: expected non-negative integer"))
            };
            match key.as_str() {
                "artifacts_dir" => {
                    cfg.artifacts_dir = PathBuf::from(
                        val.as_str().ok_or_else(|| anyhow!("{key}: expected string"))?,
                    )
                }
                "model" => {
                    cfg.model = val
                        .as_str()
                        .ok_or_else(|| anyhow!("{key}: expected string"))?
                        .to_string()
                }
                "seed" => cfg.seed = get_usize()? as u64,
                "workers" => cfg.workers = get_usize()?,
                "profile_reps" => cfg.profile_reps = get_usize()?,
                "reinstate_ms" => cfg.reinstate_ms = get_f64()?,
                "objectives.accuracy" => cfg.objectives.w_accuracy = get_f64()?,
                "objectives.latency" => cfg.objectives.w_latency = get_f64()?,
                "objectives.downtime" => cfg.objectives.w_downtime = get_f64()?,
                "link.latency_ms" => cfg.link.latency_ms = get_f64()?,
                "link.bandwidth_mbps" => cfg.link.bandwidth_mbps = get_f64()?,
                "link.jitter" => cfg.link.jitter = get_f64()?,
                "batcher.max_batch" => cfg.max_batch = get_usize()?,
                "batcher.timeout_ms" => cfg.batch_timeout_ms = get_f64()?,
                other => bail!("unknown config key '{other}'"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        self.objectives.validate()?;
        if self.model != "resnet32" && self.model != "mobilenetv2" {
            bail!("unknown model '{}'", self.model);
        }
        if self.link.bandwidth_mbps <= 0.0 {
            bail!("link.bandwidth_mbps must be positive");
        }
        if self.max_batch == 0 {
            bail!("batcher.max_batch must be >= 1");
        }
        if self.profile_reps == 0 {
            bail!("profile_reps must be >= 1");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_toml() {
        let kv = parse_toml(
            "# comment\nmodel = \"resnet32\"\nseed = 42\n[link]\nlatency_ms = 1.5\n",
        )
        .unwrap();
        assert_eq!(kv["model"], TomlValue::Str("resnet32".into()));
        assert_eq!(kv["seed"], TomlValue::Int(42));
        assert_eq!(kv["link.latency_ms"], TomlValue::Float(1.5));
    }

    #[test]
    fn parse_arrays_and_bools() {
        let kv = parse_toml("xs = [1, 2, 3]\nok = true\n").unwrap();
        assert_eq!(
            kv["xs"],
            TomlValue::Arr(vec![
                TomlValue::Int(1),
                TomlValue::Int(2),
                TomlValue::Int(3)
            ])
        );
        assert_eq!(kv["ok"], TomlValue::Bool(true));
    }

    #[test]
    fn comment_inside_string_kept() {
        let kv = parse_toml("s = \"a#b\"\n").unwrap();
        assert_eq!(kv["s"], TomlValue::Str("a#b".into()));
    }

    #[test]
    fn config_from_kv_roundtrip() {
        let kv = parse_toml(
            "model = \"mobilenetv2\"\n[objectives]\naccuracy = 0.7\nlatency = 0.2\ndowntime = 0.1\n[batcher]\nmax_batch = 4\ntimeout_ms = 1.0\n",
        )
        .unwrap();
        let cfg = Config::from_kv(&kv).unwrap();
        assert_eq!(cfg.model, "mobilenetv2");
        assert_eq!(cfg.objectives.w_accuracy, 0.7);
        assert_eq!(cfg.max_batch, 4);
    }

    #[test]
    fn unknown_key_rejected() {
        let kv = parse_toml("nonsense = 1\n").unwrap();
        assert!(Config::from_kv(&kv).is_err());
    }

    #[test]
    fn invalid_weights_rejected() {
        let o = Objectives::new(2.0, 0.0, 0.0);
        assert!(o.validate().is_err());
        let o = Objectives::new(0.0, 0.0, 0.0);
        assert!(o.validate().is_err());
        assert!(Objectives::default().validate().is_ok());
    }

    #[test]
    fn malformed_toml_errors() {
        assert!(parse_toml("[unclosed\n").is_err());
        assert!(parse_toml("novalue\n").is_err());
        assert!(parse_toml("x = @@\n").is_err());
    }
}
