//! Runtime layer: PJRT engine, AOT artifact store and host tensors.
//! Python never runs here — artifacts were lowered once at build time.

pub mod artifact;
pub mod pjrt;
pub mod tensor;

pub use artifact::{ArtifactStore, MicroEntry, UnitKind};
pub use pjrt::{Engine, UnitExecutable};
pub use tensor::{Activation, HostTensor, ShapeOnly};
