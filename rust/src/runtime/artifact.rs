//! Artifact store: the manifest.json + weights + data files the python AOT
//! path emits, resolved into typed metadata and loadable units.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Result};

use crate::dnn::layers::LayerSpec;
use crate::dnn::model::{ModelMeta, WeightEntry};
use crate::util::json::Json;

use super::pjrt::{Engine, UnitExecutable};
use super::tensor::{read_f32_file, read_i32_file, HostTensor};

/// One latency micro-benchmark artifact (single layer).
#[derive(Debug, Clone)]
pub struct MicroEntry {
    pub spec: LayerSpec,
    pub artifact: String,
}

/// Which unit of a model to load.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnitKind {
    Node(usize),
    Exit(usize),
}

/// Parsed artifact store.
pub struct ArtifactStore {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelMeta>,
    pub micro: Vec<MicroEntry>,
    pub rust_eval_n: usize,
    pub num_classes: usize,
    pub batch_sizes: Vec<usize>,
    /// Lazily-loaded flat weight files per model.
    weights: Mutex<BTreeMap<String, std::sync::Arc<Vec<f32>>>>,
}

impl ArtifactStore {
    pub fn open(dir: &Path) -> Result<ArtifactStore> {
        let manifest = Json::from_file(&dir.join("manifest.json"))?;
        let mut models = BTreeMap::new();
        for (name, v) in manifest
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing models"))?
        {
            models.insert(name.clone(), ModelMeta::from_json(name, v)?);
        }
        let mut micro = Vec::new();
        if let Some(arr) = manifest.get("micro").and_then(Json::as_arr) {
            for m in arr {
                micro.push(MicroEntry {
                    spec: LayerSpec::from_json(m)?,
                    artifact: m
                        .get("artifact")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("micro entry missing artifact"))?
                        .to_string(),
                });
            }
        }
        Ok(ArtifactStore {
            dir: dir.to_path_buf(),
            models,
            micro,
            rust_eval_n: manifest
                .get("rust_eval_n")
                .and_then(Json::as_usize)
                .unwrap_or(128),
            num_classes: manifest
                .get("num_classes")
                .and_then(Json::as_usize)
                .unwrap_or(10),
            batch_sizes: manifest
                .get("batch_sizes")
                .and_then(Json::as_usize_vec)
                .unwrap_or_else(|| vec![1]),
            weights: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("no model '{name}' in manifest"))
    }

    /// Flat weight file for a model (cached).
    pub fn weights(&self, model: &str) -> Result<std::sync::Arc<Vec<f32>>> {
        let mut cache = self.weights.lock().unwrap();
        if let Some(w) = cache.get(model) {
            return Ok(w.clone());
        }
        let meta = self.model(model)?;
        let path = self.dir.join(&meta.weights_file);
        let bytes =
            std::fs::read(&path).map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let arc = std::sync::Arc::new(data);
        cache.insert(model.to_string(), arc.clone());
        Ok(arc)
    }

    /// Materialise the weight-leaf tensors for a unit, in argument order.
    pub fn weight_slices(&self, model: &str, entries: &[WeightEntry]) -> Result<Vec<HostTensor>> {
        let flat = self.weights(model)?;
        entries
            .iter()
            .map(|e| {
                let end = e.offset + e.elems();
                if end > flat.len() {
                    return Err(anyhow!(
                        "{model}: weight '{}' [{}..{end}) beyond file ({})",
                        e.name,
                        e.offset,
                        flat.len()
                    ));
                }
                HostTensor::new(e.shape.clone(), flat[e.offset..end].to_vec())
            })
            .collect()
    }

    /// Load + compile a model unit at a batch size present in the manifest.
    pub fn load_unit(
        &self,
        engine: &Engine,
        model: &str,
        unit: UnitKind,
        batch: usize,
    ) -> Result<UnitExecutable> {
        let meta = self.model(model)?;
        let (artifacts, weights, in_shape, out_shape) = match unit {
            UnitKind::Node(i) => {
                let n = meta.node(i)?;
                (&n.artifacts, &n.weights, n.in_shape.clone(), n.out_shape.clone())
            }
            UnitKind::Exit(i) => {
                let e = meta.exit(i)?;
                (
                    &e.artifacts,
                    &e.weights,
                    e.in_shape.clone(),
                    vec![self.num_classes],
                )
            }
        };
        let rel = artifacts
            .get(&batch)
            .ok_or_else(|| anyhow!("{model} {unit:?}: no artifact for batch {batch}"))?;
        let slices = self.weight_slices(model, weights)?;
        let mut bin = vec![batch];
        bin.extend(in_shape);
        let mut bout = vec![batch];
        bout.extend(out_shape);
        UnitExecutable::load(engine, &self.dir.join(rel), slices, bin, bout)
    }

    /// The rust-side eval set: (images [n, 32, 32, 3], labels).
    pub fn test_set(&self) -> Result<(HostTensor, Vec<i32>)> {
        let n = self.rust_eval_n;
        let x = read_f32_file(&self.dir.join("data/test_x.bin"), vec![n, 32, 32, 3])?;
        let y = read_i32_file(&self.dir.join("data/test_y.bin"), n)?;
        Ok((x, y))
    }

    pub fn micro_path(&self, entry: &MicroEntry) -> PathBuf {
        self.dir.join(&entry.artifact)
    }
}
