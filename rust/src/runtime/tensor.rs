//! Host-side tensors and raw binary readers for the AOT data files.

use anyhow::{anyhow, bail, Result};

/// A dense f32 host tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<HostTensor> {
        let expect: usize = shape.iter().product();
        if data.len() != expect {
            bail!(
                "tensor data length {} does not match shape {:?} ({} elems)",
                data.len(),
                shape,
                expect
            );
        }
        Ok(HostTensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> HostTensor {
        let n = shape.iter().product();
        HostTensor {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn elems(&self) -> usize {
        self.data.len()
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Elements per leading-axis row (product of the trailing dims).
    pub fn row_elems(&self) -> usize {
        self.shape[1..].iter().product()
    }

    /// Leading-axis slice [lo, hi): e.g. a batch sub-range.
    pub fn slice0(&self, lo: usize, hi: usize) -> Result<HostTensor> {
        if self.shape.is_empty() || hi > self.shape[0] || lo > hi {
            bail!("slice0({lo}, {hi}) out of range for shape {:?}", self.shape);
        }
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        Ok(HostTensor {
            shape,
            data: self.data[lo * row..hi * row].to_vec(),
        })
    }

    /// Concatenate along axis 0. All tensors must share trailing dims.
    pub fn concat0(parts: &[HostTensor]) -> Result<HostTensor> {
        let first = parts.first().ok_or_else(|| anyhow!("concat0 of nothing"))?;
        let trailing = &first.shape[1..];
        let mut n0 = 0;
        for p in parts {
            if &p.shape[1..] != trailing {
                bail!("concat0: trailing shape mismatch");
            }
            n0 += p.shape[0];
        }
        let mut data = Vec::with_capacity(parts.iter().map(|p| p.data.len()).sum());
        for p in parts {
            data.extend_from_slice(&p.data);
        }
        let mut shape = first.shape.clone();
        shape[0] = n0;
        HostTensor::new(shape, data)
    }

    /// Build a batch by gathering single leading-axis rows `idxs`, padded
    /// to `target` rows by repeating the first gathered row — one output
    /// allocation, no intermediate per-row tensors. This is the serving
    /// engine's dispatch path: requests index rows of the input pool and
    /// the compiled batch size may exceed the dispatched request count.
    pub fn gather_pad_rows0(&self, idxs: &[usize], target: usize) -> Result<HostTensor> {
        let first = *idxs.first().ok_or_else(|| anyhow!("gather of no rows"))?;
        if target < idxs.len() {
            bail!("target {} smaller than {} gathered rows", target, idxs.len());
        }
        let n0 = *self
            .shape
            .first()
            .ok_or_else(|| anyhow!("gather from a rank-0 tensor"))?;
        let row = self.row_elems();
        let mut data = Vec::with_capacity(target * row);
        for &i in idxs {
            if i >= n0 {
                bail!("row {i} out of range for shape {:?}", self.shape);
            }
            data.extend_from_slice(&self.data[i * row..(i + 1) * row]);
        }
        for _ in idxs.len()..target {
            data.extend_from_slice(&self.data[first * row..(first + 1) * row]);
        }
        let mut shape = self.shape.clone();
        shape[0] = target;
        HostTensor::new(shape, data)
    }

    /// Argmax over the last axis, per leading row (logits -> class ids).
    pub fn argmax_rows(&self) -> Vec<usize> {
        let c = *self.shape.last().unwrap_or(&1);
        self.data
            .chunks(c)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

/// Shape-only stand-in for an activation: what the serving scheduler
/// actually consumes (row count for batching, byte size for transfer
/// modeling). Cloning copies two integers — no heap traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShapeOnly {
    /// Leading-axis (batch) size.
    pub rows: usize,
    /// Elements per row (product of the trailing dims).
    pub row_elems: usize,
}

impl ShapeOnly {
    pub fn elems(&self) -> usize {
        self.rows * self.row_elems
    }

    pub fn bytes(&self) -> usize {
        self.elems() * 4
    }
}

/// An activation flowing between pipeline stages, as the serving engine
/// tracks it: materialized f32 data on the real PJRT path, a [`ShapeOnly`]
/// handle on the synthetic path (where stages are identity and the
/// scheduler only ever reads the byte size). The handle variant makes a
/// per-stage "copy" of the activation allocation-free.
#[derive(Debug, Clone, PartialEq)]
pub enum Activation {
    Full(HostTensor),
    Shape(ShapeOnly),
}

impl Activation {
    /// Shape-only view of `t`'s batch geometry.
    pub fn shape_of(t: &HostTensor) -> Activation {
        Activation::Shape(ShapeOnly {
            rows: *t.shape.first().unwrap_or(&1),
            row_elems: t.row_elems(),
        })
    }

    pub fn rows(&self) -> usize {
        match self {
            Activation::Full(t) => *t.shape.first().unwrap_or(&1),
            Activation::Shape(s) => s.rows,
        }
    }

    pub fn bytes(&self) -> usize {
        match self {
            Activation::Full(t) => t.bytes(),
            Activation::Shape(s) => s.bytes(),
        }
    }

    /// The materialized tensor, or an error on a shape-only handle (a
    /// materializing backend was handed a synthetic activation).
    pub fn tensor(&self) -> Result<&HostTensor> {
        match self {
            Activation::Full(t) => Ok(t),
            Activation::Shape(s) => bail!(
                "shape-only activation ({} x {} elems) has no data to materialize",
                s.rows,
                s.row_elems
            ),
        }
    }
}

impl From<HostTensor> for Activation {
    fn from(t: HostTensor) -> Activation {
        Activation::Full(t)
    }
}

/// Read a raw little-endian f32 file into a tensor of the given shape.
pub fn read_f32_file(path: &std::path::Path, shape: Vec<usize>) -> Result<HostTensor> {
    let bytes = std::fs::read(path).map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    let expect: usize = shape.iter().product::<usize>() * 4;
    if bytes.len() != expect {
        bail!(
            "{}: {} bytes but shape {:?} needs {}",
            path.display(),
            bytes.len(),
            shape,
            expect
        );
    }
    let data = bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    HostTensor::new(shape, data)
}

/// Read a raw little-endian i32 file (labels).
pub fn read_i32_file(path: &std::path::Path, n: usize) -> Result<Vec<i32>> {
    let bytes = std::fs::read(path).map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    if bytes.len() != n * 4 {
        bail!("{}: {} bytes but expected {}", path.display(), bytes.len(), n * 4);
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn slice_and_concat_roundtrip() {
        let t = HostTensor::new(vec![4, 2], (0..8).map(|i| i as f32).collect()).unwrap();
        let a = t.slice0(0, 2).unwrap();
        let b = t.slice0(2, 4).unwrap();
        assert_eq!(a.shape, vec![2, 2]);
        assert_eq!(HostTensor::concat0(&[a, b]).unwrap(), t);
    }

    #[test]
    fn gather_pad_matches_slice_concat() {
        let pool = HostTensor::new(vec![4, 2], (0..8).map(|i| i as f32).collect()).unwrap();
        // The old dispatch path: slice each request row, pad with clones
        // of the first, concat once.
        let rows = vec![
            pool.slice0(2, 3).unwrap(),
            pool.slice0(0, 1).unwrap(),
            pool.slice0(2, 3).unwrap(),
            pool.slice0(2, 3).unwrap(),
        ];
        let old = HostTensor::concat0(&rows).unwrap();
        let new = pool.gather_pad_rows0(&[2, 0], 4).unwrap();
        assert_eq!(new, old);
        assert_eq!(new.shape, vec![4, 2]);
        // No padding when target == gathered rows.
        let exact = pool.gather_pad_rows0(&[1, 3], 2).unwrap();
        assert_eq!(exact.data, vec![2.0, 3.0, 6.0, 7.0]);
        // Bounds are enforced.
        assert!(pool.gather_pad_rows0(&[4], 1).is_err());
        assert!(pool.gather_pad_rows0(&[0, 1], 1).is_err());
        assert!(pool.gather_pad_rows0(&[], 2).is_err());
    }

    #[test]
    fn activation_bytes_and_rows_agree_across_variants() {
        let t = HostTensor::zeros(vec![3, 5]);
        let full = Activation::Full(t.clone());
        let shape = Activation::shape_of(&t);
        assert_eq!(full.rows(), 3);
        assert_eq!(shape.rows(), 3);
        assert_eq!(full.bytes(), shape.bytes());
        assert!(full.tensor().is_ok());
        assert!(shape.tensor().is_err(), "shape-only has no data");
    }

    #[test]
    fn argmax_rows() {
        let t = HostTensor::new(vec![2, 3], vec![0.1, 0.9, 0.0, 5.0, -1.0, 2.0]).unwrap();
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("continuer_tensor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.bin");
        let vals: Vec<f32> = vec![1.5, -2.0, 3.25, 0.0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&p, &bytes).unwrap();
        let t = read_f32_file(&p, vec![2, 2]).unwrap();
        assert_eq!(t.data, vals);
        assert!(read_f32_file(&p, vec![3, 2]).is_err());
    }
}
