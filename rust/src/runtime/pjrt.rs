//! PJRT runtime: loads AOT HLO-text artifacts and executes them on the
//! CPU PJRT client (the `xla` crate / xla_extension 0.5.1).
//!
//! Interchange is HLO *text*: jax >= 0.5 emits HloModuleProtos with 64-bit
//! instruction ids which this XLA rejects; `HloModuleProto::from_text_file`
//! reparses and reassigns ids (see /opt/xla-example/README.md).
//!
//! A `UnitExecutable` couples one compiled per-node (or exit-head) artifact
//! with its weight arguments, which are uploaded once as device buffers at
//! load time — the request path only transfers the activation.

use std::path::Path;

use anyhow::{anyhow, Result};

use super::tensor::HostTensor;

/// Wrapper around the PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Engine { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text artifact.
    pub fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {}", path.display()))?,
        )
        .map_err(|e| anyhow!("parsing HLO {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))
    }

    /// Upload a host tensor to the device.
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(&t.data, &t.shape, None)
            .map_err(|e| anyhow!("uploading tensor: {e}"))
    }
}

/// One compiled block/exit artifact plus its resident weight buffers.
pub struct UnitExecutable {
    exe: xla::PjRtLoadedExecutable,
    weights: Vec<xla::PjRtBuffer>,
    /// Expected activation shape (with batch dim).
    pub in_shape: Vec<usize>,
    /// Output shape (with batch dim).
    pub out_shape: Vec<usize>,
}

impl UnitExecutable {
    /// Compile `path` and bind `weight_slices` (leaf tensors in argument
    /// order) as resident buffers.
    pub fn load(
        engine: &Engine,
        path: &Path,
        weight_slices: Vec<HostTensor>,
        in_shape: Vec<usize>,
        out_shape: Vec<usize>,
    ) -> Result<UnitExecutable> {
        let exe = engine.compile_file(path)?;
        let weights = weight_slices
            .iter()
            .map(|t| engine.upload(t))
            .collect::<Result<Vec<_>>>()?;
        Ok(UnitExecutable {
            exe,
            weights,
            in_shape,
            out_shape,
        })
    }

    /// Run the unit on an activation. Returns the output tensor.
    pub fn run(&self, engine: &Engine, activation: &HostTensor) -> Result<HostTensor> {
        if activation.shape != self.in_shape {
            return Err(anyhow!(
                "activation shape {:?} != expected {:?}",
                activation.shape,
                self.in_shape
            ));
        }
        let act = engine.upload(activation)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weights.len());
        args.push(&act);
        args.extend(self.weights.iter());
        let result = self
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow!("executing unit: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e}"))?;
        // Artifacts are lowered with return_tuple=True -> 1-tuple.
        let out = lit.to_tuple1().map_err(|e| anyhow!("untupling: {e}"))?;
        let data = out
            .to_vec::<f32>()
            .map_err(|e| anyhow!("reading result: {e}"))?;
        HostTensor::new(self.out_shape.clone(), data)
    }
}
