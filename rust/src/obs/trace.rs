//! Chrome `trace_event` / Perfetto export of a recorded engine stream.
//!
//! Track layout: each replica is a process (`pid = replica`); inside
//! it, `tid 0` is the replica's **controller** track (failover
//! windows, quarantine windows, detection/recovery/drop instants,
//! raw condition markers) and `tid = node + 1` is one track per
//! cluster node carrying its stage spans as `ph:"X"` duration events.
//! Per-node spans never overlap because the engine serializes a
//! node's occupancy through `busy_until`.
//!
//! Open the emitted JSON in `chrome://tracing` or at
//! <https://ui.perfetto.dev> (File → Open trace file). Timestamps are
//! microseconds as the format requires; the simulation clock is ms,
//! so `ts = at_ms * 1000`.
//!
//! High-rate per-request events (arrival, completion, batch dispatch)
//! are deliberately not serialized as spans — they would dominate the
//! file without adding timeline structure; use a [`crate::obs::report`]
//! module for exact counts. They *are* folded into one `ph:"C"`
//! counter track per replica ("outstanding": arrivals minus
//! completions minus drops), which is how drain-while-deploying reads
//! in ui.perfetto.dev: under a break-before-make deployment the
//! counter climbs across the deployment span; under make-before-break
//! it keeps draining on the fallback path. (Queue depth proper is
//! ill-defined at this layer — a requeue after a mid-flight host
//! failure re-dispatches the same requests — so the counter tracks
//! outstanding work, which is conservation-exact.)
//!
//! Repartition deployments appear on the controller track as
//! `ph:"X"` spans paired from `DeployStart` to `Cutover`, with
//! per-host transfer/warm-up completion instants on the receiving
//! node's track.

use std::collections::{BTreeMap, BTreeSet};

use crate::cluster::failure::NodeCondition;
use crate::obs::{EngineEvent, EngineEventKind};
use crate::util::json::{obj, Json};

const MS_TO_US: f64 = 1000.0;

fn meta(name: &str, pid: usize, tid: Option<usize>, label: &str) -> Json {
    let mut fields = vec![
        ("ph", Json::from("M")),
        ("name", Json::from(name)),
        ("pid", Json::from(pid as f64)),
        ("args", obj(&[("name", Json::from(label))])),
    ];
    if let Some(tid) = tid {
        fields.push(("tid", Json::from(tid as f64)));
    }
    obj(&fields)
}

fn span(name: &str, cat: &str, pid: usize, tid: usize, ts_ms: f64, dur_ms: f64, args: Json) -> Json {
    obj(&[
        ("ph", Json::from("X")),
        ("name", Json::from(name)),
        ("cat", Json::from(cat)),
        ("pid", Json::from(pid as f64)),
        ("tid", Json::from(tid as f64)),
        ("ts", Json::from(ts_ms * MS_TO_US)),
        ("dur", Json::from(dur_ms.max(0.0) * MS_TO_US)),
        ("args", args),
    ])
}

fn instant(name: &str, cat: &str, pid: usize, tid: usize, ts_ms: f64, args: Json) -> Json {
    obj(&[
        ("ph", Json::from("i")),
        ("s", Json::from("t")),
        ("name", Json::from(name)),
        ("cat", Json::from(cat)),
        ("pid", Json::from(pid as f64)),
        ("tid", Json::from(tid as f64)),
        ("ts", Json::from(ts_ms * MS_TO_US)),
        ("args", args),
    ])
}

fn counter(name: &str, pid: usize, ts_ms: f64, key: &str, value: f64) -> Json {
    obj(&[
        ("ph", Json::from("C")),
        ("name", Json::from(name)),
        ("pid", Json::from(pid as f64)),
        ("ts", Json::from(ts_ms * MS_TO_US)),
        ("args", obj(&[(key, Json::from(value))])),
    ])
}

fn condition_label(c: NodeCondition) -> (&'static str, f64) {
    match c {
        NodeCondition::Up => ("up", 1.0),
        NodeCondition::Degraded(s) => ("degraded", s),
        NodeCondition::Down => ("down", 0.0),
    }
}

/// Serialize a recorded event stream as a Chrome `trace_event` JSON
/// document. Output is a pure function of the stream (BTree-ordered
/// keys, deterministic event order), so same-seed runs produce
/// byte-identical traces.
pub fn chrome_trace(events: &[EngineEvent]) -> Json {
    // Track discovery: every replica gets a controller track; every
    // node mentioned by any event gets a stage track.
    let mut replicas: BTreeSet<usize> = BTreeSet::new();
    let mut node_tracks: BTreeSet<(usize, usize)> = BTreeSet::new();
    for ev in events {
        replicas.insert(ev.replica);
        match ev.kind {
            EngineEventKind::StageStart { node, .. }
            | EngineEventKind::StageDone { node, .. }
            | EngineEventKind::Condition { node, .. }
            | EngineEventKind::Failover { node, .. }
            | EngineEventKind::Recovery { node }
            | EngineEventKind::QuarantineEnter { node }
            | EngineEventKind::QuarantineExit { node }
            | EngineEventKind::TransferDone { node }
            | EngineEventKind::WarmupDone { node } => {
                node_tracks.insert((ev.replica, node));
            }
            _ => {}
        }
    }

    let mut out: Vec<Json> = Vec::new();
    for &r in &replicas {
        out.push(meta("process_name", r, None, &format!("replica {r}")));
        out.push(meta("thread_name", r, Some(0), "controller"));
    }
    for &(r, node) in &node_tracks {
        out.push(meta("thread_name", r, Some(node + 1), &format!("node {node}")));
    }

    // Span pairing state. Stage spans key on (replica, batch, stage);
    // quarantine and deployment windows on (replica, node).
    let mut open_stage: BTreeMap<(usize, usize, usize), (f64, usize)> = BTreeMap::new();
    let mut open_quarantine: BTreeMap<(usize, usize), f64> = BTreeMap::new();
    let mut open_deploy: BTreeMap<(usize, usize), (f64, bool)> = BTreeMap::new();
    // Per-replica outstanding-request counter (ph:"C" track): arrivals
    // minus completions minus drops.
    let mut outstanding: BTreeMap<usize, f64> = BTreeMap::new();
    let mut last_ms: f64 = 0.0;

    for ev in events {
        last_ms = last_ms.max(ev.at_ms);
        let r = ev.replica;
        match ev.kind {
            EngineEventKind::StageStart {
                batch_seq,
                stage,
                node,
            } => {
                open_stage.insert((r, batch_seq, stage), (ev.at_ms, node));
            }
            EngineEventKind::StageDone {
                batch_seq,
                stage,
                node,
            } => {
                if let Some((start_ms, start_node)) = open_stage.remove(&(r, batch_seq, stage)) {
                    debug_assert_eq!(start_node, node);
                    out.push(span(
                        &format!("batch {batch_seq} stage {stage}"),
                        "stage",
                        r,
                        node + 1,
                        start_ms,
                        ev.at_ms - start_ms,
                        obj(&[
                            ("batch", Json::from(batch_seq as f64)),
                            ("stage", Json::from(stage as f64)),
                            ("node", Json::from(node as f64)),
                        ]),
                    ));
                }
            }
            EngineEventKind::Condition { node, condition } => {
                let (state, slowdown) = condition_label(condition);
                out.push(instant(
                    &format!("node {node} {state}"),
                    "condition",
                    r,
                    0,
                    ev.at_ms,
                    obj(&[
                        ("node", Json::from(node as f64)),
                        ("state", Json::from(state)),
                        ("slowdown", Json::from(slowdown)),
                    ]),
                ));
            }
            EngineEventKind::Failover {
                node,
                technique,
                false_positive,
                end_ms,
            } => {
                out.push(instant(
                    &format!("detect node {node}"),
                    "detection",
                    r,
                    0,
                    ev.at_ms,
                    obj(&[
                        ("node", Json::from(node as f64)),
                        ("false_positive", Json::from(false_positive)),
                    ]),
                ));
                out.push(span(
                    &format!("failover {} (node {node})", technique.label()),
                    "failover",
                    r,
                    0,
                    ev.at_ms,
                    end_ms - ev.at_ms,
                    obj(&[
                        ("node", Json::from(node as f64)),
                        ("technique", Json::from(technique.label())),
                        ("false_positive", Json::from(false_positive)),
                    ]),
                ));
            }
            EngineEventKind::Recovery { node } => {
                out.push(instant(
                    &format!("recovery node {node}"),
                    "recovery",
                    r,
                    0,
                    ev.at_ms,
                    obj(&[("node", Json::from(node as f64))]),
                ));
            }
            EngineEventKind::QuarantineEnter { node } => {
                open_quarantine.insert((r, node), ev.at_ms);
            }
            EngineEventKind::QuarantineExit { node } => {
                if let Some(start_ms) = open_quarantine.remove(&(r, node)) {
                    out.push(span(
                        &format!("quarantine node {node}"),
                        "quarantine",
                        r,
                        0,
                        start_ms,
                        ev.at_ms - start_ms,
                        obj(&[("node", Json::from(node as f64))]),
                    ));
                }
            }
            EngineEventKind::Drop {
                id,
                arrival_ms,
                degraded,
            } => {
                out.push(instant(
                    "drop",
                    "drop",
                    r,
                    0,
                    ev.at_ms,
                    obj(&[
                        ("id", Json::from(id as f64)),
                        ("arrival_ms", Json::from(arrival_ms)),
                        ("degraded", Json::from(degraded)),
                    ]),
                ));
                let v = outstanding.entry(r).or_insert(0.0);
                *v -= 1.0;
                out.push(counter("outstanding", r, ev.at_ms, "requests", *v));
            }
            EngineEventKind::DeployStart {
                node,
                make_before_break,
                transfers,
                cutover_ms,
            } => {
                open_deploy.insert((r, node), (ev.at_ms, make_before_break));
                out.push(instant(
                    &format!("deploy start (node {node})"),
                    "deployment",
                    r,
                    0,
                    ev.at_ms,
                    obj(&[
                        ("node", Json::from(node as f64)),
                        ("make_before_break", Json::from(make_before_break)),
                        ("transfers", Json::from(transfers as f64)),
                        ("cutover_ms", Json::from(cutover_ms)),
                    ]),
                ));
            }
            EngineEventKind::TransferDone { node } => {
                out.push(instant(
                    &format!("weights landed (node {node})"),
                    "deployment",
                    r,
                    node + 1,
                    ev.at_ms,
                    obj(&[("node", Json::from(node as f64))]),
                ));
            }
            EngineEventKind::WarmupDone { node } => {
                out.push(instant(
                    &format!("warm (node {node})"),
                    "deployment",
                    r,
                    node + 1,
                    ev.at_ms,
                    obj(&[("node", Json::from(node as f64))]),
                ));
            }
            EngineEventKind::Cutover { node, stalled_ms } => {
                if let Some((start_ms, mbb)) = open_deploy.remove(&(r, node)) {
                    let style = if mbb {
                        "make-before-break"
                    } else {
                        "break-before-make"
                    };
                    out.push(span(
                        &format!("deploy repartition {style} (node {node})"),
                        "deployment",
                        r,
                        0,
                        start_ms,
                        ev.at_ms - start_ms,
                        obj(&[
                            ("node", Json::from(node as f64)),
                            ("make_before_break", Json::from(mbb)),
                            ("stalled_ms", Json::from(stalled_ms)),
                        ]),
                    ));
                }
            }
            EngineEventKind::Arrival { .. } => {
                let v = outstanding.entry(r).or_insert(0.0);
                *v += 1.0;
                out.push(counter("outstanding", r, ev.at_ms, "requests", *v));
            }
            EngineEventKind::Completion { .. } => {
                let v = outstanding.entry(r).or_insert(0.0);
                *v -= 1.0;
                out.push(counter("outstanding", r, ev.at_ms, "requests", *v));
            }
            EngineEventKind::BatchDispatch { .. } => {}
        }
    }

    // A node can still be quarantined when the run drains; close the
    // window at the last observed timestamp so the track stays valid.
    for (&(r, node), &start_ms) in &open_quarantine {
        out.push(span(
            &format!("quarantine node {node} (open)"),
            "quarantine",
            r,
            0,
            start_ms,
            last_ms - start_ms,
            obj(&[("node", Json::from(node as f64)), ("open", Json::from(true))]),
        ));
    }
    // Same for deployments the run ended (or a recovery canceled)
    // before their cut-over fired.
    for (&(r, node), &(start_ms, mbb)) in &open_deploy {
        out.push(span(
            &format!("deploy repartition (node {node}) (open)"),
            "deployment",
            r,
            0,
            start_ms,
            last_ms - start_ms,
            obj(&[
                ("node", Json::from(node as f64)),
                ("make_before_break", Json::from(mbb)),
                ("open", Json::from(true)),
            ]),
        ));
    }

    obj(&[
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::from("ms")),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::variants::Technique;

    fn ev(at_ms: f64, replica: usize, kind: EngineEventKind) -> EngineEvent {
        EngineEvent {
            at_ms,
            replica,
            kind,
        }
    }

    #[test]
    fn stage_spans_pair_start_with_done() {
        let events = vec![
            ev(
                1.0,
                0,
                EngineEventKind::StageStart {
                    batch_seq: 0,
                    stage: 0,
                    node: 2,
                },
            ),
            ev(
                6.0,
                0,
                EngineEventKind::StageDone {
                    batch_seq: 0,
                    stage: 0,
                    node: 2,
                },
            ),
        ];
        let doc = chrome_trace(&events);
        let evs = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let spans: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .collect();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].get("ts").and_then(Json::as_f64), Some(1000.0));
        assert_eq!(spans[0].get("dur").and_then(Json::as_f64), Some(5000.0));
        assert_eq!(spans[0].get("tid").and_then(Json::as_f64), Some(3.0));
    }

    #[test]
    fn failover_emits_instant_and_window() {
        let events = vec![ev(
            10.0,
            1,
            EngineEventKind::Failover {
                node: 3,
                technique: Technique::Repartition,
                false_positive: false,
                end_ms: 18.0,
            },
        )];
        let doc = chrome_trace(&events);
        let evs = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert!(evs
            .iter()
            .any(|e| e.get("ph").and_then(Json::as_str) == Some("i")
                && e.get("cat").and_then(Json::as_str) == Some("detection")));
        let window = evs
            .iter()
            .find(|e| e.get("cat").and_then(Json::as_str) == Some("failover"))
            .expect("failover window span");
        assert_eq!(window.get("dur").and_then(Json::as_f64), Some(8000.0));
        assert_eq!(window.get("tid").and_then(Json::as_f64), Some(0.0));
    }

    #[test]
    fn deployment_pairs_start_with_cutover_and_marks_hosts() {
        let events = vec![
            ev(
                100.0,
                0,
                EngineEventKind::DeployStart {
                    node: 3,
                    make_before_break: true,
                    transfers: 1,
                    cutover_ms: 160.0,
                },
            ),
            ev(150.0, 0, EngineEventKind::TransferDone { node: 2 }),
            ev(160.0, 0, EngineEventKind::WarmupDone { node: 2 }),
            ev(
                160.0,
                0,
                EngineEventKind::Cutover {
                    node: 3,
                    stalled_ms: 0.0,
                },
            ),
        ];
        let doc = chrome_trace(&events);
        let evs = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let dep = evs
            .iter()
            .find(|e| {
                e.get("cat").and_then(Json::as_str) == Some("deployment")
                    && e.get("ph").and_then(Json::as_str) == Some("X")
            })
            .expect("deployment span");
        assert_eq!(dep.get("ts").and_then(Json::as_f64), Some(100_000.0));
        assert_eq!(dep.get("dur").and_then(Json::as_f64), Some(60_000.0));
        assert_eq!(dep.get("tid").and_then(Json::as_f64), Some(0.0));
        // Transfer/warm-up instants land on the receiving host's track.
        let instants: Vec<&Json> = evs
            .iter()
            .filter(|e| {
                e.get("cat").and_then(Json::as_str) == Some("deployment")
                    && e.get("ph").and_then(Json::as_str) == Some("i")
            })
            .collect();
        assert_eq!(instants.len(), 3); // deploy start + transfer + warm-up
        assert!(instants
            .iter()
            .filter(|e| e.get("name").and_then(Json::as_str) != Some("deploy start (node 3)"))
            .all(|e| e.get("tid").and_then(Json::as_f64) == Some(3.0)));
    }

    #[test]
    fn outstanding_counter_tracks_arrivals_completions_and_drops() {
        let events = vec![
            ev(1.0, 0, EngineEventKind::Arrival { id: 0 }),
            ev(2.0, 0, EngineEventKind::Arrival { id: 1 }),
            ev(
                5.0,
                0,
                EngineEventKind::Completion {
                    id: 0,
                    latency_ms: 4.0,
                },
            ),
            ev(
                9.0,
                0,
                EngineEventKind::Drop {
                    id: 1,
                    arrival_ms: 2.0,
                    degraded: false,
                },
            ),
        ];
        let doc = chrome_trace(&events);
        let evs = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let samples: Vec<f64> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("C"))
            .map(|e| {
                e.get("args")
                    .and_then(|a| a.get("requests"))
                    .and_then(Json::as_f64)
                    .unwrap()
            })
            .collect();
        assert_eq!(samples, vec![1.0, 2.0, 1.0, 0.0]);
    }

    #[test]
    fn unclosed_quarantine_is_closed_at_stream_end() {
        let events = vec![
            ev(5.0, 0, EngineEventKind::QuarantineEnter { node: 1 }),
            ev(
                40.0,
                0,
                EngineEventKind::Drop {
                    id: 7,
                    arrival_ms: 1.0,
                    degraded: false,
                },
            ),
        ];
        let doc = chrome_trace(&events);
        let evs = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let q = evs
            .iter()
            .find(|e| e.get("cat").and_then(Json::as_str) == Some("quarantine"))
            .expect("quarantine span");
        assert_eq!(q.get("ts").and_then(Json::as_f64), Some(5000.0));
        assert_eq!(q.get("dur").and_then(Json::as_f64), Some(35000.0));
    }
}
