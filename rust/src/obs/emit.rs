//! Shared JSON emission for experiment drivers and the CLI.
//!
//! Every driver used to hand-roll its own `std::fs::write(path,
//! json.to_string())`; this is the one place that decides how a result
//! lands on disk: consistent `--out` override handling, an opt-in
//! pretty-print flag, parent-directory creation, and a uniform
//! "wrote <path>" line.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Render a value compact (default) or pretty (`--pretty`).
pub fn render(value: &Json, pretty: bool) -> String {
    if pretty {
        value.to_pretty_string()
    } else {
        value.to_string()
    }
}

/// Write `value` to `path`, creating parent directories as needed.
pub fn write_json(path: &Path, value: &Json, pretty: bool) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    std::fs::write(path, render(value, pretty))
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// The drivers' shared `--out`/`--pretty` handling: write to `out`
/// when given, else to `default_path`; announce and return the
/// destination.
pub fn emit_json(
    value: &Json,
    default_path: &str,
    out: Option<&str>,
    pretty: bool,
) -> Result<PathBuf> {
    let path = PathBuf::from(out.unwrap_or(default_path));
    write_json(&path, value, pretty)?;
    println!("wrote {}", path.display());
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::obj;

    fn scratch(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("continuer-emit-{}-{name}", std::process::id()))
    }

    #[test]
    fn writes_compact_and_pretty() {
        let v = obj(&[("a", 1.0.into()), ("b", Json::Arr(vec![2.0.into()]))]);
        let dir = scratch("fmt");
        let compact = dir.join("nested/compact.json");
        write_json(&compact, &v, false).unwrap();
        assert_eq!(
            std::fs::read_to_string(&compact).unwrap(),
            r#"{"a":1,"b":[2]}"#
        );
        let pretty = dir.join("pretty.json");
        write_json(&pretty, &v, true).unwrap();
        let text = std::fs::read_to_string(&pretty).unwrap();
        assert!(text.contains("\n  \"a\": 1"));
        assert_eq!(Json::parse(&text).unwrap(), v);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn out_flag_overrides_default_path() {
        let v = obj(&[("x", true.into())]);
        let dir = scratch("out");
        let override_path = dir.join("override.json");
        let got = emit_json(
            &v,
            dir.join("default.json").to_str().unwrap(),
            override_path.to_str(),
            false,
        )
        .unwrap();
        assert_eq!(got, override_path);
        assert!(override_path.exists());
        assert!(!dir.join("default.json").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
