//! Modular reports over a replayed engine event stream.
//!
//! A [`ReportModule`] is a fold over [`EngineEvent`]s: `on_event` per
//! event, then `finish` renders the accumulated state as
//! [`Json`]. Experiment drivers compose modules over one recorded
//! stream (see [`replay`]) instead of each re-deriving counts from
//! `ServiceReport` internals — adding a new report means adding a
//! module, not a new driver.
//!
//! The modules here reproduce the numbers the legacy drivers computed
//! from report fields (drop attribution's inside/outside split,
//! detection-eval's downtime/false-failover counters); equivalence on
//! the same seed is asserted in the drivers' tests.

use std::collections::BTreeMap;

use crate::obs::{EngineEvent, EngineEventKind};
use crate::util::histogram::Streaming;
use crate::util::json::{obj, Json};

/// One composable report: a fold over the event stream.
pub trait ReportModule {
    /// Key under which [`ReportModule::finish`] lands in the replay
    /// output object.
    fn name(&self) -> &'static str;
    fn on_event(&mut self, ev: &EngineEvent);
    fn finish(&self) -> Json;
}

/// Drive every module over the stream once, then collect their
/// outputs into one object keyed by module name.
pub fn replay(events: &[EngineEvent], modules: &mut [Box<dyn ReportModule>]) -> Json {
    for ev in events {
        for m in modules.iter_mut() {
            m.on_event(ev);
        }
    }
    Json::Obj(
        modules
            .iter()
            .map(|m| (m.name().to_string(), m.finish()))
            .collect(),
    )
}

/// A drop is the outage's fault when the request's waiting interval
/// `[arrival, dropped_at)` overlapped an outage window (classifying on
/// the drop instant alone would leak a deadline-width of outage-caused
/// drops into "outside").
pub fn overlaps_outage(arrival_ms: f64, dropped_at_ms: f64, windows: &[(f64, f64)]) -> bool {
    windows
        .iter()
        .any(|&(s, e)| arrival_ms < e && dropped_at_ms >= s)
}

/// Classifies every drop against ground-truth outage windows and
/// streams completion latencies — the event-stream form of
/// `exper/drop_attribution.rs`.
#[derive(Debug, Default)]
pub struct DropAttribution {
    windows: Vec<(f64, f64)>,
    completed: usize,
    dropped_inside: usize,
    dropped_outside: usize,
    dropped_degraded: usize,
    latency: Streaming,
}

impl DropAttribution {
    pub fn new(outage_windows: Vec<(f64, f64)>) -> DropAttribution {
        DropAttribution {
            windows: outage_windows,
            ..DropAttribution::default()
        }
    }

    pub fn completed(&self) -> usize {
        self.completed
    }

    pub fn dropped_inside(&self) -> usize {
        self.dropped_inside
    }

    pub fn dropped_outside(&self) -> usize {
        self.dropped_outside
    }

    pub fn dropped_degraded(&self) -> usize {
        self.dropped_degraded
    }

    pub fn p99_ms(&self) -> f64 {
        self.latency.summary().p99
    }
}

impl ReportModule for DropAttribution {
    fn name(&self) -> &'static str {
        "drop_attribution"
    }

    fn on_event(&mut self, ev: &EngineEvent) {
        match ev.kind {
            EngineEventKind::Completion { latency_ms, .. } => {
                self.completed += 1;
                self.latency.record(latency_ms);
            }
            EngineEventKind::Drop {
                arrival_ms,
                degraded,
                ..
            } => {
                if overlaps_outage(arrival_ms, ev.at_ms, &self.windows) {
                    self.dropped_inside += 1;
                } else {
                    self.dropped_outside += 1;
                }
                if degraded {
                    self.dropped_degraded += 1;
                }
            }
            _ => {}
        }
    }

    fn finish(&self) -> Json {
        obj(&[
            ("completed", self.completed.into()),
            ("dropped_inside", self.dropped_inside.into()),
            ("dropped_outside", self.dropped_outside.into()),
            ("dropped_degraded", self.dropped_degraded.into()),
            ("p99_ms", self.p99_ms().into()),
        ])
    }
}

/// Failover/downtime accounting: window count, false positives,
/// summed modeled downtime, quarantine time, and — when configured
/// with the ground-truth crash — the true detection latency (first
/// correct failover on the crashed node at/after the crash instant).
#[derive(Debug, Default)]
pub struct Downtime {
    crash: Option<(usize, f64)>,
    failovers: usize,
    false_failovers: usize,
    total_downtime_ms: f64,
    detection_ms: Option<f64>,
    recoveries: usize,
    quarantine_open: BTreeMap<(usize, usize), f64>,
    quarantine_ms: f64,
    deploys: usize,
    deploy_stall_ms: f64,
}

impl Downtime {
    pub fn new() -> Downtime {
        Downtime::default()
    }

    /// Measure detection latency against a known crash of `node` at
    /// `at_ms`.
    pub fn with_crash(node: usize, at_ms: f64) -> Downtime {
        Downtime {
            crash: Some((node, at_ms)),
            ..Downtime::default()
        }
    }

    pub fn failovers(&self) -> usize {
        self.failovers
    }

    pub fn false_failovers(&self) -> usize {
        self.false_failovers
    }

    pub fn total_downtime_ms(&self) -> f64 {
        self.total_downtime_ms
    }

    pub fn detection_ms(&self) -> Option<f64> {
        self.detection_ms
    }

    /// Repartition deployments that cut over.
    pub fn deploys(&self) -> usize {
        self.deploys
    }

    /// Serving time stalled behind break-before-make deployments, ms.
    pub fn deploy_stall_ms(&self) -> f64 {
        self.deploy_stall_ms
    }
}

impl ReportModule for Downtime {
    fn name(&self) -> &'static str {
        "downtime"
    }

    fn on_event(&mut self, ev: &EngineEvent) {
        match ev.kind {
            EngineEventKind::Failover {
                node,
                false_positive,
                end_ms,
                ..
            } => {
                self.failovers += 1;
                if false_positive {
                    self.false_failovers += 1;
                }
                self.total_downtime_ms += end_ms - ev.at_ms;
                if let Some((crash_node, crash_at)) = self.crash {
                    if node == crash_node && !false_positive && ev.at_ms >= crash_at {
                        let d = ev.at_ms - crash_at;
                        self.detection_ms =
                            Some(self.detection_ms.map_or(d, |cur: f64| cur.min(d)));
                    }
                }
            }
            EngineEventKind::Recovery { .. } => self.recoveries += 1,
            // Deployment stalls are downtime the failover window does
            // not carry: under break-before-make the replica serves
            // nothing until the cut-over, and the Cutover event reports
            // exactly that stall.
            EngineEventKind::Cutover { stalled_ms, .. } => {
                self.deploys += 1;
                self.deploy_stall_ms += stalled_ms;
            }
            EngineEventKind::QuarantineEnter { node } => {
                self.quarantine_open.insert((ev.replica, node), ev.at_ms);
            }
            EngineEventKind::QuarantineExit { node } => {
                if let Some(start) = self.quarantine_open.remove(&(ev.replica, node)) {
                    self.quarantine_ms += ev.at_ms - start;
                }
            }
            _ => {}
        }
    }

    fn finish(&self) -> Json {
        obj(&[
            ("failovers", self.failovers.into()),
            ("false_failovers", self.false_failovers.into()),
            ("total_downtime_ms", self.total_downtime_ms.into()),
            (
                "detection_ms",
                self.detection_ms.map_or(Json::Null, Json::from),
            ),
            ("recoveries", self.recoveries.into()),
            ("quarantine_ms", self.quarantine_ms.into()),
            ("deploys", self.deploys.into()),
            ("deploy_stall_ms", self.deploy_stall_ms.into()),
        ])
    }
}

/// End-to-end completion-latency summary (streamed, O(1) memory).
#[derive(Debug, Default)]
pub struct LatencySummary {
    latency: Streaming,
}

impl LatencySummary {
    pub fn new() -> LatencySummary {
        LatencySummary::default()
    }
}

impl ReportModule for LatencySummary {
    fn name(&self) -> &'static str {
        "latency"
    }

    fn on_event(&mut self, ev: &EngineEvent) {
        if let EngineEventKind::Completion { latency_ms, .. } = ev.kind {
            self.latency.record(latency_ms);
        }
    }

    fn finish(&self) -> Json {
        let s = self.latency.summary();
        obj(&[
            ("n", s.n.into()),
            ("mean_ms", s.mean.into()),
            ("std_ms", s.std.into()),
            ("min_ms", s.min.into()),
            ("p50_ms", s.p50.into()),
            ("p95_ms", s.p95.into()),
            ("p99_ms", s.p99.into()),
            ("max_ms", s.max.into()),
        ])
    }
}

/// Raw event-kind counts — cheap sanity check that a stream contains
/// what a scenario promises (and a smoke signal when it doesn't).
#[derive(Debug, Default)]
pub struct EventCounts {
    counts: BTreeMap<&'static str, usize>,
}

impl EventCounts {
    pub fn new() -> EventCounts {
        EventCounts::default()
    }
}

fn kind_key(kind: &EngineEventKind) -> &'static str {
    match kind {
        EngineEventKind::Arrival { .. } => "arrival",
        EngineEventKind::BatchDispatch { .. } => "batch_dispatch",
        EngineEventKind::StageStart { .. } => "stage_start",
        EngineEventKind::StageDone { .. } => "stage_done",
        EngineEventKind::Condition { .. } => "condition",
        EngineEventKind::Failover { .. } => "failover",
        EngineEventKind::Recovery { .. } => "recovery",
        EngineEventKind::QuarantineEnter { .. } => "quarantine_enter",
        EngineEventKind::QuarantineExit { .. } => "quarantine_exit",
        EngineEventKind::Drop { .. } => "drop",
        EngineEventKind::Completion { .. } => "completion",
        EngineEventKind::DeployStart { .. } => "deploy_start",
        EngineEventKind::TransferDone { .. } => "transfer_done",
        EngineEventKind::WarmupDone { .. } => "warmup_done",
        EngineEventKind::Cutover { .. } => "cutover",
    }
}

impl ReportModule for EventCounts {
    fn name(&self) -> &'static str {
        "event_counts"
    }

    fn on_event(&mut self, ev: &EngineEvent) {
        *self.counts.entry(kind_key(&ev.kind)).or_insert(0) += 1;
    }

    fn finish(&self) -> Json {
        Json::Obj(
            self.counts
                .iter()
                .map(|(k, v)| (k.to_string(), Json::from(*v)))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::variants::Technique;

    fn ev(at_ms: f64, kind: EngineEventKind) -> EngineEvent {
        EngineEvent {
            at_ms,
            replica: 0,
            kind,
        }
    }

    #[test]
    fn drop_attribution_splits_inside_and_outside() {
        let mut m = DropAttribution::new(vec![(100.0, 200.0)]);
        // Arrived before the window, dropped inside it: inside.
        m.on_event(&ev(
            150.0,
            EngineEventKind::Drop {
                id: 0,
                arrival_ms: 90.0,
                degraded: false,
            },
        ));
        // Arrived inside, dropped after recovery: still inside.
        m.on_event(&ev(
            230.0,
            EngineEventKind::Drop {
                id: 1,
                arrival_ms: 190.0,
                degraded: true,
            },
        ));
        // Entirely after the window: outside.
        m.on_event(&ev(
            400.0,
            EngineEventKind::Drop {
                id: 2,
                arrival_ms: 300.0,
                degraded: false,
            },
        ));
        m.on_event(&ev(
            50.0,
            EngineEventKind::Completion {
                id: 3,
                latency_ms: 12.0,
            },
        ));
        assert_eq!(m.dropped_inside(), 2);
        assert_eq!(m.dropped_outside(), 1);
        assert_eq!(m.dropped_degraded(), 1);
        assert_eq!(m.completed(), 1);
    }

    #[test]
    fn downtime_measures_first_true_detection() {
        let mut m = Downtime::with_crash(3, 400.0);
        m.on_event(&ev(
            350.0,
            EngineEventKind::Failover {
                node: 3,
                technique: Technique::Repartition,
                false_positive: true,
                end_ms: 360.0,
            },
        ));
        m.on_event(&ev(
            425.0,
            EngineEventKind::Failover {
                node: 3,
                technique: Technique::EarlyExit(2),
                false_positive: false,
                end_ms: 440.0,
            },
        ));
        assert_eq!(m.failovers(), 2);
        assert_eq!(m.false_failovers(), 1);
        assert_eq!(m.detection_ms(), Some(25.0));
        assert!((m.total_downtime_ms() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn replay_collects_module_outputs_by_name() {
        let events = vec![ev(
            10.0,
            EngineEventKind::Completion {
                id: 0,
                latency_ms: 5.0,
            },
        )];
        let mut modules: Vec<Box<dyn ReportModule>> = vec![
            Box::new(LatencySummary::new()),
            Box::new(EventCounts::new()),
        ];
        let out = replay(&events, &mut modules);
        assert_eq!(out.path("latency.n").and_then(Json::as_usize), Some(1));
        assert_eq!(
            out.path("event_counts.completion").and_then(Json::as_usize),
            Some(1)
        );
    }
}
