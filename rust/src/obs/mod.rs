//! # Engine observability: event bus, trace export, modular reports.
//!
//! At million-event scale the aggregate histograms in `ServiceReport`
//! hide the 50 ms that matter: which stage stalled during a failover,
//! how long a quarantine window actually gated reintegration, where
//! the cut-over gap sits inside a repartition. This module makes the
//! engine's internal timeline a first-class, replayable stream.
//!
//! Three layers:
//!
//! 1. **Event bus** ([`EngineEvent`] + [`EventSink`]): the serving
//!    engine in `coordinator/engine.rs` emits one event per observable
//!    transition — request arrival, batch dispatch, stage start/done,
//!    raw node-condition change, detected failover/recovery,
//!    quarantine enter/exit, deadline drop, request completion, and the
//!    repartition-deployment state machine (deploy start, per-node
//!    transfer/warm-up completion, cut-over). The
//!    engine is generic over the sink (monomorphized, never boxed), so
//!    the default [`NoopSink`] is genuinely zero-cost: its `on_event`
//!    is an empty `#[inline(always)]` body and the dead event
//!    construction is eliminated by the optimizer, preserving the
//!    zero-allocation steady state from PR 3. Under
//!    `Execution::Sharded` each shard buffers its own events and the
//!    merge re-tags replica ids and time-sorts, so the merged stream
//!    has stable track identities.
//! 2. **Trace export** ([`trace`]): serializes a recorded stream as
//!    Chrome `trace_event` JSON — one track per (replica, node) with
//!    stage spans as `ph:"X"` duration events, failover windows and
//!    detection instants on a per-replica controller track, quarantine
//!    windows as spans — loadable in `chrome://tracing` or
//!    <https://ui.perfetto.dev> (File → Open trace file).
//! 3. **Modular reports** ([`report`]): a [`report::ReportModule`]
//!    trait (`on_event` + `finish -> Json`) and a replay driver, so
//!    experiment summaries (drop attribution, downtime/failover,
//!    latency) are composable subscribers over one stream instead of
//!    bespoke per-driver aggregation.
//!
//! [`emit`] rounds this out with the shared JSON emission helper
//! (`--out` / pretty-print handling) used by every experiment driver.

pub mod emit;
pub mod report;
pub mod trace;

use crate::cluster::failure::NodeCondition;
use crate::dnn::variants::Technique;

/// One observable engine transition, stamped with simulation time and
/// the replica it happened on. `Copy` so sinks can buffer by value
/// without touching the allocator per event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EngineEvent {
    /// Simulation timestamp in milliseconds.
    pub at_ms: f64,
    /// Replica the event belongs to (re-tagged to the global id when
    /// sharded per-replica streams are merged).
    pub replica: usize,
    pub kind: EngineEventKind,
}

/// The engine's event taxonomy. Every variant corresponds to exactly
/// one emission site in `coordinator/engine.rs`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EngineEventKind {
    /// A request entered a replica's queue (after routing).
    Arrival { id: usize },
    /// The batcher cut a batch and put its first stage on the heap.
    /// `seq` is the per-replica dispatch ordinal; `size` the real
    /// request count; `target` the padded batch size.
    BatchDispatch { seq: usize, size: usize, target: usize },
    /// A stage actually began computing on `node` (occupancy granted).
    StageStart { batch_seq: usize, stage: usize, node: usize },
    /// The stage finished on `node`; `stage` matches its `StageStart`.
    StageDone { batch_seq: usize, stage: usize, node: usize },
    /// Ground-truth node condition changed (failure injection), before
    /// any detector sees it.
    Condition { node: usize, condition: NodeCondition },
    /// The health layer declared `node` failed and the failover chose
    /// `technique`; the modeled cut-over blackout ends at `end_ms`.
    Failover {
        node: usize,
        technique: Technique,
        false_positive: bool,
        end_ms: f64,
    },
    /// The health layer reinstated `node` and the failover mode
    /// actually cleared (rollback to the full pipeline).
    Recovery { node: usize },
    /// `node` is back up but still held out of the serving path
    /// (failover mode active) — the reintegration gate is working.
    QuarantineEnter { node: usize },
    /// The gate released: emitted immediately before [`Recovery`].
    QuarantineExit { node: usize },
    /// A request was dropped (deadline expiry or wedged at run end).
    Drop {
        id: usize,
        arrival_ms: f64,
        degraded: bool,
    },
    /// A request completed end-to-end.
    Completion { id: usize, latency_ms: f64 },
    /// A repartition deployment began after `node` failed: the new
    /// partition's weights start moving toward `transfers` hosts and
    /// the cut-over is projected for `cutover_ms`. `make_before_break`
    /// says whether the replica keeps serving through the window on a
    /// fallback technique (else it stalls, break-before-make).
    DeployStart {
        node: usize,
        make_before_break: bool,
        transfers: usize,
        cutover_ms: f64,
    },
    /// One host finished receiving the weights of the units re-hosted
    /// onto it.
    TransferDone { node: usize },
    /// One host finished warming the units it received.
    WarmupDone { node: usize },
    /// The deployment went live: dispatch switched to the repartitioned
    /// plan atomically (in-flight fallback batches drain untouched).
    /// `stalled_ms` is how long serving was stalled waiting for it
    /// (zero under make-before-break with a feasible fallback).
    Cutover { node: usize, stalled_ms: f64 },
}

/// Receiver for the engine's event stream. The engine is generic over
/// the sink, so implementations are monomorphized into the event loop:
/// an empty `on_event` costs nothing.
pub trait EventSink: Send {
    fn on_event(&mut self, ev: &EngineEvent);

    /// Whether this sink observes events at all. Sharded execution
    /// consults this before paying for per-shard buffering; `false`
    /// keeps the merged run allocation-free.
    fn wants_events(&self) -> bool {
        true
    }

    /// Drain any buffered events (used by the sharded merge). Sinks
    /// that don't buffer return an empty vec.
    fn take_events(&mut self) -> Vec<EngineEvent> {
        Vec::new()
    }
}

/// The zero-cost default sink: drops every event at compile time.
#[derive(Debug, Default, Clone, Copy)]
pub struct NoopSink;

impl EventSink for NoopSink {
    #[inline(always)]
    fn on_event(&mut self, _ev: &EngineEvent) {}

    #[inline(always)]
    fn wants_events(&self) -> bool {
        false
    }
}

/// A recording sink: buffers every event in order. The sequential
/// engine streams into one directly; sharded runs stream through
/// [`ChannelSink`]s instead, so no shard buffers a whole run.
#[derive(Debug, Default)]
pub struct EventBuffer {
    pub events: Vec<EngineEvent>,
}

impl EventSink for EventBuffer {
    #[inline]
    fn on_event(&mut self, ev: &EngineEvent) {
        self.events.push(*ev);
    }

    fn take_events(&mut self) -> Vec<EngineEvent> {
        std::mem::take(&mut self.events)
    }
}

/// Bound on the sharded streaming channel: deep enough that shards
/// rarely block on the drain thread, small enough that a recording run
/// stays O(1) in in-flight events instead of buffering whole shards.
pub const EVENT_CHANNEL_CAP: usize = 8192;

/// The sharded engine's streaming sink: each shard owns one, re-tags
/// its events from the shard-local replica 0 to the global replica id,
/// and sends them over a bounded channel that the *caller* thread
/// drains while the shards run (backpressure, not whole-run buffering —
/// the follow-up PR 5 left open). A full channel blocks the emitting
/// shard until the drain catches up; a dropped receiver silently
/// discards, so a failing run still unwinds cleanly.
///
/// Per-sender FIFO order is guaranteed by the channel, so the drain can
/// bucket received events by replica and recover exactly the
/// deterministic order the old per-shard buffers merged to: concatenate
/// buckets in replica order, then stable-sort by timestamp.
#[derive(Debug)]
pub struct ChannelSink {
    tx: std::sync::mpsc::SyncSender<EngineEvent>,
    replica: usize,
}

impl ChannelSink {
    pub fn new(tx: std::sync::mpsc::SyncSender<EngineEvent>, replica: usize) -> ChannelSink {
        ChannelSink { tx, replica }
    }
}

impl EventSink for ChannelSink {
    #[inline]
    fn on_event(&mut self, ev: &EngineEvent) {
        let _ = self.tx.send(EngineEvent {
            replica: self.replica,
            ..*ev
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_sink_wants_nothing() {
        let mut s = NoopSink;
        assert!(!s.wants_events());
        s.on_event(&EngineEvent {
            at_ms: 0.0,
            replica: 0,
            kind: EngineEventKind::Arrival { id: 0 },
        });
        assert!(s.take_events().is_empty());
    }

    #[test]
    fn channel_sink_retags_and_streams_in_send_order() {
        let (tx, rx) = std::sync::mpsc::sync_channel(EVENT_CHANNEL_CAP);
        let mut a = ChannelSink::new(tx.clone(), 3);
        let mut b = ChannelSink::new(tx, 5);
        for i in 0..3 {
            // Shard-local events always carry replica 0.
            let ev = EngineEvent {
                at_ms: i as f64,
                replica: 0,
                kind: EngineEventKind::Arrival { id: i },
            };
            a.on_event(&ev);
            b.on_event(&ev);
        }
        drop(a);
        drop(b);
        let got: Vec<EngineEvent> = rx.iter().collect();
        assert_eq!(got.len(), 6);
        // Re-tagged to the global replica id, per-sender order intact.
        for r in [3usize, 5] {
            let times: Vec<f64> = got
                .iter()
                .filter(|e| e.replica == r)
                .map(|e| e.at_ms)
                .collect();
            assert_eq!(times, vec![0.0, 1.0, 2.0]);
        }
    }

    #[test]
    fn channel_sink_survives_dropped_receiver() {
        let (tx, rx) = std::sync::mpsc::sync_channel(4);
        drop(rx);
        let mut s = ChannelSink::new(tx, 0);
        // Must not panic or block: a failing run unwinds past the sink.
        s.on_event(&EngineEvent {
            at_ms: 0.0,
            replica: 0,
            kind: EngineEventKind::Arrival { id: 0 },
        });
    }

    #[test]
    fn buffer_records_in_order_and_drains() {
        let mut b = EventBuffer::default();
        for i in 0..4 {
            b.on_event(&EngineEvent {
                at_ms: i as f64,
                replica: 0,
                kind: EngineEventKind::Arrival { id: i },
            });
        }
        assert!(b.wants_events());
        let evs = b.take_events();
        assert_eq!(evs.len(), 4);
        assert!(evs.windows(2).all(|w| w[0].at_ms <= w[1].at_ms));
        assert!(b.take_events().is_empty());
    }
}
