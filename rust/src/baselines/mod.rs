//! Baseline recovery policies the evaluation compares CONTINUER against:
//! fixed single-technique policies and a SEE-like early-exit-only policy
//! (Wang et al. [30], which always exits during outages).
//!
//! Every baseline implements [`RecoveryPolicy`], the same trait CONTINUER
//! itself implements, so a baseline plugs into the serving engine via
//! `Failover::with_policy` and the comparison runs inside the identical
//! event loop rather than a per-policy reimplementation.

use anyhow::Result;

use crate::config::Objectives;
use crate::coordinator::scheduler::{CandidateMetrics, Decision};
use crate::dnn::variants::Technique;

pub use crate::coordinator::policy::{Continuer, RecoveryPolicy};

/// Backwards-compatible alias: the trait used to live here.
pub use crate::coordinator::policy::RecoveryPolicy as Policy;

fn find_kind(candidates: &[CandidateMetrics], kind: &str) -> Option<Technique> {
    candidates
        .iter()
        .map(|c| c.technique)
        .find(|t| t.kind_name() == kind)
}

/// Always repartition (the traditional recovery; always feasible).
pub struct AlwaysRepartition;

impl RecoveryPolicy for AlwaysRepartition {
    fn name(&self) -> &'static str {
        "always-repartition"
    }

    fn decide(&self, candidates: &[CandidateMetrics]) -> Result<Decision> {
        find_kind(candidates, "repartition")
            .map(Decision::fixed)
            .ok_or_else(|| anyhow::anyhow!("repartition missing from candidates"))
    }
}

/// Always early-exit when possible, else repartition (SEE-like).
pub struct AlwaysEarlyExit;

impl RecoveryPolicy for AlwaysEarlyExit {
    fn name(&self) -> &'static str {
        "always-early-exit"
    }

    fn decide(&self, candidates: &[CandidateMetrics]) -> Result<Decision> {
        find_kind(candidates, "early-exit")
            .or_else(|| find_kind(candidates, "repartition"))
            .map(Decision::fixed)
            .ok_or_else(|| anyhow::anyhow!("no feasible technique"))
    }
}

/// Always skip when possible, else repartition (DeepFogGuard-like).
pub struct AlwaysSkip;

impl RecoveryPolicy for AlwaysSkip {
    fn name(&self) -> &'static str {
        "always-skip"
    }

    fn decide(&self, candidates: &[CandidateMetrics]) -> Result<Decision> {
        find_kind(candidates, "skip-connection")
            .or_else(|| find_kind(candidates, "repartition"))
            .map(Decision::fixed)
            .ok_or_else(|| anyhow::anyhow!("no feasible technique"))
    }
}

/// All baselines plus CONTINUER under the given objectives.
pub fn all_policies(objectives: Objectives) -> Vec<Box<dyn RecoveryPolicy>> {
    vec![
        Box::new(Continuer(objectives)),
        Box::new(AlwaysRepartition),
        Box::new(AlwaysEarlyExit),
        Box::new(AlwaysSkip),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cands() -> Vec<CandidateMetrics> {
        vec![
            CandidateMetrics {
                technique: Technique::Repartition,
                accuracy: 90.0,
                latency_ms: 30.0,
                downtime_ms: 4.0,
            },
            CandidateMetrics {
                technique: Technique::EarlyExit(3),
                accuracy: 70.0,
                latency_ms: 8.0,
                downtime_ms: 1.0,
            },
            CandidateMetrics {
                technique: Technique::SkipConnection(4),
                accuracy: 85.0,
                latency_ms: 25.0,
                downtime_ms: 3.0,
            },
        ]
    }

    #[test]
    fn fixed_policies_pick_their_kind() {
        assert_eq!(
            AlwaysRepartition.decide(&cands()).unwrap().chosen,
            Technique::Repartition
        );
        assert_eq!(
            AlwaysEarlyExit.decide(&cands()).unwrap().chosen,
            Technique::EarlyExit(3)
        );
        assert_eq!(
            AlwaysSkip.decide(&cands()).unwrap().chosen,
            Technique::SkipConnection(4)
        );
    }

    #[test]
    fn fallback_to_repartition() {
        let only_rep = vec![cands()[0]];
        assert_eq!(
            AlwaysEarlyExit.decide(&only_rep).unwrap().chosen,
            Technique::Repartition
        );
        assert_eq!(
            AlwaysSkip.decide(&only_rep).unwrap().chosen,
            Technique::Repartition
        );
    }

    #[test]
    fn fixed_decisions_carry_no_scores() {
        let d = AlwaysRepartition.decide(&cands()).unwrap();
        assert!(d.scores.is_empty());
    }

    #[test]
    fn continuer_uses_weights() {
        let p = Continuer(Objectives::new(0.05, 0.9, 0.05));
        assert_eq!(p.decide(&cands()).unwrap().chosen, Technique::EarlyExit(3));
    }

    #[test]
    fn all_policies_have_unique_names() {
        let ps = all_policies(Objectives::default());
        let mut names: Vec<&str> = ps.iter().map(|p| p.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 4);
    }
}
