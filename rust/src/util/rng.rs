//! Deterministic PRNG (the `rand` crate is not in the offline vendor set).
//!
//! `Rng` is xoshiro256++ seeded via SplitMix64 — fast, high quality, and
//! fully reproducible across platforms, which the experiment harness relies
//! on (every table/figure regenerates bit-identically for a given seed).

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // SplitMix64 expansion of the seed into the state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire's multiply-shift, good enough without rejection for our
        // non-cryptographic uses.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform i64 in [lo, hi].
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Derive an independent stream (for per-thread / per-component rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Derive stream `stream_id` *without* advancing this generator:
    /// unlike [`Rng::fork`], the same `(parent state, stream_id)` pair
    /// always yields the same stream, and deriving streams in any order
    /// (or in parallel from clones) yields the same family. This is what
    /// lets per-replica workload schedules stay byte-identical whether
    /// they are generated for one sequential engine or for `R` shards.
    pub fn derive(&self, stream_id: u64) -> Rng {
        let mix = self.s[0]
            ^ self.s[1].rotate_left(13)
            ^ self.s[2].rotate_left(29)
            ^ self.s[3].rotate_left(43);
        Rng::new(mix ^ stream_id.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(13);
        let n = 20000;
        let mean = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.03, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn derive_is_pure_and_stream_distinct() {
        let parent = Rng::new(42);
        let before = parent.clone();
        let mut a1 = parent.derive(3);
        let mut a2 = parent.derive(3);
        let mut b = parent.derive(4);
        // Same stream id twice: identical stream; parent untouched.
        for _ in 0..50 {
            assert_eq!(a1.next_u64(), a2.next_u64());
        }
        assert_eq!(before.clone().next_u64(), parent.clone().next_u64());
        // Distinct ids: distinct streams (and distinct from the parent).
        let mut a = parent.derive(3);
        assert_ne!(a.next_u64(), b.next_u64());
        assert_ne!(parent.derive(0).next_u64(), parent.clone().next_u64());
    }

    #[test]
    fn derive_order_independent() {
        let parent = Rng::new(7);
        // Deriving 2 then 5 equals deriving 5 then 2: no hidden state.
        let mut a = parent.derive(2);
        let _ = parent.derive(5);
        let mut b = parent.derive(2);
        for _ in 0..20 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let s = r.sample_indices(20, 10);
        assert_eq!(s.len(), 10);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 10);
    }
}
