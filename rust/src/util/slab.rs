//! Generational slab: O(1) keyed storage with free-list reuse, for
//! hot-path collections whose keys are minted and retired millions of
//! times per run (the engine's in-flight batches). Unlike a `HashMap`
//! there is no hashing on access, no rehash-driven reallocation in
//! steady state, and a retired key can never alias a later value: every
//! removal bumps the slot's generation, so stale keys simply miss.

/// Handle into a [`Slab`]: slot index plus the generation it was minted
/// under. `Copy`, and safe to hold across removals — a key whose slot was
/// recycled no longer resolves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlabKey {
    index: u32,
    generation: u32,
}

#[derive(Debug)]
struct Slot<T> {
    generation: u32,
    value: Option<T>,
}

/// The slab itself. Capacity grows to the peak concurrent population and
/// is reused thereafter (the free list hands back vacated slots).
#[derive(Debug, Default)]
pub struct Slab<T> {
    slots: Vec<Slot<T>>,
    free: Vec<u32>,
    len: usize,
}

impl<T> Slab<T> {
    pub fn new() -> Slab<T> {
        Slab {
            slots: Vec::new(),
            free: Vec::new(),
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of slots ever allocated (the peak concurrent population).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn insert(&mut self, value: T) -> SlabKey {
        self.len += 1;
        if let Some(index) = self.free.pop() {
            let slot = &mut self.slots[index as usize];
            debug_assert!(slot.value.is_none(), "free-listed slot still occupied");
            slot.value = Some(value);
            SlabKey {
                index,
                generation: slot.generation,
            }
        } else {
            let index = u32::try_from(self.slots.len()).expect("slab index overflow");
            self.slots.push(Slot {
                generation: 0,
                value: Some(value),
            });
            SlabKey {
                index,
                generation: 0,
            }
        }
    }

    pub fn get(&self, key: SlabKey) -> Option<&T> {
        let slot = self.slots.get(key.index as usize)?;
        if slot.generation != key.generation {
            return None;
        }
        slot.value.as_ref()
    }

    pub fn get_mut(&mut self, key: SlabKey) -> Option<&mut T> {
        let slot = self.slots.get_mut(key.index as usize)?;
        if slot.generation != key.generation {
            return None;
        }
        slot.value.as_mut()
    }

    /// Remove and return the value, retiring the key: the slot's
    /// generation advances so the same `SlabKey` can never resolve again.
    pub fn remove(&mut self, key: SlabKey) -> Option<T> {
        let slot = self.slots.get_mut(key.index as usize)?;
        if slot.generation != key.generation {
            return None;
        }
        let value = slot.value.take()?;
        slot.generation = slot.generation.wrapping_add(1);
        self.free.push(key.index);
        self.len -= 1;
        Some(value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut s: Slab<String> = Slab::new();
        let a = s.insert("a".into());
        let b = s.insert("b".into());
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(a).unwrap(), "a");
        assert_eq!(s.get(b).unwrap(), "b");
        assert_eq!(s.remove(a).unwrap(), "a");
        assert_eq!(s.len(), 1);
        assert!(s.get(a).is_none());
        assert!(s.remove(a).is_none(), "double remove misses");
    }

    #[test]
    fn slots_are_reused_and_stale_keys_miss() {
        let mut s: Slab<u32> = Slab::new();
        let a = s.insert(1);
        s.remove(a);
        let b = s.insert(2);
        // Same physical slot, new generation.
        assert_eq!(s.capacity(), 1);
        assert!(s.get(a).is_none(), "stale key must not alias the new value");
        assert_eq!(*s.get(b).unwrap(), 2);
    }

    #[test]
    fn capacity_tracks_peak_not_total() {
        let mut s: Slab<usize> = Slab::new();
        for round in 0..100 {
            let k1 = s.insert(round);
            let k2 = s.insert(round + 1);
            s.remove(k1);
            s.remove(k2);
        }
        assert_eq!(s.capacity(), 2, "steady state reuses two slots");
        assert!(s.is_empty());
    }

    #[test]
    fn get_mut_updates_in_place() {
        let mut s: Slab<Vec<u32>> = Slab::new();
        let k = s.insert(vec![1]);
        s.get_mut(k).unwrap().push(2);
        assert_eq!(s.remove(k).unwrap(), vec![1, 2]);
    }
}
