//! Mini property-based testing framework (the `proptest` crate is not in
//! the offline vendor set).
//!
//! Usage:
//! ```ignore
//! check(100, seed, |g| {
//!     let xs = g.vec_f64(0.0, 100.0, 1..50);
//!     let norm = min_max_normalize(&xs);
//!     prop_assert(norm.iter().all(|v| (0.0..=1.0).contains(v)), "in range")
//! });
//! ```
//!
//! On failure the framework performs greedy input-level shrinking: the
//! failing case's generator trace is replayed with halved sizes/values
//! where possible, and the smallest still-failing seed is reported.

use super::rng::Rng;

/// Outcome of one property evaluation.
pub type PropResult = Result<(), String>;

pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

pub fn prop_assert_eq<T: PartialEq + std::fmt::Debug>(a: T, b: T) -> PropResult {
    if a == b {
        Ok(())
    } else {
        Err(format!("expected {a:?} == {b:?}"))
    }
}

/// Close-to comparison for floats.
pub fn prop_assert_close(a: f64, b: f64, tol: f64) -> PropResult {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("|{a} - {b}| > {tol}"))
    }
}

/// Generator handle passed to properties. `size` scales collection sizes
/// during shrinking (1.0 = full size).
pub struct Gen {
    rng: Rng,
    size: f64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Gen {
        Gen {
            rng: Rng::new(seed),
            size,
        }
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        // During shrinking bias toward lo.
        lo + self.rng.f64() * (hi - lo) * self.size
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = ((hi - lo) as f64 * self.size).round() as usize;
        lo + if span == 0 { 0 } else { self.rng.below(span + 1) }
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn vec_f64(&mut self, lo: f64, hi: f64, len: std::ops::Range<usize>) -> Vec<f64> {
        let n = self.usize(len.start, len.end.saturating_sub(1).max(len.start));
        (0..n).map(|_| self.f64(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, lo: usize, hi: usize, len: std::ops::Range<usize>) -> Vec<usize> {
        let n = self.usize(len.start, len.end.saturating_sub(1).max(len.start));
        (0..n).map(|_| self.usize(lo, hi)).collect()
    }

    /// Raw access for custom generators.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` random cases of the property. Panics (test failure) on the
/// first failing case after shrinking, reporting the seed for replay.
pub fn check<F: Fn(&mut Gen) -> PropResult>(cases: u64, seed: u64, prop: F) {
    for case in 0..cases {
        let case_seed = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case);
        let mut g = Gen::new(case_seed, 1.0);
        if let Err(msg) = prop(&mut g) {
            // Shrink: retry with progressively smaller `size` and keep the
            // smallest size that still fails.
            let mut fail_size = 1.0;
            let mut fail_msg = msg;
            for k in 1..=6 {
                let size = 1.0 / (1 << k) as f64;
                let mut g = Gen::new(case_seed, size);
                if let Err(m) = prop(&mut g) {
                    fail_size = size;
                    fail_msg = m;
                }
            }
            panic!(
                "property failed (case {case}, seed {case_seed}, size {fail_size}): {fail_msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(50, 1, |g| {
            let xs = g.vec_f64(0.0, 10.0, 1..20);
            prop_assert(xs.iter().all(|x| (0.0..=10.0).contains(x)), "bounds")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(50, 2, |g| {
            let x = g.f64(0.0, 10.0);
            prop_assert(x < 5.0, "x too big")
        });
    }

    #[test]
    fn gen_usize_bounds() {
        check(100, 3, |g| {
            let v = g.usize(2, 8);
            prop_assert((2..=8).contains(&v), "usize bounds")
        });
    }

    #[test]
    fn assert_helpers() {
        assert!(prop_assert_eq(1, 1).is_ok());
        assert!(prop_assert_eq(1, 2).is_err());
        assert!(prop_assert_close(1.0, 1.0005, 1e-3).is_ok());
        assert!(prop_assert_close(1.0, 2.0, 1e-3).is_err());
    }
}
