//! Minimal JSON parser / writer.
//!
//! The offline vendor set has no `serde`/`serde_json`, so the manifest and
//! config loaders use this from-scratch implementation. It supports the
//! full JSON grammar (objects, arrays, strings with escapes, numbers,
//! booleans, null) and preserves numbers as f64, which is sufficient for
//! every artifact the python side emits.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----- parsing --------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn from_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?)
    }

    // ----- typed accessors --------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access: keys separated by '.'.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = match cur {
                Json::Obj(m) => m.get(part)?,
                Json::Arr(a) => a.get(part.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<f64> (None if any element is not a number).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ----- writing ----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Human-readable form: 2-space indentation, one element per line,
    /// same escaping and number formatting as [`Json::to_string`].
    pub fn to_pretty_string(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            // Scalars and empty containers render as in compact mode.
            other => other.write(out),
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}

impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}

impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}

impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}

impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Convenience builder for objects: `obj(&[("a", 1.0.into())])`.
pub fn obj(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(
        pairs
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect(),
    )
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.i,
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            // python json.dumps can emit these for float('inf')/nan
            Some(b'N') => self.lit("NaN", Json::Num(f64::NAN)),
            Some(b'I') => self.lit("Infinity", Json::Num(f64::INFINITY)),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Handle surrogate pairs.
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.b.len() < self.i + 11
                                    || self.b[self.i + 5] != b'\\'
                                    || self.b[self.i + 6] != b'u'
                                {
                                    return Err(self.err("lone surrogate"));
                                }
                                let hex2 =
                                    std::str::from_utf8(&self.b[self.i + 7..self.i + 11])
                                        .map_err(|_| self.err("bad surrogate"))?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| self.err("bad surrogate"))?;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                s.push(
                                    char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?,
                                );
                                self.i += 6;
                            } else {
                                s.push(
                                    char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                                );
                            }
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
            if self.peek() == Some(b'I') {
                return self.lit("Infinity", Json::Num(f64::NEG_INFINITY));
            }
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(Json::parse("-12").unwrap(), Json::Num(-12.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(
            Json::parse("\"hi\\nthere\"").unwrap(),
            Json::Str("hi\nthere".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.path("a.2.b").unwrap().as_str(), Some("c"));
        assert_eq!(v.get("d"), Some(&Json::Null));
        assert_eq!(v.path("a.0").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn parse_unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
    }

    #[test]
    fn pretty_roundtrips_and_indents() {
        let v = Json::parse(r#"{"arr":[1,2],"empty":{},"s":"x"}"#).unwrap();
        let pretty = v.to_pretty_string();
        assert!(pretty.contains("\n  \"arr\": [\n    1,\n    2\n  ]"));
        assert!(pretty.contains("\"empty\": {}"));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"arr":[1,2.5,"x"],"nested":{"t":true,"n":null},"s":"a\"b"}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{'a': 1}").is_err());
    }

    #[test]
    fn python_special_floats() {
        assert!(Json::parse("NaN").unwrap().as_f64().unwrap().is_nan());
        assert_eq!(
            Json::parse("[-Infinity, Infinity]").unwrap().as_f64_vec(),
            Some(vec![f64::NEG_INFINITY, f64::INFINITY])
        );
    }

    #[test]
    fn typed_vec_accessors() {
        let v = Json::parse("[3, 4, 5]").unwrap();
        assert_eq!(v.as_usize_vec(), Some(vec![3, 4, 5]));
        assert_eq!(Json::parse("[3, \"x\"]").unwrap().as_f64_vec(), None);
    }
}
