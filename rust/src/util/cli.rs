//! Tiny command-line parser (no `clap` in the offline vendor set).
//!
//! Supports `binary <subcommand> [--flag] [--key value] [positional...]`.
//! Flags may be given as `--key value` or `--key=value`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1).collect())
    }

    pub fn parse(raw: Vec<String>) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(body.to_string(), v);
                } else {
                    args.flags.push(body.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from).collect())
    }

    #[test]
    fn subcommand_and_positional() {
        let a = p("serve extra1 extra2");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn options_both_styles() {
        let a = p("exp table7 --model resnet32 --seed=42");
        assert_eq!(a.get("model"), Some("resnet32"));
        assert_eq!(a.get("seed"), Some("42"));
        assert_eq!(a.positional, vec!["table7"]);
    }

    #[test]
    fn trailing_flag() {
        let a = p("serve --verbose");
        assert!(a.flag("verbose"));
        assert_eq!(a.get("verbose"), None);
    }

    #[test]
    fn flag_followed_by_option() {
        // --verbose takes the next token because it doesn't start with --;
        // that's the documented `--key value` behaviour.
        let a = p("run --dry-run --n 5");
        assert!(a.flag("dry-run"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 5);
    }

    #[test]
    fn typed_accessors() {
        let a = p("x --n 5 --rate 0.25 --bad abc");
        assert_eq!(a.get_usize("n", 1).unwrap(), 5);
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 0.25);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(a.get_usize("bad", 0).is_err());
    }
}
