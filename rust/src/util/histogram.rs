//! Streaming latency metrics: a log-bucketed histogram plus an online
//! mean/variance accumulator, so a serving run's metric memory is O(1)
//! in request count instead of one `Completion` per request.
//!
//! [`LogHistogram`] buckets values geometrically: bucket `i` covers
//! `[min_value * growth^i, min_value * growth^(i+1))`, so any quantile
//! read back is within one bucket's *relative* width (`growth - 1`,
//! 2% at the default) of the exact order statistic — the right error
//! model for latencies, where tail accuracy should scale with the value.
//! [`Streaming`] combines the histogram with Welford's online mean and
//! variance and exact min/max, and renders the same [`Summary`] shape the
//! sorted-vector path produced.

use super::stats::Summary;

/// Log-bucketed histogram with a fixed relative error per bucket.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    /// Values at or below this land in a dedicated low bucket and read
    /// back as `min_value` (latencies below one microsecond are noise).
    min_value: f64,
    /// Geometric bucket width; `growth - 1` is the relative error bound.
    growth: f64,
    inv_ln_growth: f64,
    /// Hard cap on bucket count; larger values saturate into the last
    /// bucket instead of growing the vector without bound.
    max_buckets: usize,
    counts: Vec<u64>,
    low: u64,
    total: u64,
}

impl LogHistogram {
    pub fn new(min_value: f64, growth: f64, max_buckets: usize) -> LogHistogram {
        assert!(min_value > 0.0, "min_value must be positive");
        assert!(growth > 1.0, "growth must exceed 1");
        assert!(max_buckets >= 1, "need at least one bucket");
        LogHistogram {
            min_value,
            growth,
            inv_ln_growth: 1.0 / growth.ln(),
            max_buckets,
            counts: Vec::new(),
            low: 0,
            total: 0,
        }
    }

    /// Defaults tuned for millisecond latencies: 1 µs floor, 2% relative
    /// error, and enough buckets to span past 10^14 ms.
    pub fn latency_default() -> LogHistogram {
        LogHistogram::new(1e-3, 1.02, 2048)
    }

    pub fn record(&mut self, v: f64) {
        self.total += 1;
        // NaN and values at or below the floor land in the low bucket.
        if v.is_nan() || v <= self.min_value {
            self.low += 1;
            return;
        }
        // `as usize` truncates toward zero (a floor, v > min_value here)
        // and saturates +inf into the top bucket.
        let idx = ((v / self.min_value).ln() * self.inv_ln_growth) as usize;
        let idx = idx.min(self.max_buckets - 1);
        if idx >= self.counts.len() {
            self.counts.resize(idx + 1, 0);
        }
        self.counts[idx] += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// The raw bucket state: the low-bucket count plus the geometric
    /// bucket counts. Exposed so sharded-engine merges can be asserted
    /// bucket-for-bucket against a sequential reference run.
    pub fn buckets(&self) -> (u64, &[u64]) {
        (self.low, &self.counts)
    }

    /// Fold another histogram into this one. Bucket counts are integers,
    /// so merging shards is *exact*: merge-of-parts is bucket-for-bucket
    /// identical to recording the concatenated stream. Panics if the two
    /// histograms were built with different geometry.
    pub fn merge(&mut self, other: &LogHistogram) {
        assert!(
            self.min_value == other.min_value
                && self.growth == other.growth
                && self.max_buckets == other.max_buckets,
            "LogHistogram::merge: mismatched geometry"
        );
        if other.counts.len() > self.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (c, &o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.low += other.low;
        self.total += other.total;
    }

    /// One bucket's relative width — the quantile error bound.
    pub fn relative_error(&self) -> f64 {
        self.growth - 1.0
    }

    /// Approximate percentile, `q` in [0, 100]: the geometric midpoint of
    /// the bucket holding the rank-`ceil(q/100 * n)` order statistic, so
    /// the result is within one bucket's relative width of that exact
    /// order statistic. Returns 0.0 on an empty histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((q / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let rank = rank.min(self.total);
        let mut cum = self.low;
        if cum >= rank {
            return self.min_value;
        }
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return self.min_value * self.growth.powi(i as i32) * self.growth.sqrt();
            }
        }
        // Unreachable when counts are consistent; saturate at the top edge.
        self.min_value * self.growth.powi(self.counts.len() as i32)
    }
}

/// Online summary statistics: Welford mean/variance, exact min/max and a
/// [`LogHistogram`] for percentiles. Fixed-size regardless of how many
/// samples stream through.
#[derive(Debug, Clone)]
pub struct Streaming {
    n: usize,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    hist: LogHistogram,
}

impl Default for Streaming {
    fn default() -> Streaming {
        Streaming::new(LogHistogram::latency_default())
    }
}

impl Streaming {
    pub fn new(hist: LogHistogram) -> Streaming {
        Streaming {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            hist,
        }
    }

    pub fn record(&mut self, v: f64) {
        self.n += 1;
        let d = v - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (v - self.mean);
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
        self.hist.record(v);
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn hist(&self) -> &LogHistogram {
        &self.hist
    }

    /// Fold another accumulator into this one (Chan et al.'s pairwise
    /// Welford combine). Counts, min/max and histogram buckets merge
    /// exactly; mean and variance are exact up to float rounding — the
    /// combined `m2` can differ from the single-stream accumulation by
    /// a few ulps because the addition order differs, which is why
    /// sequential-vs-sharded equivalence asserts them within a relative
    /// tolerance rather than bit-for-bit.
    pub fn merge(&mut self, other: &Streaming) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let n = n1 + n2;
        let delta = other.mean - self.mean;
        self.mean += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.hist.merge(&other.hist);
    }

    /// Render the accumulated stream as a [`Summary`]: mean/std/min/max
    /// are exact (up to float accumulation order), percentiles are
    /// histogram-derived within one bucket's relative error.
    pub fn summary(&self) -> Summary {
        if self.n == 0 {
            return Summary::default();
        }
        Summary {
            n: self.n,
            mean: self.mean,
            std: (self.m2 / self.n as f64).max(0.0).sqrt(),
            min: self.min,
            p50: self.hist.quantile(50.0),
            p95: self.hist.quantile(95.0),
            p99: self.hist.quantile(99.0),
            max: self.max,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{mean, percentile, stddev};

    #[test]
    fn quantile_within_one_bucket_of_exact() {
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64 * 0.5).collect();
        let mut h = LogHistogram::latency_default();
        for &x in &xs {
            h.record(x);
        }
        for q in [0.0, 50.0, 95.0, 99.0, 100.0] {
            let exact = percentile(&xs, q);
            let approx = h.quantile(q);
            assert!(
                approx >= exact / 1.02 && approx <= exact * 1.02,
                "q{q}: approx {approx} vs exact {exact}"
            );
        }
    }

    #[test]
    fn low_values_saturate_at_floor() {
        let mut h = LogHistogram::latency_default();
        h.record(0.0);
        h.record(-3.0);
        h.record(1e-9);
        assert_eq!(h.count(), 3);
        assert_eq!(h.quantile(50.0), 1e-3);
    }

    #[test]
    fn huge_values_clamp_to_last_bucket() {
        let mut h = LogHistogram::new(1.0, 2.0, 4);
        h.record(1e30);
        let q = h.quantile(100.0);
        assert!(q.is_finite() && q > 1.0, "clamped quantile {q}");
    }

    #[test]
    fn empty_histogram_quantile_is_zero() {
        let h = LogHistogram::latency_default();
        assert_eq!(h.quantile(99.0), 0.0);
    }

    #[test]
    fn streaming_matches_exact_moments() {
        let xs = [3.0, 1.0, 4.0, 1.5, 9.25, 2.5];
        let mut s = Streaming::default();
        for &x in &xs {
            s.record(x);
        }
        let sum = s.summary();
        assert_eq!(sum.n, xs.len());
        assert!((sum.mean - mean(&xs)).abs() < 1e-12);
        assert!((sum.std - stddev(&xs)).abs() < 1e-9);
        assert_eq!(sum.min, 1.0);
        assert_eq!(sum.max, 9.25);
    }

    #[test]
    fn empty_streaming_is_default_summary() {
        let s = Streaming::default();
        let sum = s.summary();
        assert_eq!(sum.n, 0);
        assert_eq!(sum.mean, 0.0);
    }

    #[test]
    fn histogram_merge_of_halves_is_bucket_exact() {
        let xs: Vec<f64> = (1..=999).map(|i| (i as f64 * 0.37).sin().abs() * 80.0 + 0.01).collect();
        let mut whole = LogHistogram::latency_default();
        let mut left = LogHistogram::latency_default();
        let mut right = LogHistogram::latency_default();
        for (i, &x) in xs.iter().enumerate() {
            whole.record(x);
            if i % 2 == 0 {
                left.record(x);
            } else {
                right.record(x);
            }
        }
        left.merge(&right);
        assert_eq!(left.count(), whole.count());
        let (mlow, mcounts) = left.buckets();
        let (wlow, wcounts) = whole.buckets();
        assert_eq!(mlow, wlow);
        assert_eq!(mcounts, wcounts, "merge must be bucket-for-bucket exact");
        for q in [50.0, 95.0, 99.0] {
            assert_eq!(left.quantile(q), whole.quantile(q));
        }
    }

    #[test]
    #[should_panic(expected = "mismatched geometry")]
    fn histogram_merge_rejects_mismatched_geometry() {
        let mut a = LogHistogram::latency_default();
        let b = LogHistogram::new(1.0, 2.0, 8);
        a.merge(&b);
    }

    #[test]
    fn streaming_merge_of_halves_matches_whole_stream() {
        let xs: Vec<f64> = (0..2000)
            .map(|i| ((i as f64 * 0.613).cos() * 40.0).abs() + 0.5)
            .collect();
        let mut whole = Streaming::default();
        let mut parts: Vec<Streaming> = (0..4).map(|_| Streaming::default()).collect();
        for (i, &x) in xs.iter().enumerate() {
            whole.record(x);
            parts[i % 4].record(x);
        }
        let mut merged = parts.remove(0);
        for p in &parts {
            merged.merge(p);
        }
        let m = merged.summary();
        let w = whole.summary();
        assert_eq!(m.n, w.n);
        assert_eq!(m.min, w.min, "min is exact");
        assert_eq!(m.max, w.max, "max is exact");
        // Welford pairwise combine: exact up to accumulation-order float
        // rounding.
        assert!((m.mean - w.mean).abs() <= 1e-12 * w.mean.abs(), "{} vs {}", m.mean, w.mean);
        assert!((m.std - w.std).abs() <= 1e-9 * w.std.abs().max(1.0), "{} vs {}", m.std, w.std);
        // Percentiles ride on the exactly-merged histogram.
        assert_eq!(m.p50, w.p50);
        assert_eq!(m.p95, w.p95);
        assert_eq!(m.p99, w.p99);
    }

    #[test]
    fn streaming_merge_with_empty_sides() {
        let mut filled = Streaming::default();
        for x in [1.0, 2.0, 3.0] {
            filled.record(x);
        }
        let reference = filled.summary();
        // empty.merge(filled) adopts the filled stream...
        let mut empty = Streaming::default();
        empty.merge(&filled);
        assert_eq!(format!("{:?}", empty.summary()), format!("{reference:?}"));
        // ...and filled.merge(empty) is a no-op.
        filled.merge(&Streaming::default());
        assert_eq!(format!("{:?}", filled.summary()), format!("{reference:?}"));
    }
}
