//! Event-queue core for the serving engine: an [`EventQueue`] trait with
//! two interchangeable implementations.
//!
//! - [`HeapQueue`] — the original [`BinaryHeap`] min-queue, kept as the
//!   reference implementation. `O(log n)` push/pop.
//! - [`CalendarQueue`] — an adaptive calendar queue (the timer-wheel
//!   family): a power-of-two array of buckets ("days"), each one bucket
//!   width of virtual time wide, with a cursor walking the current day.
//!   Push hashes `at_ms` to its day in `O(1)`; pop takes the current
//!   day's earliest entry in `O(1)` amortized. The bucket count doubles/
//!   halves with occupancy, and on every resize the bucket width is
//!   retuned to the observed mean inter-event gap, so the structure
//!   tracks whatever event density the simulation produces.
//!
//! Both order strictly by `(at_ms, seq)` — exact `f64::total_cmp` time,
//! monotone insertion index as the FIFO tie-break — so pop order, and
//! therefore every `ServiceReport` the engine produces, is byte-identical
//! whichever implementation runs. That equivalence is enforced by
//! `tests/eventq_property.rs` (arbitrary push/pop schedules) and the
//! same-seed report tests in `tests/sharded_equivalence.rs`.
//!
//! [`QueueKind`] selects the implementation through
//! [`EngineConfig::event_queue`](crate::coordinator::engine::EngineConfig::event_queue);
//! [`AnyQueue`] is the enum the engine actually holds (static dispatch,
//! no boxing on the hot path).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A min-queue of `(at_ms, seq)`-keyed events. Pop order is strictly
/// ascending `(at_ms, seq)` under `f64::total_cmp` — every
/// implementation must be exchangeable without changing a single popped
/// byte.
pub trait EventQueue<T> {
    /// Insert an event. `seq` is the caller's monotone insertion index;
    /// it breaks same-timestamp ties FIFO.
    fn push(&mut self, at_ms: f64, seq: u64, item: T);
    /// Remove and return the earliest event.
    fn pop(&mut self) -> Option<(f64, u64, T)>;
    /// Earliest pending event time without removing it. Takes `&mut`
    /// because the calendar implementation advances its day cursor past
    /// empty buckets while searching.
    fn peek_time(&mut self) -> Option<f64>;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Which [`EventQueue`] implementation the engine runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueueKind {
    /// The [`BinaryHeap`] reference implementation (`O(log n)`).
    Heap,
    /// The adaptive calendar queue (`O(1)` amortized) — the default;
    /// byte-identical pop order to [`QueueKind::Heap`].
    #[default]
    Calendar,
}

impl QueueKind {
    /// Parse a `--queue` style argument.
    pub fn parse(s: &str) -> Option<QueueKind> {
        match s {
            "heap" => Some(QueueKind::Heap),
            "calendar" => Some(QueueKind::Calendar),
            _ => None,
        }
    }

    /// Queue kind from the `CONTINUER_QUEUE` environment variable
    /// (`heap` or `calendar`), defaulting to [`QueueKind::Calendar`].
    /// CI uses this to sweep the engine's own unit tests under both
    /// implementations without re-plumbing every test helper.
    pub fn from_env() -> QueueKind {
        match std::env::var("CONTINUER_QUEUE") {
            Ok(v) => QueueKind::parse(&v).unwrap_or_default(),
            Err(_) => QueueKind::default(),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            QueueKind::Heap => "heap",
            QueueKind::Calendar => "calendar",
        }
    }
}

/// One queued event. Size matters: the engine's hot-path compaction
/// budget test guards [`entry_size`] of its event payload.
#[derive(Debug)]
struct Entry<T> {
    at_ms: f64,
    seq: u64,
    item: T,
}

impl<T> Entry<T> {
    /// Total order shared by both implementations: exact time, then
    /// insertion index.
    fn key_cmp(&self, at_ms: f64, seq: u64) -> Ordering {
        self.at_ms.total_cmp(&at_ms).then(self.seq.cmp(&seq))
    }
}

/// Size in bytes of one queued entry carrying payload `T` — what the
/// engine's event-size budget test bounds.
pub const fn entry_size<T>() -> usize {
    std::mem::size_of::<Entry<T>>()
}

// ---------------------------------------------------------------------------
// HeapQueue: the BinaryHeap reference
// ---------------------------------------------------------------------------

struct HeapEntry<T>(Entry<T>);

impl<T> PartialEq for HeapEntry<T> {
    fn eq(&self, other: &HeapEntry<T>) -> bool {
        self.0.seq == other.0.seq
    }
}

impl<T> Eq for HeapEntry<T> {}

impl<T> Ord for HeapEntry<T> {
    fn cmp(&self, other: &HeapEntry<T>) -> Ordering {
        // Inverted: BinaryHeap is a max-heap, we pop the earliest event.
        other.0.key_cmp(self.0.at_ms, self.0.seq)
    }
}

impl<T> PartialOrd for HeapEntry<T> {
    fn partial_cmp(&self, other: &HeapEntry<T>) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The original engine queue: a [`BinaryHeap`] with inverted `(at_ms,
/// seq)` ordering. Kept as the reference every other implementation must
/// match pop-for-pop.
pub struct HeapQueue<T> {
    heap: BinaryHeap<HeapEntry<T>>,
}

impl<T> Default for HeapQueue<T> {
    fn default() -> HeapQueue<T> {
        HeapQueue { heap: BinaryHeap::new() }
    }
}

impl<T> HeapQueue<T> {
    pub fn new() -> HeapQueue<T> {
        HeapQueue::default()
    }
}

impl<T> EventQueue<T> for HeapQueue<T> {
    fn push(&mut self, at_ms: f64, seq: u64, item: T) {
        self.heap.push(HeapEntry(Entry { at_ms, seq, item }));
    }

    fn pop(&mut self) -> Option<(f64, u64, T)> {
        self.heap.pop().map(|e| (e.0.at_ms, e.0.seq, e.0.item))
    }

    fn peek_time(&mut self) -> Option<f64> {
        self.heap.peek().map(|e| e.0.at_ms)
    }

    fn len(&self) -> usize {
        self.heap.len()
    }
}

// ---------------------------------------------------------------------------
// CalendarQueue: adaptive power-of-two calendar
// ---------------------------------------------------------------------------

/// Smallest bucket array; also the floor the shrink path stops at.
const MIN_BUCKETS: usize = 8;
/// Grow when occupancy exceeds `buckets * GROW_AT`; shrink below
/// `buckets / SHRINK_AT`. The 8x gap between the thresholds is the
/// hysteresis that keeps a queue hovering near a boundary from
/// thrashing rebuilds.
const GROW_AT: usize = 2;
const SHRINK_AT: usize = 4;
/// Bucket width is retuned to `WIDTH_GAPS x` the observed mean
/// inter-event gap on every resize: a few events per bucket-day keeps
/// both the per-pop scan and the per-push insert O(1) amortized.
const WIDTH_GAPS: f64 = 4.0;

/// An adaptive calendar queue (Brown 1988): `O(1)` amortized push and
/// pop against the heap's `O(log n)`, with pop order byte-identical to
/// [`HeapQueue`].
///
/// Geometry: `buckets.len()` is a power of two; bucket `b` holds every
/// entry whose *day* `floor((at_ms - origin) / width)` satisfies
/// `day & mask == b`. Each bucket is kept sorted by `(at_ms, seq)`
/// *descending*, so its earliest entry pops from the back in `O(1)` and
/// a push binary-searches its slot (buckets hold ~`WIDTH_GAPS` entries
/// on average, so the insert memmove is constant-sized). The cursor
/// `cur_day` maintains the invariant that no entry's day precedes it:
/// pop serves the cursor's day or walks forward; a push behind the
/// cursor (rare — the engine's pops are non-decreasing) rewinds it.
///
/// A full empty lap of the wheel means the pending events are sparse
/// relative to the bucket width (e.g. a far-future failure event after
/// traffic drains); pop then jumps the cursor straight to the earliest
/// entry instead of stepping day by day.
pub struct CalendarQueue<T> {
    buckets: Vec<Vec<Entry<T>>>,
    /// `buckets.len() - 1`; day → bucket is a mask, not a modulo.
    mask: u64,
    /// Virtual-time width of one day, ms.
    width: f64,
    inv_width: f64,
    /// Virtual time of day 0's left edge. Re-anchored whenever the
    /// queue drains empty so day indices stay small.
    origin: f64,
    /// The earliest day any entry may occupy.
    cur_day: u64,
    len: usize,
}

impl<T> Default for CalendarQueue<T> {
    fn default() -> CalendarQueue<T> {
        CalendarQueue {
            buckets: (0..MIN_BUCKETS).map(|_| Vec::new()).collect(),
            mask: (MIN_BUCKETS - 1) as u64,
            // Arbitrary starting width; the first resize retunes it to
            // the observed event density.
            width: 1.0,
            inv_width: 1.0,
            origin: 0.0,
            cur_day: 0,
            len: 0,
        }
    }
}

impl<T> CalendarQueue<T> {
    pub fn new() -> CalendarQueue<T> {
        CalendarQueue::default()
    }

    fn day_of(&self, at_ms: f64) -> u64 {
        if at_ms <= self.origin {
            return 0;
        }
        // Saturating float → int cast: absurdly far futures all land on
        // the last representable day, which still orders correctly
        // because intra-bucket order is exact `(at_ms, seq)`.
        ((at_ms - self.origin) * self.inv_width) as u64
    }

    fn insert_entry(&mut self, e: Entry<T>) {
        let day = self.day_of(e.at_ms);
        if day < self.cur_day {
            // A push behind the cursor (the engine never does this on
            // its hot path, but nothing forbids it): rewind so the
            // "no entry precedes cur_day" invariant holds.
            self.cur_day = day;
        }
        let bucket = &mut self.buckets[(day & self.mask) as usize];
        // Descending (at_ms, seq): the bucket's earliest entry sits at
        // the back, where pop removes in O(1).
        let pos = bucket.partition_point(|q| q.key_cmp(e.at_ms, e.seq) == Ordering::Greater);
        bucket.insert(pos, e);
    }

    /// Drain everything, retune the bucket width to the observed mean
    /// inter-event gap, re-anchor the origin at the earliest entry, and
    /// reinsert into `new_buckets` buckets.
    fn rebuild(&mut self, new_buckets: usize) {
        let mut entries: Vec<Entry<T>> = Vec::with_capacity(self.len);
        for b in &mut self.buckets {
            entries.append(b);
        }
        let mut min_t = f64::INFINITY;
        let mut max_t = f64::NEG_INFINITY;
        for e in &entries {
            min_t = min_t.min(e.at_ms);
            max_t = max_t.max(e.at_ms);
        }
        if entries.len() >= 2 && max_t > min_t {
            let gap = (max_t - min_t) / (entries.len() - 1) as f64;
            let width = gap * WIDTH_GAPS;
            if width.is_finite() && width > 0.0 {
                self.width = width;
                self.inv_width = 1.0 / width;
            }
        }
        if min_t.is_finite() {
            self.origin = min_t;
        }
        self.cur_day = 0;
        if self.buckets.len() != new_buckets {
            self.buckets = (0..new_buckets).map(|_| Vec::new()).collect();
            self.mask = (new_buckets - 1) as u64;
        }
        for e in entries {
            self.insert_entry(e);
        }
    }

    fn maybe_resize(&mut self) {
        let nb = self.buckets.len();
        if self.len > nb * GROW_AT {
            self.rebuild(nb * 2);
        } else if nb > MIN_BUCKETS && self.len < nb / SHRINK_AT {
            self.rebuild(nb / 2);
        }
    }

    /// The earliest entry's bucket index — a direct `O(buckets)` search
    /// used after a full lap of the wheel finds nothing in its own day
    /// (the sparse-queue regime). Each bucket's candidate is its back
    /// entry (the bucket minimum), so the scan is one comparison per
    /// bucket.
    fn min_bucket(&self) -> Option<usize> {
        let mut best: Option<(usize, f64, u64)> = None;
        for (i, bucket) in self.buckets.iter().enumerate() {
            if let Some(e) = bucket.last() {
                let better = match best {
                    None => true,
                    Some((_, t, s)) => e.key_cmp(t, s) == Ordering::Less,
                };
                if better {
                    best = Some((i, e.at_ms, e.seq));
                }
            }
        }
        best.map(|(i, _, _)| i)
    }
}

impl<T> EventQueue<T> for CalendarQueue<T> {
    fn push(&mut self, at_ms: f64, seq: u64, item: T) {
        if self.len == 0 && at_ms.is_finite() {
            // Empty queue: re-anchor the calendar at this event so day
            // indices restart from zero whatever virtual time it is.
            self.origin = at_ms;
            self.cur_day = 0;
        }
        self.insert_entry(Entry { at_ms, seq, item });
        self.len += 1;
        self.maybe_resize();
    }

    fn pop(&mut self) -> Option<(f64, u64, T)> {
        if self.len == 0 {
            return None;
        }
        // Walk at most one lap: every entry's day is >= cur_day, a day
        // maps to exactly one bucket, and a bucket's back entry is its
        // minimum — so the first back entry found within its own day is
        // the global (at_ms, seq) minimum.
        for _ in 0..self.buckets.len() {
            let b = (self.cur_day & self.mask) as usize;
            let due = self.buckets[b]
                .last()
                .is_some_and(|e| self.day_of(e.at_ms) <= self.cur_day);
            if due {
                let e = self.buckets[b].pop().expect("checked non-empty");
                self.len -= 1;
                self.maybe_resize();
                return Some((e.at_ms, e.seq, e.item));
            }
            // Saturating: if day_of ever pinned an entry to u64::MAX,
            // the lap degrades to the min_bucket jump below.
            self.cur_day = self.cur_day.saturating_add(1);
        }
        // Sparse regime: jump to the earliest entry directly.
        let b = self.min_bucket().expect("len > 0 must have an entry");
        let e = self.buckets[b].pop().expect("min bucket is non-empty");
        // Everything else is strictly later (exact-tie at_ms shares the
        // popped entry's day), so the cursor may jump forward to it.
        self.cur_day = self.day_of(e.at_ms);
        self.len -= 1;
        self.maybe_resize();
        Some((e.at_ms, e.seq, e.item))
    }

    fn peek_time(&mut self) -> Option<f64> {
        if self.len == 0 {
            return None;
        }
        for _ in 0..self.buckets.len() {
            let b = (self.cur_day & self.mask) as usize;
            if let Some(e) = self.buckets[b].last() {
                if self.day_of(e.at_ms) <= self.cur_day {
                    return Some(e.at_ms);
                }
            }
            self.cur_day = self.cur_day.saturating_add(1);
        }
        let b = self.min_bucket().expect("len > 0 must have an entry");
        let e = self.buckets[b].last().expect("min bucket is non-empty");
        let (at, day) = (e.at_ms, self.day_of(e.at_ms));
        // Safe to fast-forward: nothing precedes the minimum.
        self.cur_day = day;
        Some(at)
    }

    fn len(&self) -> usize {
        self.len
    }
}

// ---------------------------------------------------------------------------
// AnyQueue: the engine's runtime-selected queue
// ---------------------------------------------------------------------------

/// The queue the engine holds: selected once from
/// [`QueueKind`] at construction, then statically dispatched per call
/// (a two-arm match, not a vtable).
pub enum AnyQueue<T> {
    Heap(HeapQueue<T>),
    Calendar(CalendarQueue<T>),
}

impl<T> AnyQueue<T> {
    pub fn new(kind: QueueKind) -> AnyQueue<T> {
        match kind {
            QueueKind::Heap => AnyQueue::Heap(HeapQueue::new()),
            QueueKind::Calendar => AnyQueue::Calendar(CalendarQueue::new()),
        }
    }
}

impl<T> EventQueue<T> for AnyQueue<T> {
    fn push(&mut self, at_ms: f64, seq: u64, item: T) {
        match self {
            AnyQueue::Heap(q) => q.push(at_ms, seq, item),
            AnyQueue::Calendar(q) => q.push(at_ms, seq, item),
        }
    }

    fn pop(&mut self) -> Option<(f64, u64, T)> {
        match self {
            AnyQueue::Heap(q) => q.pop(),
            AnyQueue::Calendar(q) => q.pop(),
        }
    }

    fn peek_time(&mut self) -> Option<f64> {
        match self {
            AnyQueue::Heap(q) => q.peek_time(),
            AnyQueue::Calendar(q) => q.peek_time(),
        }
    }

    fn len(&self) -> usize {
        match self {
            AnyQueue::Heap(q) => q.len(),
            AnyQueue::Calendar(q) => q.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain<Q: EventQueue<u64>>(q: &mut Q) -> Vec<(f64, u64, u64)> {
        let mut out = Vec::new();
        while let Some(e) = q.pop() {
            out.push(e);
        }
        out
    }

    #[test]
    fn pops_in_time_then_seq_order() {
        let mut q = CalendarQueue::new();
        q.push(5.0, 1, 1);
        q.push(1.0, 2, 2);
        q.push(5.0, 3, 3);
        q.push(0.5, 4, 4);
        assert_eq!(q.peek_time(), Some(0.5));
        let order: Vec<u64> = drain(&mut q).into_iter().map(|(_, _, x)| x).collect();
        assert_eq!(order, vec![4, 2, 1, 3], "time order, FIFO on the 5.0 tie");
        assert_eq!(q.pop(), None);
        assert_eq!(q.peek_time(), None);
    }

    #[test]
    fn matches_heap_through_grow_and_shrink() {
        // Enough entries to force several doublings, then a full drain
        // through the shrink path; clustered times force ties.
        let mut cal = CalendarQueue::new();
        let mut heap = HeapQueue::new();
        let mut seq = 0u64;
        let mut push = |cal: &mut CalendarQueue<u64>, heap: &mut HeapQueue<u64>, t: f64| {
            seq += 1;
            cal.push(t, seq, seq);
            heap.push(t, seq, seq);
        };
        for i in 0..500u64 {
            // Mixed density: ms-scale traffic plus far-future outliers.
            let t = match i % 7 {
                0 => (i / 7) as f64,
                6 => 1e5 + i as f64,
                _ => (i as f64 * 0.37) % 40.0,
            };
            push(&mut cal, &mut heap, t);
        }
        assert_eq!(cal.len(), heap.len());
        assert_eq!(drain(&mut cal), drain(&mut heap));
    }

    #[test]
    fn interleaved_push_pop_stays_consistent() {
        // Engine-shaped schedule: pops are non-decreasing and pushes
        // land at or after the last popped time.
        let mut cal = CalendarQueue::new();
        let mut heap = HeapQueue::new();
        let mut seq = 0u64;
        let mut clock = 0.0f64;
        for round in 0..200u64 {
            for k in 0..3 {
                seq += 1;
                let t = clock + (round * 3 + k) as f64 * 0.11;
                cal.push(t, seq, seq);
                heap.push(t, seq, seq);
            }
            let a = cal.pop();
            let b = heap.pop();
            assert_eq!(a, b);
            clock = a.expect("just pushed").0;
            assert_eq!(cal.peek_time(), heap.peek_time());
        }
        assert_eq!(drain(&mut cal), drain(&mut heap));
    }

    #[test]
    fn sparse_far_future_events_pop_without_walking_days() {
        // A handful of events separated by ~1e9x the bucket width: the
        // lap-then-jump path must find them (and in order).
        let mut q = CalendarQueue::new();
        for (i, t) in [0.001, 1e6, 2e9, 3e12].iter().enumerate() {
            q.push(*t, i as u64 + 1, i as u64);
        }
        let order: Vec<f64> = drain(&mut q).into_iter().map(|(t, _, _)| t).collect();
        assert_eq!(order, vec![0.001, 1e6, 2e9, 3e12]);
    }

    #[test]
    fn reanchors_after_draining_empty() {
        let mut q = CalendarQueue::new();
        q.push(1e12, 1, 1);
        assert_eq!(q.pop().map(|e| e.2), Some(1));
        // A fresh burst at tiny times after a far-future drain must not
        // strand the cursor.
        q.push(0.5, 2, 2);
        q.push(0.25, 3, 3);
        assert_eq!(q.pop().map(|e| e.2), Some(3));
        assert_eq!(q.pop().map(|e| e.2), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn queue_kind_parses_and_defaults() {
        assert_eq!(QueueKind::parse("heap"), Some(QueueKind::Heap));
        assert_eq!(QueueKind::parse("calendar"), Some(QueueKind::Calendar));
        assert_eq!(QueueKind::parse("wheel"), None);
        assert_eq!(QueueKind::default(), QueueKind::Calendar);
        assert_eq!(QueueKind::Heap.label(), "heap");
        assert_eq!(QueueKind::Calendar.label(), "calendar");
    }
}
