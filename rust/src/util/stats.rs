//! Statistics helpers shared by the predictors, scheduler and harness:
//! summary stats, percentiles, min-max normalisation (paper §IV-C),
//! regression quality metrics (MSE, R², MAPE — paper Tables II, V, VI).

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated percentile, q in [0, 100]. Panics on empty input.
/// Clones and sorts per call — when reading several percentiles from one
/// sample set, sort once and use [`percentile_sorted`].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(&v, q)
}

/// Linear-interpolated percentile over an already ascending-sorted slice.
/// Panics on empty input.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty slice");
    let pos = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (pos - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Min-max normalisation to [0, 1] (paper's "Linear Max-Min technique",
/// §IV-C). Constant inputs normalise to 0.5 (no information → neutral).
pub fn min_max_normalize(xs: &[f64]) -> Vec<f64> {
    let (lo, hi) = (min(xs), max(xs));
    if (hi - lo).abs() < 1e-12 {
        return vec![0.5; xs.len()];
    }
    xs.iter().map(|x| (x - lo) / (hi - lo)).collect()
}

/// Mean squared error.
pub fn mse(pred: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(pred.len(), actual.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter()
        .zip(actual)
        .map(|(p, a)| (p - a) * (p - a))
        .sum::<f64>()
        / pred.len() as f64
}

/// Coefficient of determination R² = 1 - SS_res / SS_tot.
pub fn r2(pred: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(pred.len(), actual.len());
    let m = mean(actual);
    let ss_tot: f64 = actual.iter().map(|a| (a - m) * (a - m)).sum();
    let ss_res: f64 = pred
        .iter()
        .zip(actual)
        .map(|(p, a)| (a - p) * (a - p))
        .sum();
    if ss_tot.abs() < 1e-12 {
        return if ss_res.abs() < 1e-12 { 1.0 } else { 0.0 };
    }
    1.0 - ss_res / ss_tot
}

/// Average percentage error |pred - actual| / actual * 100 (the paper's
/// metric for Tables V and VI). Entries with |actual| < eps are skipped.
pub fn avg_pct_error(pred: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(pred.len(), actual.len());
    let mut total = 0.0;
    let mut n = 0usize;
    for (p, a) in pred.iter().zip(actual) {
        if a.abs() > 1e-12 {
            total += ((p - a) / a).abs() * 100.0;
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        total / n as f64
    }
}

/// Summary of a latency sample set, in whatever unit the input used.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        // Sort once; every percentile then indexes the same sorted copy
        // (the old path cloned + sorted the full vector per percentile).
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: stddev(xs),
            min: min(xs),
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
            max: max(xs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 25.0), 1.75);
    }

    #[test]
    fn percentile_sorted_matches_percentile() {
        let xs = [9.0, 1.0, 5.0, 3.0, 7.0];
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        for q in [0.0, 12.5, 50.0, 95.0, 100.0] {
            assert_eq!(percentile(&xs, q), percentile_sorted(&sorted, q));
        }
    }

    #[test]
    fn normalize_range() {
        let n = min_max_normalize(&[2.0, 4.0, 6.0]);
        assert_eq!(n, vec![0.0, 0.5, 1.0]);
        assert_eq!(min_max_normalize(&[3.0, 3.0]), vec![0.5, 0.5]);
    }

    #[test]
    fn mse_r2_perfect() {
        let a = [1.0, 2.0, 3.0];
        assert_eq!(mse(&a, &a), 0.0);
        assert_eq!(r2(&a, &a), 1.0);
    }

    #[test]
    fn r2_mean_predictor_is_zero() {
        let actual = [1.0, 2.0, 3.0];
        let pred = [2.0, 2.0, 2.0];
        assert!(r2(&pred, &actual).abs() < 1e-12);
    }

    #[test]
    fn pct_error() {
        let e = avg_pct_error(&[110.0, 90.0], &[100.0, 100.0]);
        assert!((e - 10.0).abs() < 1e-9);
        // zero actuals skipped
        let e2 = avg_pct_error(&[110.0, 5.0], &[100.0, 0.0]);
        assert!((e2 - 10.0).abs() < 1e-9);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.p50, 3.0);
    }
}
