//! Benchmark harness (criterion is not in the offline vendor set).
//!
//! `Bencher` does warmup + timed iterations with outlier-robust reporting;
//! `Table` renders aligned ASCII tables for the experiment harness so every
//! paper table/figure prints in a consistent format.

use std::time::Instant;

use super::stats::Summary;

/// Time `f` with warmup; returns per-iteration summaries in microseconds.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    Summary::of(&samples)
}

/// Time a batch-style closure that reports how many items it processed;
/// returns (per-item mean us, items/sec).
pub fn bench_throughput<F: FnMut() -> usize>(warmup: usize, iters: usize, mut f: F) -> (f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let t0 = Instant::now();
    let mut items = 0usize;
    for _ in 0..iters {
        items += f();
    }
    let secs = t0.elapsed().as_secs_f64();
    if items == 0 {
        return (0.0, 0.0);
    }
    (secs * 1e6 / items as f64, items as f64 / secs)
}

/// Aligned ASCII table builder.
pub struct Table {
    pub title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("| ");
            for i in 0..ncol {
                line.push_str(&format!("{:w$} ", cells[i], w = widths[i]));
                line.push_str("| ");
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with fixed decimals, for table cells.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Format a percentage.
pub fn pct(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}%")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let s = bench(2, 10, || n += 1);
        assert_eq!(n, 12);
        assert_eq!(s.n, 10);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn throughput_positive() {
        let (per_item, per_sec) = bench_throughput(1, 5, || 100);
        assert!(per_item > 0.0);
        assert!(per_sec > 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["a", "long_header"]);
        t.row(&["x".into(), "1".into()]);
        t.row(&["yyyy".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("long_header"));
        let lines: Vec<&str> = r.lines().collect();
        // all data lines equal length
        assert_eq!(lines[1].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(pct(12.345, 1), "12.3%");
    }
}
