//! Fixed-size worker pool over std threads + channels.
//!
//! Tokio is not in the offline vendor set; the serving loop and the
//! parallel sections of the profiler use this pool instead. `scoped_map`
//! provides rayon-like parallel map with deterministic output ordering.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A simple fixed-size thread pool.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(workers: usize) -> ThreadPool {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("continuer-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, handles }
    }

    /// Submit a job; does not block.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool closed");
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Parallel map preserving input order. Items are distributed over
/// `workers` threads; the result vector matches the input indexing.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    parallel_map_with(items, workers, f, || {}).0
}

/// [`parallel_map`] plus a foreground task: `foreground` runs on the
/// *calling* thread while the worker threads map the items, and the call
/// returns once both the foreground task and every item are done. This is
/// the shape the sharded serving engine needs — shards run on scoped
/// workers while the arrival feeder (which owns the channel senders and
/// must observe shard backpressure counters live) and the streaming
/// event-sink drain both run alongside them. `foreground` needs no
/// `Send` (it never leaves the calling thread) and its return value is
/// handed back next to the mapped results — the sharded engine returns
/// the drained observability stream this way.
pub fn parallel_map_with<T, R, F, G, V>(
    items: Vec<T>,
    workers: usize,
    f: F,
    foreground: G,
) -> (Vec<R>, V)
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
    G: FnOnce() -> V,
{
    let n = items.len();
    if n == 0 {
        return (Vec::new(), foreground());
    }
    let workers = workers.max(1).min(n);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let work: Mutex<std::vec::IntoIter<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>().into_iter());
    let fg = thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let next = { work.lock().unwrap().next() };
                match next {
                    Some((i, item)) => {
                        let r = f(item);
                        *results[i].lock().unwrap() = Some(r);
                    }
                    None => break,
                }
            });
        }
        foreground()
    });
    let out = results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker produced result"))
        .collect();
    (out, fg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }

    #[test]
    fn parallel_map_order() {
        let out = parallel_map((0..50).collect::<Vec<_>>(), 4, |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn parallel_map_with_runs_foreground_alongside_workers() {
        // A feeder/consumer pair across the foreground/worker boundary:
        // the foreground closure produces into a channel that a mapped
        // item drains, so the call can only return if both ran
        // concurrently under the same scope.
        let (tx, rx) = mpsc::channel::<u32>();
        let rx = Mutex::new(rx);
        let (out, fed) = parallel_map_with(
            vec![0u32],
            2,
            |_| {
                let rx = rx.lock().unwrap();
                (0..100).map(|_| rx.recv().unwrap()).sum::<u32>()
            },
            move || {
                for v in 0..100 {
                    tx.send(v).unwrap();
                }
                100usize
            },
        );
        assert_eq!(out, vec![(0..100).sum::<u32>()]);
        assert_eq!(fed, 100, "the foreground value is handed back");
    }

    #[test]
    fn parallel_map_with_empty_still_runs_foreground() {
        let (out, ran): (Vec<i32>, bool) = parallel_map_with(Vec::new(), 4, |x| x, || true);
        assert!(out.is_empty());
        assert!(ran);
    }
}
