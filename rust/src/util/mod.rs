//! Foundation substrates built from scratch for the offline environment
//! (no serde / clap / rand / tokio / criterion / proptest in the vendor
//! set — see DESIGN.md §1.7).

pub mod bench;
pub mod cli;
pub mod eventq;
pub mod histogram;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod slab;
pub mod stats;
pub mod threadpool;
