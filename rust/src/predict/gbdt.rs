//! Gradient-boosted regression trees (squared loss) — the from-scratch
//! substitute for XGBoost (Latency Prediction Model) and LightGBM
//! (Accuracy Prediction Model); DESIGN.md §1.3.

use crate::util::rng::Rng;
use crate::util::stats;

use super::dataset::Dataset;
use super::tree::{Tree, TreeParams};

/// Boosting hyperparameters (named after their XGBoost equivalents, which
/// the paper tunes via Optuna — here via `tuner::random_search`).
#[derive(Debug, Clone)]
pub struct GbdtParams {
    pub n_estimators: usize,
    pub learning_rate: f64,
    pub max_depth: usize,
    pub min_child_weight: usize,
    pub subsample: f64,
    pub colsample_bytree: f64,
    pub lambda: f64,
    pub n_bins: usize,
    /// Stop when `early_stop` consecutive rounds fail to improve training
    /// loss by at least `tol` (0 disables).
    pub early_stop: usize,
    pub seed: u64,
}

impl Default for GbdtParams {
    fn default() -> Self {
        GbdtParams {
            n_estimators: 200,
            learning_rate: 0.1,
            max_depth: 6,
            min_child_weight: 1,
            subsample: 1.0,
            colsample_bytree: 1.0,
            lambda: 1.0,
            n_bins: 32,
            early_stop: 10,
            seed: 123,
        }
    }
}

/// A fitted GBDT model.
#[derive(Debug, Clone)]
pub struct Gbdt {
    base: f64,
    learning_rate: f64,
    trees: Vec<Tree>,
}

impl Gbdt {
    pub fn fit(data: &Dataset, params: &GbdtParams) -> Gbdt {
        assert!(!data.is_empty(), "Gbdt::fit on empty dataset");
        let n = data.len();
        let base = stats::mean(&data.targets);
        let mut pred = vec![base; n];
        let mut trees = Vec::new();
        let mut rng = Rng::new(params.seed);
        let tree_params = TreeParams {
            max_depth: params.max_depth,
            min_child_weight: params.min_child_weight,
            n_bins: params.n_bins,
            colsample: params.colsample_bytree,
            lambda: params.lambda,
        };
        let mut best_loss = f64::INFINITY;
        let mut stall = 0usize;
        for _ in 0..params.n_estimators {
            let residuals: Vec<f64> = data
                .targets
                .iter()
                .zip(&pred)
                .map(|(y, p)| y - p)
                .collect();
            let rows: Vec<usize> = if params.subsample < 1.0 {
                let k = ((n as f64) * params.subsample).ceil() as usize;
                rng.sample_indices(n, k.clamp(1, n))
            } else {
                (0..n).collect()
            };
            let tree = Tree::fit(&data.features, &residuals, &rows, &tree_params, &mut rng);
            for i in 0..n {
                pred[i] += params.learning_rate * tree.predict_one(&data.features[i]);
            }
            trees.push(tree);
            if params.early_stop > 0 {
                let loss = stats::mse(&pred, &data.targets);
                if loss + 1e-12 < best_loss {
                    best_loss = loss;
                    stall = 0;
                } else {
                    stall += 1;
                    if stall >= params.early_stop {
                        break;
                    }
                }
            }
        }
        Gbdt {
            base,
            learning_rate: params.learning_rate,
            trees,
        }
    }

    pub fn predict_one(&self, row: &[f64]) -> f64 {
        let mut p = self.base;
        for t in &self.trees {
            p += self.learning_rate * t.predict_one(row);
        }
        p
    }

    pub fn predict(&self, rows: &[Vec<f64>]) -> Vec<f64> {
        rows.iter().map(|r| self.predict_one(r)).collect()
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    /// Evaluate (MSE, R²) on a dataset.
    pub fn evaluate(&self, data: &Dataset) -> (f64, f64) {
        let pred = self.predict(&data.features);
        (
            stats::mse(&pred, &data.targets),
            stats::r2(&pred, &data.targets),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn friedman_like(n: usize, seed: u64) -> Dataset {
        // y = 10 sin(x0 x1) + 20 (x2 - .5)^2 + 10 x3 + 5 x4 + noise
        let mut rng = Rng::new(seed);
        let mut d = Dataset::new((0..5).map(|i| format!("x{i}")).collect());
        for _ in 0..n {
            let x: Vec<f64> = (0..5).map(|_| rng.f64()).collect();
            let y = 10.0 * (x[0] * x[1] * std::f64::consts::PI).sin()
                + 20.0 * (x[2] - 0.5).powi(2)
                + 10.0 * x[3]
                + 5.0 * x[4]
                + rng.normal() * 0.1;
            d.push(x, y);
        }
        d
    }

    #[test]
    fn learns_nonlinear_function() {
        let data = friedman_like(600, 1);
        let (tr, te) = data.split(0.8, 2);
        let model = Gbdt::fit(&tr, &GbdtParams::default());
        let (mse, r2) = model.evaluate(&te);
        assert!(r2 > 0.9, "r2 = {r2}, mse = {mse}");
    }

    #[test]
    fn constant_target() {
        let mut d = Dataset::new(vec!["x".into()]);
        for i in 0..50 {
            d.push(vec![i as f64], 7.0);
        }
        let m = Gbdt::fit(&d, &GbdtParams::default());
        assert!((m.predict_one(&[25.0]) - 7.0).abs() < 1e-6);
        // early stop should have kicked in long before 200 trees
        assert!(m.n_trees() < 50);
    }

    #[test]
    fn shrinkage_stabilises() {
        let data = friedman_like(300, 3);
        let slow = Gbdt::fit(
            &data,
            &GbdtParams {
                learning_rate: 0.05,
                n_estimators: 50,
                early_stop: 0,
                ..Default::default()
            },
        );
        assert_eq!(slow.n_trees(), 50);
        let (_, r2_train) = slow.evaluate(&data);
        assert!(r2_train > 0.8);
    }

    #[test]
    fn subsample_and_colsample_run() {
        let data = friedman_like(300, 4);
        let (tr, te) = data.split(0.8, 5);
        let m = Gbdt::fit(
            &tr,
            &GbdtParams {
                subsample: 0.7,
                colsample_bytree: 0.6,
                ..Default::default()
            },
        );
        let (_, r2) = m.evaluate(&te);
        assert!(r2 > 0.8, "r2 = {r2}");
    }

    #[test]
    fn deterministic_given_seed() {
        let data = friedman_like(200, 6);
        let a = Gbdt::fit(&data, &GbdtParams::default());
        let b = Gbdt::fit(&data, &GbdtParams::default());
        assert_eq!(a.predict_one(&[0.5; 5]), b.predict_one(&[0.5; 5]));
    }
}
