//! Prediction substrate: a from-scratch gradient-boosted-tree library
//! (XGBoost / LightGBM / Optuna substitutes — DESIGN.md §1.3) plus the two
//! models the CONTINUER profiler phase trains:
//!
//! - [`latency_model::LatencyModel`] — per-layer-type latency regression
//!   (paper Table I features, Table II quality).
//! - [`accuracy_model::AccuracyModel`] — accuracy-from-weight-statistics
//!   regression (paper §IV-B-ii, Unterthiner et al. [23]).

pub mod accuracy_model;
pub mod dataset;
pub mod gbdt;
pub mod latency_model;
pub mod tree;
pub mod tuner;

pub use accuracy_model::{AccuracyModel, AccuracyQuality};
pub use dataset::Dataset;
pub use gbdt::{Gbdt, GbdtParams};
pub use latency_model::{KindQuality, LatencyModel, LayerSample};
