//! Accuracy Prediction Model (paper §IV-B-ii).
//!
//! Estimates the accuracy a technique variant would deliver, from the
//! pretrained weights of the DNN — following the paper's adoption of
//! Unterthiner et al. [23]: per-layer-group weight statistics (mean, std,
//! percentiles q0/25/50/75/100) plus the Table-III training parameters
//! (train accuracy/loss, learning rate, epoch, architecture id).
//!
//! The training set is the AOT build's per-epoch history: one instance per
//! (epoch, technique variant); the label is that variant's measured eval
//! accuracy. An 80:20 split (paper's ratio) yields held-out MSE / R².
//! Accuracies are in percent, matching the paper's reported MSE scale.

use anyhow::{anyhow, Result};

use crate::dnn::model::{EpochRecord, ModelMeta};
use crate::dnn::variants::Technique;

use super::dataset::Dataset;
use super::gbdt::{Gbdt, GbdtParams};

const STAT_LEN: usize = 8; // [count, mean, std, q0, q25, q50, q75, q100]

pub struct AccuracyModel {
    gbdt: Gbdt,
    pub feature_names: Vec<String>,
}

/// Quality of the fitted model on the held-out split.
#[derive(Debug, Clone)]
pub struct AccuracyQuality {
    pub n_train: usize,
    pub n_test: usize,
    pub mse: f64,
    pub r2: f64,
}

pub fn feature_names() -> Vec<String> {
    let mut names: Vec<String> = vec![
        "is_repartition",
        "is_exit",
        "is_skip",
        "position_frac",
        "epoch_frac",
        "lr",
        "train_acc",
        "train_loss",
        "model_resnet32",
        "model_mobilenetv2",
        "log_active_params",
    ]
    .into_iter()
    .map(String::from)
    .collect();
    for stat in ["mean", "std", "q0", "q25", "q50", "q75", "q100"] {
        names.push(format!("path_{stat}"));
    }
    for stat in ["mean", "std", "q0", "q25", "q50", "q75", "q100"] {
        names.push(format!("head_{stat}"));
    }
    names
}

/// Aggregate per-unit weight stats (count-weighted mean of each statistic)
/// over the given unit keys ("n3", "e5", ...).
fn aggregate_stats(rec: &EpochRecord, keys: &[String]) -> (Vec<f64>, f64) {
    let mut agg = vec![0.0; STAT_LEN - 1];
    let mut total = 0.0;
    for k in keys {
        if let Some(s) = rec.weight_stats.get(k) {
            if s.len() == STAT_LEN {
                let count = s[0];
                for (i, v) in s[1..].iter().enumerate() {
                    agg[i] += count * v;
                }
                total += count;
            }
        }
    }
    if total > 0.0 {
        for v in &mut agg {
            *v /= total;
        }
    }
    (agg, total)
}

/// Unit keys on a variant's active path.
fn active_keys(model: &ModelMeta, tech: Technique) -> (Vec<String>, String) {
    match tech {
        Technique::Repartition => (
            model.nodes.iter().map(|n| format!("n{}", n.index)).collect(),
            format!("n{}", model.num_nodes),
        ),
        Technique::EarlyExit(e) => (
            model
                .nodes
                .iter()
                .filter(|n| n.index <= e)
                .map(|n| format!("n{}", n.index))
                .chain(std::iter::once(format!("e{e}")))
                .collect(),
            format!("e{e}"),
        ),
        Technique::SkipConnection(k) => (
            model
                .nodes
                .iter()
                .filter(|n| n.index != k)
                .map(|n| format!("n{}", n.index))
                .collect(),
            format!("n{}", model.num_nodes),
        ),
    }
}

/// Feature row for (model, epoch record, technique).
pub fn features(model: &ModelMeta, rec: &EpochRecord, epochs: usize, tech: Technique) -> Vec<f64> {
    let (onehot, pos) = match tech {
        Technique::Repartition => ([1.0, 0.0, 0.0], 1.0),
        Technique::EarlyExit(e) => ([0.0, 1.0, 0.0], e as f64 / model.num_nodes as f64),
        Technique::SkipConnection(k) => ([0.0, 0.0, 1.0], k as f64 / model.num_nodes as f64),
    };
    let (path_keys, head_key) = active_keys(model, tech);
    let (path_stats, path_count) = aggregate_stats(rec, &path_keys);
    let (head_stats, _) = aggregate_stats(rec, &[head_key]);
    let mut row = vec![
        onehot[0],
        onehot[1],
        onehot[2],
        pos,
        rec.epoch as f64 / epochs.max(1) as f64,
        rec.lr,
        rec.train_acc,
        rec.train_loss,
        if model.name == "resnet32" { 1.0 } else { 0.0 },
        if model.name == "mobilenetv2" { 1.0 } else { 0.0 },
        (path_count + 1.0).ln(),
    ];
    row.extend(path_stats);
    row.extend(head_stats);
    row
}

/// Label (accuracy %) of a variant at one epoch, if recorded.
fn label(rec: &EpochRecord, tech: Technique) -> Option<f64> {
    match tech {
        Technique::Repartition => Some(rec.variant_acc.repartition * 100.0),
        Technique::EarlyExit(e) => rec.variant_acc.exit.get(&e).map(|a| a * 100.0),
        Technique::SkipConnection(k) => rec.variant_acc.skip.get(&k).map(|a| a * 100.0),
    }
}

/// All technique variants a model's history records.
fn history_variants(model: &ModelMeta) -> Vec<Technique> {
    let mut v = vec![Technique::Repartition];
    v.extend(model.exit_nodes.iter().map(|&e| Technique::EarlyExit(e)));
    v.extend(
        model
            .skippable_nodes
            .iter()
            .map(|&k| Technique::SkipConnection(k)),
    );
    v
}

/// Build the (features, label) dataset from one or more models' histories.
pub fn build_dataset(models: &[&ModelMeta]) -> Dataset {
    let mut d = Dataset::new(feature_names());
    for m in models {
        let epochs = m.history.len();
        for rec in &m.history {
            for tech in history_variants(m) {
                if let Some(y) = label(rec, tech) {
                    d.push(features(m, rec, epochs, tech), y);
                }
            }
        }
    }
    d
}

impl AccuracyModel {
    /// Fit on the models' training histories; returns held-out quality.
    pub fn fit(
        models: &[&ModelMeta],
        params: &GbdtParams,
        seed: u64,
    ) -> Result<(AccuracyModel, AccuracyQuality)> {
        let data = build_dataset(models);
        if data.len() < 10 {
            return Err(anyhow!(
                "accuracy model: only {} instances in history",
                data.len()
            ));
        }
        let (tr, te) = data.split(0.8, seed);
        let probe = Gbdt::fit(&tr, params);
        let (mse, r2) = probe.evaluate(&te);
        let quality = AccuracyQuality {
            n_train: tr.len(),
            n_test: te.len(),
            mse,
            r2,
        };
        // Runtime model refits on everything.
        let gbdt = Gbdt::fit(&data, params);
        Ok((
            AccuracyModel {
                gbdt,
                feature_names: data.feature_names.clone(),
            },
            quality,
        ))
    }

    /// Predict the accuracy (%) of a technique, using the final epoch's
    /// weight statistics (i.e. the deployed weights).
    pub fn predict(&self, model: &ModelMeta, tech: Technique) -> Result<f64> {
        let rec = model
            .history
            .last()
            .ok_or_else(|| anyhow!("{}: empty history", model.name))?;
        let row = features(model, rec, model.history.len(), tech);
        Ok(self.gbdt.predict_one(&row).clamp(0.0, 100.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::model::test_fixtures::tiny_model;
    use crate::dnn::model::VariantAccuracies;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;

    /// Give the tiny model a plausible synthetic history.
    fn with_history(epochs: usize) -> ModelMeta {
        let mut m = tiny_model();
        let mut rng = Rng::new(1);
        for epoch in 0..epochs {
            let progress = (epoch + 1) as f64 / epochs as f64;
            let mut va = VariantAccuracies {
                repartition: 0.4 + 0.5 * progress,
                ..Default::default()
            };
            for e in 1..=4usize {
                va.exit
                    .insert(e, (0.2 + 0.1 * e as f64) * progress + 0.1);
            }
            for k in [2usize, 3, 4] {
                va.skip.insert(k, 0.35 + 0.45 * progress);
            }
            let mut ws = BTreeMap::new();
            for key in ["n1", "n2", "n3", "n4", "n5", "e1", "e2", "e3", "e4"] {
                let spread = 1.0 - 0.5 * progress;
                ws.insert(
                    key.to_string(),
                    vec![
                        1000.0,
                        0.01 * rng.normal(),
                        spread,
                        -2.0 * spread,
                        -0.5 * spread,
                        0.0,
                        0.5 * spread,
                        2.0 * spread,
                    ],
                );
            }
            m.history.push(EpochRecord {
                epoch,
                lr: 1e-3,
                train_loss: 2.0 * (1.0 - progress) + 0.1,
                train_acc: 0.3 + 0.65 * progress,
                variant_acc: va,
                weight_stats: ws,
            });
        }
        m
    }

    #[test]
    fn dataset_shape() {
        let m = with_history(6);
        let d = build_dataset(&[&m]);
        // 6 epochs x (1 repartition + 4 exits + 3 skips) = 48
        assert_eq!(d.len(), 48);
        assert_eq!(d.n_features(), feature_names().len());
    }

    #[test]
    fn fits_and_predicts_ordering() {
        let m = with_history(10);
        let (model, q) = AccuracyModel::fit(&[&m], &GbdtParams::default(), 3).unwrap();
        assert!(q.r2 > 0.5, "r2 = {}", q.r2);
        let full = model.predict(&m, Technique::Repartition).unwrap();
        let early = model.predict(&m, Technique::EarlyExit(1)).unwrap();
        assert!(
            full > early,
            "full {full}% should beat earliest exit {early}%"
        );
        // predictions clamped to [0, 100]
        assert!((0.0..=100.0).contains(&full));
    }

    #[test]
    fn too_little_history_errors() {
        let m = tiny_model(); // no history
        assert!(AccuracyModel::fit(&[&m], &GbdtParams::default(), 0).is_err());
    }
}
