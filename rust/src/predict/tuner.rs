//! Random-search hyperparameter tuner — the from-scratch substitute for the
//! paper's Optuna optimisation of the XGBoost predictors (DESIGN.md §1.3).
//!
//! Search space mirrors what the paper reports tuning: learning rate,
//! n_estimators, max_depth, colsample_bytree, min_child_weight. Selection
//! is by mean k-fold validation MSE.

use crate::util::rng::Rng;
use crate::util::stats;

use super::dataset::Dataset;
use super::gbdt::{Gbdt, GbdtParams};

/// Search configuration.
#[derive(Debug, Clone)]
pub struct TunerConfig {
    pub trials: usize,
    pub folds: usize,
    pub seed: u64,
}

impl Default for TunerConfig {
    fn default() -> Self {
        TunerConfig {
            trials: 20,
            folds: 3,
            seed: 7,
        }
    }
}

/// Result of a tuning run.
#[derive(Debug, Clone)]
pub struct TunerResult {
    pub best: GbdtParams,
    pub best_mse: f64,
    /// (params, validation mse) per trial, in evaluation order.
    pub trials: Vec<(GbdtParams, f64)>,
}

fn sample_params(rng: &mut Rng) -> GbdtParams {
    let n_estimators = [50usize, 100, 200, 400];
    let learning_rate = [0.03, 0.05, 0.1, 0.2];
    let subsample = [0.7, 0.85, 1.0];
    let colsample = [0.7, 1.0];
    let lambda = [0.5, 1.0, 2.0];
    GbdtParams {
        n_estimators: n_estimators[rng.below(4)],
        learning_rate: learning_rate[rng.below(4)],
        max_depth: rng.int_range(3, 10) as usize,
        min_child_weight: rng.int_range(1, 4) as usize,
        subsample: subsample[rng.below(3)],
        colsample_bytree: colsample[rng.below(2)],
        lambda: lambda[rng.below(3)],
        ..GbdtParams::default()
    }
}

fn cv_mse(data: &Dataset, params: &GbdtParams, folds: usize, seed: u64) -> f64 {
    let fold_mses: Vec<f64> = data
        .kfold(folds, seed)
        .into_iter()
        .filter(|(tr, va)| !tr.is_empty() && !va.is_empty())
        .map(|(tr, va)| {
            let m = Gbdt::fit(&tr, params);
            let pred = m.predict(&va.features);
            stats::mse(&pred, &va.targets)
        })
        .collect();
    stats::mean(&fold_mses)
}

/// Random-search over GBDT hyperparameters; returns the best params by
/// cross-validated MSE. Always includes the defaults as trial 0 so the
/// tuner can only improve on them.
pub fn random_search(data: &Dataset, cfg: &TunerConfig) -> TunerResult {
    let mut rng = Rng::new(cfg.seed);
    let mut trials = Vec::new();
    let mut best: Option<(GbdtParams, f64)> = None;
    for t in 0..cfg.trials.max(1) {
        let params = if t == 0 {
            GbdtParams::default()
        } else {
            sample_params(&mut rng)
        };
        let mse = cv_mse(data, &params, cfg.folds, cfg.seed);
        if best
            .as_ref()
            .map(|(_, bm)| mse < *bm)
            .unwrap_or(true)
        {
            best = Some((params.clone(), mse));
        }
        trials.push((params, mse));
    }
    let (best_params, best_mse) = best.unwrap();
    TunerResult {
        best: best_params,
        best_mse,
        trials,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quadratic(n: usize) -> Dataset {
        let mut rng = Rng::new(1);
        let mut d = Dataset::new(vec!["x".into()]);
        for _ in 0..n {
            let x = rng.f64() * 4.0 - 2.0;
            d.push(vec![x], x * x + rng.normal() * 0.05);
        }
        d
    }

    #[test]
    fn finds_reasonable_params() {
        let data = quadratic(300);
        let res = random_search(
            &data,
            &TunerConfig {
                trials: 5,
                folds: 3,
                seed: 2,
            },
        );
        assert_eq!(res.trials.len(), 5);
        assert!(res.best_mse < 0.1, "best cv mse {}", res.best_mse);
        // best must be min over trials
        let min_trial = res
            .trials
            .iter()
            .map(|(_, m)| *m)
            .fold(f64::INFINITY, f64::min);
        assert!((res.best_mse - min_trial).abs() < 1e-12);
    }

    #[test]
    fn deterministic() {
        let data = quadratic(150);
        let cfg = TunerConfig {
            trials: 4,
            folds: 2,
            seed: 9,
        };
        let a = random_search(&data, &cfg);
        let b = random_search(&data, &cfg);
        assert_eq!(a.best_mse, b.best_mse);
    }
}
