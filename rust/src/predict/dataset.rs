//! Feature-matrix container + train/test split for the prediction models.

use crate::util::rng::Rng;

/// Row-major feature matrix with targets.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub features: Vec<Vec<f64>>, // rows x cols
    pub targets: Vec<f64>,
    pub feature_names: Vec<String>,
}

impl Dataset {
    pub fn new(feature_names: Vec<String>) -> Dataset {
        Dataset {
            features: Vec::new(),
            targets: Vec::new(),
            feature_names,
        }
    }

    pub fn push(&mut self, row: Vec<f64>, target: f64) {
        debug_assert!(
            self.feature_names.is_empty() || row.len() == self.feature_names.len(),
            "row arity mismatch"
        );
        self.features.push(row);
        self.targets.push(target);
    }

    pub fn len(&self) -> usize {
        self.targets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.targets.is_empty()
    }

    pub fn n_features(&self) -> usize {
        self.features.first().map(|r| r.len()).unwrap_or(0)
    }

    /// Shuffled train/test split; `train_frac` in (0, 1]. The paper uses
    /// 80:20 for the accuracy model.
    pub fn split(&self, train_frac: f64, seed: u64) -> (Dataset, Dataset) {
        let mut idx: Vec<usize> = (0..self.len()).collect();
        Rng::new(seed).shuffle(&mut idx);
        let n_train = ((self.len() as f64) * train_frac).round() as usize;
        let n_train = n_train.clamp(1, self.len());
        let pick = |ids: &[usize]| Dataset {
            features: ids.iter().map(|&i| self.features[i].clone()).collect(),
            targets: ids.iter().map(|&i| self.targets[i]).collect(),
            feature_names: self.feature_names.clone(),
        };
        (pick(&idx[..n_train]), pick(&idx[n_train..]))
    }

    /// K-fold iterator: returns (train, valid) datasets per fold.
    pub fn kfold(&self, k: usize, seed: u64) -> Vec<(Dataset, Dataset)> {
        let k = k.max(2).min(self.len().max(2));
        let mut idx: Vec<usize> = (0..self.len()).collect();
        Rng::new(seed).shuffle(&mut idx);
        let mut folds = Vec::new();
        for f in 0..k {
            let valid_ids: Vec<usize> = idx
                .iter()
                .enumerate()
                .filter(|(i, _)| i % k == f)
                .map(|(_, &v)| v)
                .collect();
            let train_ids: Vec<usize> = idx
                .iter()
                .enumerate()
                .filter(|(i, _)| i % k != f)
                .map(|(_, &v)| v)
                .collect();
            let pick = |ids: &[usize]| Dataset {
                features: ids.iter().map(|&i| self.features[i].clone()).collect(),
                targets: ids.iter().map(|&i| self.targets[i]).collect(),
                feature_names: self.feature_names.clone(),
            };
            folds.push((pick(&train_ids), pick(&valid_ids)));
        }
        folds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn make(n: usize) -> Dataset {
        let mut d = Dataset::new(vec!["x".into()]);
        for i in 0..n {
            d.push(vec![i as f64], (i * 2) as f64);
        }
        d
    }

    #[test]
    fn split_sizes() {
        let d = make(100);
        let (tr, te) = d.split(0.8, 1);
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
        // disjoint and exhaustive
        let mut all: Vec<f64> = tr.targets.iter().chain(te.targets.iter()).cloned().collect();
        all.sort_by(|a, b| a.total_cmp(b));
        assert_eq!(all, (0..100).map(|i| (i * 2) as f64).collect::<Vec<_>>());
    }

    #[test]
    fn split_deterministic() {
        let d = make(50);
        let (a, _) = d.split(0.5, 7);
        let (b, _) = d.split(0.5, 7);
        assert_eq!(a.targets, b.targets);
    }

    #[test]
    fn kfold_covers_everything() {
        let d = make(25);
        let folds = d.kfold(5, 3);
        assert_eq!(folds.len(), 5);
        let mut seen: Vec<f64> = folds.iter().flat_map(|(_, v)| v.targets.clone()).collect();
        seen.sort_by(|a, b| a.total_cmp(b));
        assert_eq!(seen.len(), 25);
        for (tr, va) in &folds {
            assert_eq!(tr.len() + va.len(), 25);
        }
    }
}
