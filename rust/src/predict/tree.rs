//! Regression tree with histogram-based split finding — the weak learner
//! of the GBDT (the paper's XGBoost uses `tree_method=hist`; this is the
//! same idea built from scratch).

use crate::util::rng::Rng;

/// Tree growth parameters.
#[derive(Debug, Clone)]
pub struct TreeParams {
    pub max_depth: usize,
    /// Minimum number of samples in a leaf (XGBoost's min_child_weight
    /// with hessian=1 under squared loss).
    pub min_child_weight: usize,
    /// Number of histogram bins per feature.
    pub n_bins: usize,
    /// Fraction of features considered per split (colsample).
    pub colsample: f64,
    /// L2 regularisation on leaf values (XGBoost lambda).
    pub lambda: f64,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams {
            max_depth: 6,
            min_child_weight: 1,
            n_bins: 32,
            colsample: 1.0,
            lambda: 1.0,
        }
    }
}

#[derive(Debug, Clone)]
enum Node {
    Leaf {
        value: f64,
    },
    Split {
        feature: usize,
        threshold: f64,
        left: usize,
        right: usize,
    },
}

/// A fitted regression tree.
#[derive(Debug, Clone)]
pub struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    /// Fit to (features[rows], residuals[rows]) over the given row subset.
    pub fn fit(
        features: &[Vec<f64>],
        residuals: &[f64],
        rows: &[usize],
        params: &TreeParams,
        rng: &mut Rng,
    ) -> Tree {
        let n_features = features.first().map(|r| r.len()).unwrap_or(0);
        let mut tree = Tree { nodes: Vec::new() };
        let root_rows: Vec<usize> = rows.to_vec();
        tree.grow(features, residuals, root_rows, 0, n_features, params, rng);
        tree
    }

    fn leaf_value(residuals: &[f64], rows: &[usize], lambda: f64) -> f64 {
        // Squared loss: grad = -(r), hess = 1 => value = sum(r)/(n + lambda)
        let sum: f64 = rows.iter().map(|&i| residuals[i]).sum();
        sum / (rows.len() as f64 + lambda)
    }

    fn grow(
        &mut self,
        features: &[Vec<f64>],
        residuals: &[f64],
        rows: Vec<usize>,
        depth: usize,
        n_features: usize,
        params: &TreeParams,
        rng: &mut Rng,
    ) -> usize {
        let make_leaf = |t: &mut Tree, rows: &[usize]| {
            t.nodes.push(Node::Leaf {
                value: Self::leaf_value(residuals, rows, params.lambda),
            });
            t.nodes.len() - 1
        };
        if depth >= params.max_depth || rows.len() < 2 * params.min_child_weight {
            return make_leaf(self, &rows);
        }

        // Candidate features (colsample).
        let n_cand = ((n_features as f64) * params.colsample).ceil() as usize;
        let cand: Vec<usize> = if n_cand >= n_features {
            (0..n_features).collect()
        } else {
            rng.sample_indices(n_features, n_cand.max(1))
        };

        // Best split by gain (variance-reduction / XGBoost gain with h=1).
        let total_g: f64 = rows.iter().map(|&i| residuals[i]).sum();
        let total_n = rows.len() as f64;
        let lam = params.lambda;
        let score = |g: f64, n: f64| g * g / (n + lam);
        let base_score = score(total_g, total_n);
        let mut best: Option<(f64, usize, f64)> = None; // (gain, feature, threshold)

        for &f in &cand {
            // Histogram bins from min/max of this node's rows.
            let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
            for &i in &rows {
                let v = features[i][f];
                lo = lo.min(v);
                hi = hi.max(v);
            }
            if !(hi > lo) {
                continue;
            }
            let nb = params.n_bins;
            let width = (hi - lo) / nb as f64;
            let mut bin_g = vec![0.0f64; nb];
            let mut bin_n = vec![0usize; nb];
            for &i in &rows {
                let b = (((features[i][f] - lo) / width) as usize).min(nb - 1);
                bin_g[b] += residuals[i];
                bin_n[b] += 1;
            }
            let mut g_left = 0.0;
            let mut n_left = 0usize;
            for b in 0..nb - 1 {
                g_left += bin_g[b];
                n_left += bin_n[b];
                let n_right = rows.len() - n_left;
                if n_left < params.min_child_weight || n_right < params.min_child_weight {
                    continue;
                }
                let gain = score(g_left, n_left as f64)
                    + score(total_g - g_left, n_right as f64)
                    - base_score;
                let threshold = lo + width * (b + 1) as f64;
                if gain > 1e-12 && best.map(|(bg, _, _)| gain > bg).unwrap_or(true) {
                    best = Some((gain, f, threshold));
                }
            }
        }

        let Some((_, feature, threshold)) = best else {
            return make_leaf(self, &rows);
        };
        let (left_rows, right_rows): (Vec<usize>, Vec<usize>) =
            rows.iter().partition(|&&i| features[i][feature] < threshold);
        if left_rows.is_empty() || right_rows.is_empty() {
            return make_leaf(self, &rows);
        }
        // Reserve our slot, then grow children.
        let my_idx = self.nodes.len();
        self.nodes.push(Node::Leaf { value: 0.0 }); // placeholder
        let left = self.grow(features, residuals, left_rows, depth + 1, n_features, params, rng);
        let right = self.grow(features, residuals, right_rows, depth + 1, n_features, params, rng);
        self.nodes[my_idx] = Node::Split {
            feature,
            threshold,
            left,
            right,
        };
        my_idx
    }

    pub fn predict_one(&self, row: &[f64]) -> f64 {
        let mut idx = 0usize;
        loop {
            match &self.nodes[idx] {
                Node::Leaf { value } => return *value,
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    idx = if row[*feature] < *threshold { *left } else { *right };
                }
            }
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn depth(&self) -> usize {
        fn d(nodes: &[Node], i: usize) -> usize {
            match &nodes[i] {
                Node::Leaf { .. } => 1,
                Node::Split { left, right, .. } => 1 + d(nodes, *left).max(d(nodes, *right)),
            }
        }
        if self.nodes.is_empty() {
            0
        } else {
            d(&self.nodes, 0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step_data() -> (Vec<Vec<f64>>, Vec<f64>) {
        // y = 1 if x > 5 else -1
        let features: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64 / 10.0]).collect();
        let targets: Vec<f64> = (0..100).map(|i| if i > 50 { 1.0 } else { -1.0 }).collect();
        (features, targets)
    }

    #[test]
    fn fits_step_function() {
        let (x, y) = step_data();
        let rows: Vec<usize> = (0..x.len()).collect();
        let mut rng = Rng::new(0);
        let t = Tree::fit(&x, &y, &rows, &TreeParams::default(), &mut rng);
        assert!(t.predict_one(&[9.0]) > 0.8);
        assert!(t.predict_one(&[1.0]) < -0.8);
    }

    #[test]
    fn respects_max_depth() {
        let (x, y) = step_data();
        let rows: Vec<usize> = (0..x.len()).collect();
        let mut rng = Rng::new(0);
        let p = TreeParams {
            max_depth: 2,
            ..Default::default()
        };
        let t = Tree::fit(&x, &y, &rows, &p, &mut rng);
        assert!(t.depth() <= 3, "depth {} exceeds max_depth+1", t.depth());
    }

    #[test]
    fn constant_target_single_leaf() {
        let x: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64]).collect();
        let y = vec![2.0; 20];
        let rows: Vec<usize> = (0..20).collect();
        let mut rng = Rng::new(1);
        let t = Tree::fit(&x, &y, &rows, &TreeParams::default(), &mut rng);
        assert_eq!(t.n_nodes(), 1);
        // shrinks toward 0 by lambda: 40/(20+1)
        assert!((t.predict_one(&[5.0]) - 40.0 / 21.0).abs() < 1e-9);
    }

    #[test]
    fn two_feature_interaction() {
        // y depends only on feature 1; tree should ignore feature 0
        let mut x = Vec::new();
        let mut y = Vec::new();
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            let a = rng.f64();
            let b = rng.f64();
            x.push(vec![a, b]);
            y.push(if b > 0.5 { 3.0 } else { -3.0 });
        }
        let rows: Vec<usize> = (0..x.len()).collect();
        let t = Tree::fit(&x, &y, &rows, &TreeParams::default(), &mut Rng::new(0));
        assert!(t.predict_one(&[0.1, 0.9]) > 2.0);
        assert!(t.predict_one(&[0.9, 0.1]) < -2.0);
    }

    #[test]
    fn min_child_weight_blocks_tiny_leaves() {
        let (x, y) = step_data();
        let rows: Vec<usize> = (0..x.len()).collect();
        let p = TreeParams {
            min_child_weight: 60, // cannot split 100 rows into >= 60 + >= 60
            ..Default::default()
        };
        let t = Tree::fit(&x, &y, &rows, &p, &mut Rng::new(0));
        assert_eq!(t.n_nodes(), 1);
    }
}
