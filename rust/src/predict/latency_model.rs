//! Latency Prediction Model (paper §IV-B-i).
//!
//! One GBDT per layer type (Table I), trained on the profiler's layer
//! micro-benchmarks and queried at failure time to estimate the end-to-end
//! latency of each candidate technique. Targets are trained in log space
//! (layer latencies span orders of magnitude); reported MSE/R² (Table II)
//! are computed on the log-scale targets, matching the paper's
//! normalised-error regime.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::dnn::layers::{LayerKind, LayerSpec};

use super::dataset::Dataset;
use super::gbdt::{Gbdt, GbdtParams};

/// A profiled layer sample: spec + measured latency (milliseconds).
#[derive(Debug, Clone)]
pub struct LayerSample {
    pub spec: LayerSpec,
    pub latency_ms: f64,
}

/// Per-kind regression quality (paper Table II rows).
#[derive(Debug, Clone)]
pub struct KindQuality {
    pub kind: LayerKind,
    pub n_train: usize,
    pub n_test: usize,
    pub mse: f64,
    pub r2: f64,
}

/// The fitted latency model.
pub struct LatencyModel {
    models: BTreeMap<LayerKind, Gbdt>,
    /// Fallback ms-per-flop for kinds with no samples at all.
    fallback_ms_per_flop: f64,
}

fn log_target(ms: f64) -> f64 {
    (ms.max(1e-9)).ln()
}

fn unlog(v: f64) -> f64 {
    v.exp()
}

impl LatencyModel {
    /// Fit per-kind models. Returns the model plus held-out quality per
    /// kind (80:20 split per kind; the runtime models are refit on all
    /// samples afterwards).
    pub fn fit(
        samples: &[LayerSample],
        params: &GbdtParams,
        seed: u64,
    ) -> Result<(LatencyModel, Vec<KindQuality>)> {
        if samples.is_empty() {
            return Err(anyhow!("LatencyModel::fit: no samples"));
        }
        let mut by_kind: BTreeMap<LayerKind, Vec<&LayerSample>> = BTreeMap::new();
        for s in samples {
            by_kind.entry(s.spec.kind).or_default().push(s);
        }
        let mut models = BTreeMap::new();
        let mut quality = Vec::new();
        for (kind, group) in &by_kind {
            let mut data = Dataset::new(
                LayerSpec::FEATURE_NAMES.iter().map(|s| s.to_string()).collect(),
            );
            for s in group {
                data.push(s.spec.features(), log_target(s.latency_ms));
            }
            if data.len() >= 8 {
                let (tr, te) = data.split(0.8, seed);
                let m = Gbdt::fit(&tr, params);
                let (mse, r2) = m.evaluate(&te);
                quality.push(KindQuality {
                    kind: *kind,
                    n_train: tr.len(),
                    n_test: te.len(),
                    mse,
                    r2,
                });
            }
            // Runtime model uses every sample.
            models.insert(*kind, Gbdt::fit(&data, params));
        }
        // Fallback constant from the global flops/latency ratio.
        let tot_ms: f64 = samples.iter().map(|s| s.latency_ms).sum();
        let tot_flops: f64 = samples.iter().map(|s| s.spec.flops() as f64).sum();
        Ok((
            LatencyModel {
                models,
                fallback_ms_per_flop: if tot_flops > 0.0 { tot_ms / tot_flops } else { 1e-6 },
            },
            quality,
        ))
    }

    /// Predicted latency of one layer, milliseconds.
    pub fn predict_layer(&self, spec: &LayerSpec) -> f64 {
        match self.models.get(&spec.kind) {
            Some(m) => unlog(m.predict_one(&spec.features())),
            None => spec.flops() as f64 * self.fallback_ms_per_flop,
        }
    }

    /// Predicted compute latency of a layer path (sum over layers), ms.
    pub fn predict_path<'a>(&self, layers: impl IntoIterator<Item = &'a LayerSpec>) -> f64 {
        layers.into_iter().map(|l| self.predict_layer(l)).sum()
    }

    pub fn kinds(&self) -> Vec<LayerKind> {
        self.models.keys().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Synthetic ground truth: latency ~ a*flops + b*output + noise.
    fn synth_samples(kind: LayerKind, n: usize, seed: u64) -> Vec<LayerSample> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::new();
        for _ in 0..n {
            let h = [4usize, 8, 16, 32][rng.below(4)];
            let c = [8usize, 16, 32, 64][rng.below(4)];
            let f = [16usize, 32, 64][rng.below(3)];
            let spec = LayerSpec {
                kind,
                input_h: h,
                input_w: h,
                input_c: c,
                kernel: if kind == LayerKind::Conv { 3 } else { 0 },
                stride: 1,
                filters: if kind == LayerKind::Conv { f } else { 0 },
            };
            let lat = 1e-6 * spec.flops() as f64 * (1.0 + 0.05 * rng.normal()) + 0.01;
            out.push(LayerSample {
                spec,
                latency_ms: lat.max(1e-4),
            });
        }
        out
    }

    #[test]
    fn fits_flops_driven_latency() {
        let mut samples = synth_samples(LayerKind::Conv, 120, 1);
        samples.extend(synth_samples(LayerKind::Relu, 60, 2));
        let (model, quality) = LatencyModel::fit(&samples, &GbdtParams::default(), 3).unwrap();
        assert_eq!(quality.len(), 2);
        for q in &quality {
            assert!(q.r2 > 0.7, "{:?} r2 = {}", q.kind, q.r2);
        }
        // big conv must predict slower than small conv
        let small = LayerSpec {
            kind: LayerKind::Conv,
            input_h: 4,
            input_w: 4,
            input_c: 8,
            kernel: 3,
            stride: 1,
            filters: 16,
        };
        let big = LayerSpec {
            input_h: 32,
            input_w: 32,
            input_c: 64,
            filters: 64,
            ..small.clone()
        };
        assert!(model.predict_layer(&big) > model.predict_layer(&small));
    }

    #[test]
    fn path_is_sum() {
        let samples = synth_samples(LayerKind::Conv, 80, 4);
        let (model, _) = LatencyModel::fit(&samples, &GbdtParams::default(), 5).unwrap();
        let s = &samples[0].spec;
        let one = model.predict_layer(s);
        let three = model.predict_path([s, s, s]);
        assert!((three - 3.0 * one).abs() < 1e-9);
    }

    #[test]
    fn fallback_for_unseen_kind() {
        let samples = synth_samples(LayerKind::Conv, 40, 6);
        let (model, _) = LatencyModel::fit(&samples, &GbdtParams::default(), 7).unwrap();
        let dense = LayerSpec {
            kind: LayerKind::Dense,
            input_h: 1,
            input_w: 1,
            input_c: 128,
            kernel: 0,
            stride: 0,
            filters: 10,
        };
        assert!(model.predict_layer(&dense) > 0.0);
    }

    #[test]
    fn empty_errors() {
        assert!(LatencyModel::fit(&[], &GbdtParams::default(), 0).is_err());
    }
}
