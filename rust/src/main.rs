//! CONTINUER CLI — the leader entrypoint.
//!
//! Subcommands:
//!   info                         artifact/manifest summary
//!   exp <id>                     regenerate a paper table/figure
//!                                (fig2 fig3 fig4 fig6 table2 table5 fig7
//!                                 table6 fig8 table7 table8 e2e detection
//!                                 deploy drops all)
//!   serve                        e2e serving demo with failure injection
//!   profile                      run the layer profiler sweep
//!   detection-eval               detector-aggressiveness sweep (synthetic,
//!                                no artifacts needed)
//!   deploy-eval                  repartition deployment cost: break-before-make
//!                                vs make-before-break vs deployment-free
//!                                techniques (synthetic)
//!   drop-attribution             deadline sweep classifying drops inside
//!                                vs outside failure windows (synthetic)
//!   trace                        record a synthetic failure scenario and
//!                                export a Chrome/Perfetto trace (synthetic)
//!   clean-results                drop cached experiment results
//!
//! Common options:
//!   --artifacts <dir>   artifacts directory (default ./artifacts)
//!   --config <file>     TOML config (see configs/default.toml)
//!   --model <name>      resnet32 | mobilenetv2
//!   --seed <n>          simulation seed

use anyhow::{anyhow, Result};

use continuer::config::Config;
use continuer::exper::{self, ExpContext};
use continuer::util::cli::Args;

fn build_config(args: &Args) -> Result<Config> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::from_file(std::path::Path::new(path))?,
        None => Config::default(),
    };
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = dir.into();
    } else if cfg.artifacts_dir == std::path::PathBuf::from("artifacts") {
        cfg.artifacts_dir = exper::default_artifacts_dir();
    }
    if let Some(model) = args.get("model") {
        cfg.model = model.to_string();
    }
    cfg.seed = args.get_usize("seed", cfg.seed as usize)? as u64;
    cfg.profile_reps = args.get_usize("reps", cfg.profile_reps)?;
    cfg.validate()?;
    Ok(cfg)
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "help" | "--help" => {
            println!("{}", HELP);
            Ok(())
        }
        "info" => {
            let cfg = build_config(&args)?;
            exper::require_artifacts(&cfg.artifacts_dir)?;
            let ctx = ExpContext::open(cfg)?;
            println!("platform: {}", ctx.engine.platform_name());
            println!("artifacts: {}", ctx.config.artifacts_dir.display());
            println!("micro benchmarks: {}", ctx.store.micro.len());
            for (name, m) in &ctx.store.models {
                println!(
                    "model {name}: {} nodes, {} exits, {} skippable, full acc {:.2}%, {} history epochs",
                    m.num_nodes,
                    m.exits.len(),
                    m.skippable_nodes.len(),
                    m.final_accuracy.repartition * 100.0,
                    m.history.len()
                );
            }
            Ok(())
        }
        "exp" => {
            let id = args
                .positional
                .first()
                .ok_or_else(|| anyhow!("usage: continuer exp <id>"))?
                .clone();
            let cfg = build_config(&args)?;
            exper::require_artifacts(&cfg.artifacts_dir)?;
            let ctx = ExpContext::open(cfg)?;
            exper::run(&id, &ctx)
        }
        "serve" => {
            let n_requests = args.get_usize("requests", 60)?;
            let cfg = build_config(&args)?;
            exper::require_artifacts(&cfg.artifacts_dir)?;
            let ctx = ExpContext::open(cfg)?;
            exper::e2e::run_n(&ctx, n_requests)
        }
        "profile" => {
            let cfg = build_config(&args)?;
            exper::require_artifacts(&cfg.artifacts_dir)?;
            let ctx = ExpContext::open(cfg)?;
            exper::table2::run(&ctx)
        }
        // Synthetic health experiments: no artifacts required.
        "detection-eval" => {
            let seed = args.get_usize("seed", 0)? as u64;
            let out = args.get("out");
            continuer::exper::detection_eval::run_standalone(seed, out, args.flag("pretty"))
        }
        "deploy-eval" => {
            let seed = args.get_usize("seed", 0)? as u64;
            let out = args.get("out");
            continuer::exper::deploy_eval::run_standalone(seed, out, args.flag("pretty"))
        }
        "drop-attribution" => {
            let seed = args.get_usize("seed", 0)? as u64;
            let out = args.get("out");
            continuer::exper::drop_attribution::run_standalone(seed, out, args.flag("pretty"))
        }
        "trace" => {
            let requests = args.get_usize("requests", 2000)?;
            let replicas = args.get_usize("replicas", 2)?;
            let seed = args.get_usize("seed", 0)? as u64;
            let out = args.get("out");
            continuer::exper::trace_export::run_standalone(
                requests,
                replicas,
                seed,
                out,
                args.flag("pretty"),
            )
        }
        "clean-results" => {
            let cfg = build_config(&args)?;
            let dir = cfg.artifacts_dir.join("results");
            if dir.exists() {
                std::fs::remove_dir_all(&dir)?;
                println!("removed {}", dir.display());
            }
            Ok(())
        }
        other => Err(anyhow!("unknown subcommand '{other}'; try `continuer help`")),
    }
}

const HELP: &str = "\
CONTINUER — maintaining distributed DNN services during edge failures

USAGE: continuer <subcommand> [options]

SUBCOMMANDS
  info              summarize the artifact manifest
  exp <id>          regenerate a paper table/figure:
                    fig2 fig3 fig4 fig6 table2 table5 fig7 table6 fig8
                    table7 table8 e2e detection deploy drops all
  serve             end-to-end serving demo with failure injection
  profile           layer-latency profiling sweep (= exp table2)
  detection-eval    detector sweep: downtime vs false failovers (synthetic)
  deploy-eval       repartition deployment cost: BBM vs MBB vs early-exit/skip
                    (synthetic)
  drop-attribution  deadline sweep: drops inside vs outside outages (synthetic)
  trace             export a Chrome trace_event JSON of a synthetic failure
                    scenario — stage spans per (replica, node), failover and
                    quarantine markers; open in https://ui.perfetto.dev
  clean-results     drop cached experiment results

OPTIONS
  --artifacts <dir>  artifacts directory (default ./artifacts)
  --config <file>    TOML config file
  --model <name>     resnet32 | mobilenetv2 (for serve)
  --requests <n>     request count for serve (default 60) / trace (default 2000)
  --replicas <n>     pipeline replicas for trace (default 2)
  --out <file>       output path for trace / detection-eval / deploy-eval /
                     drop-attribution
  --pretty           pretty-print emitted JSON
  --seed <n>         simulation seed
  --reps <n>         profiling repetitions";
