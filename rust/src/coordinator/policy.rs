//! Recovery-policy interface: the single decision point the serving
//! engine consults when a node failure is detected. CONTINUER's
//! additive-weighting scheduler and every baseline in [`crate::baselines`]
//! implement the same trait, so experiments compare policies inside the
//! identical engine instead of through per-policy serving loops.

use anyhow::Result;

use crate::config::Objectives;

use super::scheduler::{select, CandidateMetrics, Decision};

/// A recovery policy: given the candidate techniques (with their predicted
/// accuracy/latency and empirical downtime), pick one.
///
/// `Send + Sync` because each [`super::failover::Failover`] controller —
/// and the boxed policy inside it — moves onto a worker thread when the
/// engine runs sharded. Policies are decision tables over the candidate
/// metrics (no shared mutable state), so every implementation satisfies
/// the bound structurally.
pub trait RecoveryPolicy: Send + Sync {
    fn name(&self) -> &'static str;
    fn decide(&self, candidates: &[CandidateMetrics]) -> Result<Decision>;
}

/// CONTINUER itself: simple additive weighting over min-max-normalised
/// objectives (paper §IV-C).
pub struct Continuer(pub Objectives);

impl RecoveryPolicy for Continuer {
    fn name(&self) -> &'static str {
        "continuer"
    }

    fn decide(&self, candidates: &[CandidateMetrics]) -> Result<Decision> {
        select(candidates, &self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::variants::Technique;

    #[test]
    fn continuer_policy_matches_select() {
        let cands = vec![
            CandidateMetrics {
                technique: Technique::Repartition,
                accuracy: 90.0,
                latency_ms: 30.0,
                downtime_ms: 4.0,
            },
            CandidateMetrics {
                technique: Technique::EarlyExit(3),
                accuracy: 70.0,
                latency_ms: 8.0,
                downtime_ms: 1.0,
            },
        ];
        let obj = Objectives::default();
        let p = Continuer(obj.clone());
        let via_policy = p.decide(&cands).unwrap();
        let via_select = select(&cands, &obj).unwrap();
        assert_eq!(via_policy.chosen, via_select.chosen);
        assert_eq!(p.name(), "continuer");
    }
}
