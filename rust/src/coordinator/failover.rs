//! Failover controller: the runtime-phase state machine that reacts to a
//! node failure by querying the estimator, running the Scheduler and
//! reconfiguring the serving path (paper Fig. 1, runtime phase).

use std::time::Instant;

use anyhow::Result;

use crate::config::Objectives;
use crate::dnn::variants::Technique;

use super::estimator::Estimator;
use super::scheduler::{select, CandidateMetrics, Decision};

/// Current serving mode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Mode {
    /// All nodes up; full pipeline.
    Healthy,
    /// Operating under a recovery technique after `failed` failed.
    Degraded { failed: usize, technique: Technique },
}

/// Timing breakdown of one failover (the paper's downtime components).
#[derive(Debug, Clone)]
pub struct FailoverReport {
    pub failed_node: usize,
    pub decision: Decision,
    /// Time to build candidate metrics (predictor queries), ms.
    pub predict_ms: f64,
    /// Time to run the scheduler selection, ms.
    pub select_ms: f64,
    /// Reinstate constant applied for the chosen technique, ms.
    pub reinstate_ms: f64,
    /// Full candidate metrics as seen by the scheduler.
    pub candidates: Vec<CandidateMetrics>,
}

impl FailoverReport {
    /// Total downtime attributed to selection (paper Table VIII):
    /// prediction retrieval + selection + reinstate.
    pub fn downtime_ms(&self) -> f64 {
        self.predict_ms + self.select_ms + self.reinstate_ms
    }
}

/// The failover controller.
pub struct Failover {
    pub objectives: Objectives,
    pub mode: Mode,
    pub history: Vec<FailoverReport>,
}

impl Failover {
    pub fn new(objectives: Objectives) -> Failover {
        Failover {
            objectives,
            mode: Mode::Healthy,
            history: Vec::new(),
        }
    }

    /// Handle the failure of `failed`: query predictions, select, switch
    /// mode. Returns the report (also kept in history).
    pub fn on_failure(&mut self, est: &Estimator, failed: usize) -> Result<FailoverReport> {
        let t0 = Instant::now();
        let candidates = est.candidate_metrics(failed)?;
        let predict_ms = t0.elapsed().as_secs_f64() * 1e3;

        let t1 = Instant::now();
        let decision = select(&candidates, &self.objectives)?;
        let select_ms = t1.elapsed().as_secs_f64() * 1e3;

        let reinstate_ms = match decision.chosen {
            Technique::EarlyExit(_) => 0.0,
            _ => est.reinstate_ms,
        };
        self.mode = Mode::Degraded {
            failed,
            technique: decision.chosen,
        };
        let report = FailoverReport {
            failed_node: failed,
            decision,
            predict_ms,
            select_ms,
            reinstate_ms,
            candidates,
        };
        self.history.push(report.clone());
        Ok(report)
    }

    /// Node recovered: back to the healthy pipeline.
    pub fn on_recovery(&mut self, node: usize) {
        if let Mode::Degraded { failed, .. } = self.mode {
            if failed == node {
                self.mode = Mode::Healthy;
            }
        }
    }

    pub fn technique(&self) -> Option<Technique> {
        match self.mode {
            Mode::Healthy => None,
            Mode::Degraded { technique, .. } => Some(technique),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovery_only_clears_matching_failure() {
        let mut f = Failover::new(Objectives::default());
        f.mode = Mode::Degraded {
            failed: 3,
            technique: Technique::Repartition,
        };
        f.on_recovery(5);
        assert!(matches!(f.mode, Mode::Degraded { failed: 3, .. }));
        f.on_recovery(3);
        assert_eq!(f.mode, Mode::Healthy);
        assert_eq!(f.technique(), None);
    }
}
