//! Failover controller: the runtime-phase state machine that reacts to a
//! node failure by querying the estimator, consulting its
//! [`RecoveryPolicy`] and reconfiguring the serving path (paper Fig. 1,
//! runtime phase). Each pipeline replica owns one controller, so failures
//! degrade replicas independently.

use std::time::Instant;

use anyhow::Result;

use crate::config::Objectives;
use crate::dnn::variants::Technique;

use super::estimator::MetricsSource;
use super::policy::{Continuer, RecoveryPolicy};
use super::scheduler::{CandidateMetrics, Decision};

/// Current serving mode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Mode {
    /// All nodes up; full pipeline.
    Healthy,
    /// Operating under a recovery technique after `failed` failed.
    Degraded { failed: usize, technique: Technique },
}

/// Timing breakdown of one failover (the paper's downtime components).
#[derive(Debug, Clone)]
pub struct FailoverReport {
    pub failed_node: usize,
    pub decision: Decision,
    /// Time to build candidate metrics (predictor queries), ms.
    pub predict_ms: f64,
    /// Time to run the policy's selection, ms.
    pub select_ms: f64,
    /// Reinstate constant applied for the chosen technique, ms.
    pub reinstate_ms: f64,
    /// Full candidate metrics as seen by the policy.
    pub candidates: Vec<CandidateMetrics>,
}

impl FailoverReport {
    /// Total downtime attributed to selection (paper Table VIII):
    /// prediction retrieval + selection + reinstate.
    pub fn downtime_ms(&self) -> f64 {
        self.predict_ms + self.select_ms + self.reinstate_ms
    }
}

/// The failover controller, parameterised by the recovery policy so the
/// baselines run through the identical machinery.
pub struct Failover {
    pub policy: Box<dyn RecoveryPolicy>,
    pub mode: Mode,
    pub history: Vec<FailoverReport>,
    /// How many times the path was repartitioned back onto a cleared
    /// node (rollbacks of false positives included).
    pub reintegrations: usize,
}

impl Failover {
    /// CONTINUER's own scheduler under the given objective weights.
    pub fn new(objectives: Objectives) -> Failover {
        Failover::with_policy(Box::new(Continuer(objectives)))
    }

    /// Any recovery policy (baselines included).
    pub fn with_policy(policy: Box<dyn RecoveryPolicy>) -> Failover {
        Failover {
            policy,
            mode: Mode::Healthy,
            history: Vec::new(),
            reintegrations: 0,
        }
    }

    /// Handle the failure of `failed`: query predictions, let the policy
    /// select, switch mode. Returns the report (also kept in history).
    pub fn on_failure(&mut self, est: &dyn MetricsSource, failed: usize) -> Result<FailoverReport> {
        // `x + 0.0` is bit-identical for every finite candidate downtime,
        // so delegating keeps unpriced runs byte-equal to the pre-pricing
        // controller.
        self.on_failure_priced(est, failed, 0.0)
    }

    /// [`Self::on_failure`] with the repartition candidate's downtime
    /// raised by `extra_repartition_downtime_ms` before the policy
    /// decides — how the engine charges repartition for its modeled
    /// weight-transfer + warm-up window (break-before-make), so the
    /// selection prices deployment cost like any other downtime.
    pub fn on_failure_priced(
        &mut self,
        est: &dyn MetricsSource,
        failed: usize,
        extra_repartition_downtime_ms: f64,
    ) -> Result<FailoverReport> {
        let t0 = Instant::now();
        let mut candidates = est.candidate_metrics(failed)?;
        super::scheduler::price_repartition_deploy(&mut candidates, extra_repartition_downtime_ms);
        let predict_ms = t0.elapsed().as_secs_f64() * 1e3;

        let t1 = Instant::now();
        let decision = self.policy.decide(&candidates)?;
        let select_ms = t1.elapsed().as_secs_f64() * 1e3;

        let reinstate_ms = match decision.chosen {
            Technique::EarlyExit(_) => 0.0,
            _ => est.reinstate_ms(),
        };
        self.mode = Mode::Degraded {
            failed,
            technique: decision.chosen,
        };
        let report = FailoverReport {
            failed_node: failed,
            decision,
            predict_ms,
            select_ms,
            reinstate_ms,
            candidates,
        };
        self.history.push(report.clone());
        Ok(report)
    }

    /// The node was cleared for reintegration (by the oracle instantly,
    /// or by the health monitor only after its quarantine window — so a
    /// flapping node never bounces the mode here). For a false-positive
    /// failover this is the rollback. Returns whether the mode actually
    /// switched back to healthy.
    pub fn on_recovery(&mut self, node: usize) -> bool {
        if let Mode::Degraded { failed, .. } = self.mode {
            if failed == node {
                self.mode = Mode::Healthy;
                self.reintegrations += 1;
                return true;
            }
        }
        false
    }

    pub fn technique(&self) -> Option<Technique> {
        match self.mode {
            Mode::Healthy => None,
            Mode::Degraded { technique, .. } => Some(technique),
        }
    }

    /// The failure the replica is currently degraded around, if any.
    pub fn failed_node(&self) -> Option<usize> {
        match self.mode {
            Mode::Healthy => None,
            Mode::Degraded { failed, .. } => Some(failed),
        }
    }

    /// Pick the technique that keeps the replica serving *while* a
    /// repartition deploys (make-before-break): the policy's choice over
    /// the repartition-free candidates only — those need no weight
    /// movement, so they are live immediately. Returns `None` when no
    /// such candidate exists (the deployment then stalls like
    /// break-before-make). Does not switch mode, time itself, or touch
    /// history: this is a side query, not a failover.
    pub fn fallback_technique(
        &self,
        est: &dyn MetricsSource,
        failed: usize,
    ) -> Result<Option<Technique>> {
        let candidates: Vec<CandidateMetrics> = est
            .candidate_metrics(failed)?
            .into_iter()
            .filter(|c| c.technique != Technique::Repartition)
            .collect();
        if candidates.is_empty() {
            return Ok(None);
        }
        // A fixed policy can "choose" a technique outside the filtered
        // set or refuse to decide at all without its pet candidate
        // (always-repartition); fall back to the first repartition-free
        // candidate rather than deploy-blocking on a plan that is not
        // live yet.
        let chosen = match self.policy.decide(&candidates) {
            Ok(d) if candidates.iter().any(|c| c.technique == d.chosen) => d.chosen,
            _ => candidates[0].technique,
        };
        Ok(Some(chosen))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovery_only_clears_matching_failure() {
        let mut f = Failover::new(Objectives::default());
        f.mode = Mode::Degraded {
            failed: 3,
            technique: Technique::Repartition,
        };
        assert!(!f.on_recovery(5), "non-matching node must not clear");
        assert!(matches!(f.mode, Mode::Degraded { failed: 3, .. }));
        assert_eq!(f.failed_node(), Some(3));
        assert_eq!(f.reintegrations, 0);
        assert!(f.on_recovery(3));
        assert_eq!(f.mode, Mode::Healthy);
        assert_eq!(f.technique(), None);
        assert_eq!(f.failed_node(), None);
        assert_eq!(f.reintegrations, 1);
    }

    #[test]
    fn policy_drives_the_choice() {
        struct AlwaysFirst;
        impl RecoveryPolicy for AlwaysFirst {
            fn name(&self) -> &'static str {
                "always-first"
            }
            fn decide(&self, candidates: &[CandidateMetrics]) -> Result<Decision> {
                Ok(Decision::fixed(candidates[0].technique))
            }
        }
        struct Stub;
        impl MetricsSource for Stub {
            fn candidate_metrics(&self, failed: usize) -> Result<Vec<CandidateMetrics>> {
                Ok(vec![
                    CandidateMetrics {
                        technique: Technique::SkipConnection(failed),
                        accuracy: 85.0,
                        latency_ms: 25.0,
                        downtime_ms: 3.0,
                    },
                    CandidateMetrics {
                        technique: Technique::Repartition,
                        accuracy: 90.0,
                        latency_ms: 30.0,
                        downtime_ms: 4.0,
                    },
                ])
            }
            fn reinstate_ms(&self) -> f64 {
                1.0
            }
        }
        let mut f = Failover::with_policy(Box::new(AlwaysFirst));
        let report = f.on_failure(&Stub, 3).unwrap();
        assert_eq!(report.decision.chosen, Technique::SkipConnection(3));
        assert!(matches!(
            f.mode,
            Mode::Degraded { failed: 3, technique: Technique::SkipConnection(3) }
        ));
        // skip pays the reinstate constant
        assert!((report.reinstate_ms - 1.0).abs() < 1e-12);
    }
}
