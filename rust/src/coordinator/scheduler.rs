//! The CONTINUER Scheduler (paper §IV-C): selects the recovery technique
//! for a node failure from each candidate's estimated accuracy, estimated
//! end-to-end latency and (empirical) downtime, combined by classic simple
//! additive weighting over min-max-normalised objectives (paper Eq. 2):
//!
//!   select  argmax  w1·A' − w2·L' − w3·D'
//!
//! (accuracy is a benefit; latency and downtime are costs). Weights are
//! the user-defined objectives; an unspecified objective gets weight 0.

use anyhow::{bail, Result};

use crate::config::Objectives;
use crate::dnn::variants::Technique;
use crate::util::stats::min_max_normalize;

/// Metrics of one candidate technique, as fed to the scheduler.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CandidateMetrics {
    pub technique: Technique,
    /// Accuracy, percent (estimated by the Accuracy Prediction Model).
    pub accuracy: f64,
    /// End-to-end latency, ms (estimated by the Latency Prediction Model).
    pub latency_ms: f64,
    /// Downtime, ms (empirical).
    pub downtime_ms: f64,
}

/// A scoring decision with full transparency for logging/experiments.
#[derive(Debug, Clone)]
pub struct Decision {
    pub chosen: Technique,
    /// (technique, score) for every candidate, in input order. Empty for
    /// policies that pick without scoring (the fixed baselines).
    pub scores: Vec<(Technique, f64)>,
}

impl Decision {
    /// A decision made without scoring (fixed baseline policies).
    pub fn fixed(chosen: Technique) -> Decision {
        Decision {
            chosen,
            scores: Vec::new(),
        }
    }
}

/// Score and select among candidates. Deterministic tie-break: the earlier
/// candidate in input order wins (candidates are enumerated in the fixed
/// order repartition, early-exit, skip).
pub fn select(candidates: &[CandidateMetrics], weights: &Objectives) -> Result<Decision> {
    if candidates.is_empty() {
        bail!("scheduler: no candidate techniques");
    }
    weights.validate()?;
    let acc: Vec<f64> = candidates.iter().map(|c| c.accuracy).collect();
    let lat: Vec<f64> = candidates.iter().map(|c| c.latency_ms).collect();
    let down: Vec<f64> = candidates.iter().map(|c| c.downtime_ms).collect();
    let acc_n = min_max_normalize(&acc);
    let lat_n = min_max_normalize(&lat);
    let down_n = min_max_normalize(&down);
    let mut scores = Vec::with_capacity(candidates.len());
    let mut best: Option<(usize, f64)> = None;
    for i in 0..candidates.len() {
        let s = weights.w_accuracy * acc_n[i]
            - weights.w_latency * lat_n[i]
            - weights.w_downtime * down_n[i];
        scores.push((candidates[i].technique, s));
        if best.map(|(_, bs)| s > bs).unwrap_or(true) {
            best = Some((i, s));
        }
    }
    let (idx, _) = best.unwrap();
    Ok(Decision {
        chosen: candidates[idx].technique,
        scores,
    })
}

/// Charge the repartition candidate(s) for a modeled deployment window:
/// `extra_downtime_ms` of weight-transfer + warm-up time that
/// break-before-make repartitioning would stall serving for. Applied
/// *before* [`select`] min-max-normalises, so deployment cost competes
/// with the other candidates' downtime on equal terms.
///
/// Note the normalisation consequence: with only two candidates the
/// normalised downtimes are always {0, 1} whatever the raw gap, so a
/// constant surcharge can never flip a two-candidate decision — pricing
/// only bites when at least three candidates spread the scale (see
/// `deploy_pricing_flips_three_candidate_decision`).
pub fn price_repartition_deploy(candidates: &mut [CandidateMetrics], extra_downtime_ms: f64) {
    for c in candidates {
        if c.technique == Technique::Repartition {
            c.downtime_ms += extra_downtime_ms;
        }
    }
}

/// Sweep helper for Table VII: all weight combinations in {lo..hi} steps.
pub fn weight_sweep(lo: f64, hi: f64, step: f64) -> Vec<Objectives> {
    let mut out = Vec::new();
    let n = ((hi - lo) / step).round() as usize;
    for i in 0..=n {
        for j in 0..=n {
            for k in 0..=n {
                out.push(Objectives::new(
                    lo + i as f64 * step,
                    lo + j as f64 * step,
                    lo + k as f64 * step,
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(t: Technique, a: f64, l: f64, d: f64) -> CandidateMetrics {
        CandidateMetrics {
            technique: t,
            accuracy: a,
            latency_ms: l,
            downtime_ms: d,
        }
    }

    fn three() -> Vec<CandidateMetrics> {
        vec![
            cand(Technique::Repartition, 90.0, 30.0, 4.0), // accurate, slow
            cand(Technique::EarlyExit(3), 70.0, 8.0, 1.0), // fast, inaccurate
            cand(Technique::SkipConnection(4), 85.0, 25.0, 3.0),
        ]
    }

    #[test]
    fn accuracy_heavy_picks_repartition() {
        let d = select(&three(), &Objectives::new(0.9, 0.05, 0.05)).unwrap();
        assert_eq!(d.chosen, Technique::Repartition);
    }

    #[test]
    fn latency_heavy_picks_early_exit() {
        let d = select(&three(), &Objectives::new(0.05, 0.9, 0.05)).unwrap();
        assert_eq!(d.chosen, Technique::EarlyExit(3));
    }

    #[test]
    fn single_candidate_trivial() {
        let only = vec![cand(Technique::Repartition, 90.0, 30.0, 4.0)];
        let d = select(&only, &Objectives::default()).unwrap();
        assert_eq!(d.chosen, Technique::Repartition);
    }

    #[test]
    fn empty_candidates_error() {
        assert!(select(&[], &Objectives::default()).is_err());
    }

    #[test]
    fn invalid_weights_error() {
        assert!(select(&three(), &Objectives::new(0.0, 0.0, 0.0)).is_err());
    }

    #[test]
    fn sweep_count_matches_paper_grid() {
        // 0.1..0.9 step 0.1 -> 9 values per weight -> 729 combos
        let combos = weight_sweep(0.1, 0.9, 0.1);
        assert_eq!(combos.len(), 729);
        assert!(combos.iter().all(|o| o.validate().is_ok()));
    }

    #[test]
    fn scores_reported_for_all() {
        let d = select(&three(), &Objectives::default()).unwrap();
        assert_eq!(d.scores.len(), 3);
    }

    #[test]
    fn normalisation_makes_scale_irrelevant() {
        // Scaling all latencies by 1000x must not change the decision.
        let a = select(&three(), &Objectives::default()).unwrap();
        let scaled: Vec<CandidateMetrics> = three()
            .iter()
            .map(|c| CandidateMetrics {
                latency_ms: c.latency_ms * 1000.0,
                ..*c
            })
            .collect();
        let b = select(&scaled, &Objectives::default()).unwrap();
        assert_eq!(a.chosen, b.chosen);
    }

    #[test]
    fn deploy_pricing_leaves_other_candidates_untouched() {
        let mut cands = three();
        price_repartition_deploy(&mut cands, 25.0);
        assert_eq!(cands[0].downtime_ms, 29.0);
        assert_eq!(cands[1].downtime_ms, 1.0);
        assert_eq!(cands[2].downtime_ms, 3.0);
        // Zero surcharge is bit-exact identity.
        let mut cands = three();
        price_repartition_deploy(&mut cands, 0.0);
        assert_eq!(cands, three());
    }

    #[test]
    fn deploy_pricing_flips_three_candidate_decision() {
        // Accuracy-leaning weights pick repartition when its deployment
        // is free, but a large modeled transfer window re-ranks it below
        // skip. Needs >= 3 candidates: with two, min-max normalisation
        // maps downtimes to {0, 1} regardless of the surcharge.
        let w = Objectives::new(0.75, 0.1, 0.15);
        let cands = vec![
            cand(Technique::Repartition, 90.0, 30.0, 4.0),
            cand(Technique::EarlyExit(3), 60.0, 8.0, 1.0),
            cand(Technique::SkipConnection(4), 85.0, 25.0, 3.0),
        ];
        assert_eq!(select(&cands, &w).unwrap().chosen, Technique::Repartition);
        let mut priced = cands.clone();
        price_repartition_deploy(&mut priced, 100.0);
        assert_eq!(
            select(&priced, &w).unwrap().chosen,
            Technique::SkipConnection(4)
        );
    }

    #[test]
    fn prop_chosen_has_max_score() {
        use crate::util::proptest::{check, prop_assert};
        check(200, 0xABCD, |g| {
            let n = g.usize(1, 6);
            let cands: Vec<CandidateMetrics> = (0..n)
                .map(|i| {
                    cand(
                        Technique::EarlyExit(i + 1),
                        g.f64(10.0, 100.0),
                        g.f64(1.0, 50.0),
                        g.f64(0.1, 20.0),
                    )
                })
                .collect();
            let w = Objectives::new(g.f64(0.1, 0.9), g.f64(0.1, 0.9), g.f64(0.1, 0.9));
            let d = select(&cands, &w).map_err(|e| e.to_string())?;
            let max = d
                .scores
                .iter()
                .map(|(_, s)| *s)
                .fold(f64::NEG_INFINITY, f64::max);
            let chosen_score = d
                .scores
                .iter()
                .find(|(t, _)| *t == d.chosen)
                .map(|(_, s)| *s)
                .unwrap();
            prop_assert((chosen_score - max).abs() < 1e-12, "chosen must have max score")
        });
    }
}
