//! The CONTINUER framework (paper §III-IV): profiler phase (offline) and
//! runtime phase (scheduler + failover + serving loop).

pub mod batcher;
pub mod estimator;
pub mod failover;
pub mod profiler;
pub mod scheduler;
pub mod service;

pub use estimator::Estimator;
pub use failover::{Failover, FailoverReport, Mode};
pub use profiler::{fit_platform, platform_transform, DowntimeTable, LayerProfiler, PlatformLatencyModel};
pub use scheduler::{select, weight_sweep, CandidateMetrics, Decision};
