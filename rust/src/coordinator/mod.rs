//! The CONTINUER framework (paper §III-IV) and the serving stack built on
//! top of it.
//!
//! Offline phase: [`profiler`] fits the per-platform latency models and
//! the downtime table. Runtime phase, bottom-up:
//!
//! - [`estimator`] bridges the fitted predictors to per-candidate metrics
//!   ([`MetricsSource`] abstracts it for tests).
//! - [`policy`] is the recovery decision: the [`RecoveryPolicy`] trait,
//!   implemented by CONTINUER's additive-weighting scheduler
//!   ([`Continuer`], via [`scheduler`]) and by every baseline in
//!   [`crate::baselines`] — all policies run inside the identical engine.
//! - [`failover`] is the per-replica state machine that reacts to a
//!   detected failure by consulting its policy and switching the path.
//!   Detections come from [`crate::health`] in monitored runs, so they
//!   can be false positives the controller later rolls back when the
//!   quarantine gate clears the node.
//! - [`batcher`] picks compiled batch sizes under queue pressure.
//! - [`router`] spreads arrivals over pipeline replicas: round-robin,
//!   join-shortest-queue, and — for heterogeneous fleets with per-replica
//!   [`engine::EngineConfig::speed_factors`] — smooth weighted
//!   round-robin ([`router::WrrState`]) and speed-weighted JSQ, which
//!   ranks replicas by expected drain time (`outstanding /
//!   effective_speed`) so a detected `Degraded` replica sheds load
//!   before any failover threshold trips.
//! - [`engine`] is the event-driven serving core: a pluggable min-queue
//!   of timestamped events (arrivals, failures, detections, batcher
//!   timeouts, stage start/completion) with per-stage occupancy, so up
//!   to `pipeline_depth` batches pipeline through each replica and
//!   replica shards fail independently.
//! - [`service`] holds the report types and the seed-compatible
//!   single-pipeline entry point.
//!
//! # Event core
//!
//! The engine pops events in exact `(time, push-sequence)` order from an
//! [`crate::util::eventq::EventQueue`], selected per run by
//! [`engine::EngineConfig::event_queue`]:
//! [`QueueKind::Heap`](crate::util::eventq::QueueKind) is the
//! `BinaryHeap` reference (`O(log n)` per operation);
//! [`QueueKind::Calendar`](crate::util::eventq::QueueKind) — the
//! default — is an adaptive calendar queue (power-of-two bucket array
//! keyed by time, bucket width retuned from the observed inter-event
//! gap on resize) with amortized `O(1)` push and pop at the
//! million-event rates `benches/engine_scale.rs` drives. The two are
//! interchangeable by construction, not by luck: both order by the
//! identical `(at_ms, seq)` key, so pop order — and with it every
//! [`service::ServiceReport`] — is byte-identical between them on the
//! same seed (asserted per mode in `tests/sharded_equivalence.rs` and
//! on arbitrary schedules in `tests/eventq_property.rs`). Each shard of
//! a sharded run owns its own instance of the configured queue.
//!
//! # Repartition deployment
//!
//! Repartitioning after a failure is not a free swap: the re-hosted
//! block's weights must move over the cluster's links and the receiving
//! node pays a warm-up before the new partition serves. The engine
//! models this as a deployment state machine
//! ([`engine::DeploymentConfig`], [`service::DeployMode`]): when the
//! failover decision picks repartition, per-host weight transfers are
//! scheduled from [`engine::StageBackend::unit_weight_bytes`] and
//! [`engine::StageBackend::deploy_transfer_ms`], and the new plan only
//! becomes live at the cut-over event. Under
//! [`service::DeployMode::BreakBeforeMake`] dispatch stalls through the
//! window (requests queue or expire against their deadlines, and the
//! stall is priced into the decision via
//! [`scheduler::price_repartition_deploy`]); under
//! [`service::DeployMode::MakeBeforeBreak`] the replica keeps serving
//! on a repartition-free fallback (early-exit/skip, chosen by
//! [`failover::Failover::fallback_technique`]) and cuts over atomically
//! — nothing stalls, nothing requeues. Every deployment lands in
//! [`service::ServiceReport::deploy_windows`], and
//! [`service::DeployMode::Instantaneous`] (the default) reproduces the
//! pre-deployment engine byte-for-byte. Reintegration stays
//! instantaneous by design: the recovered node kept its weights, so
//! rolling back is a routing flip, not a deployment.
//!
//! The engine's steady-state hot path allocates nothing per event: step
//! plans are memoized per replica in a [`plan_cache::PlanCache`]
//! (`Arc<[Step]>`, one miss per distinct technique/failure pair),
//! in-flight batches live in a generational slab whose slots are
//! free-list reused, synthetic-path activations are shape-only handles
//! (the real PJRT path materializes its batch in one gather), and
//! latency metrics stream into a log-bucketed histogram + online moments
//! instead of a grow-forever completion vector (exact records return
//! behind `EngineConfig::record_completions`).
//!
//! # Threading
//!
//! The engine runs in one of two modes ([`engine::Execution`]):
//! `Sequential` is the single-threaded deterministic reference;
//! `Sharded(workers)` runs one shard per replica on real threads
//! ([`crate::util::threadpool`]). Everything a shard touches is already
//! per-replica state — event queue, slab, plan cache, streaming metrics,
//! failover controller — so shards share nothing mutable: the positional
//! policies (round-robin, weighted round-robin) are pre-split at
//! generation time, the JSQ family is fed live over channels routed by
//! per-replica atomic outstanding counters and shard-published
//! effective-speed estimates ([`router::ShardRouter`]), and per-shard
//! reports merge at the end (exact histogram-bucket adds, pairwise
//! Welford combine, record/window concat). Live-routed shards can also
//! steal work from each other ([`engine::EngineConfig::steal`]): a shard
//! at its pipeline-depth limit parks queue overflow in a shared
//! per-shard injector pool, and an idle shard reclaims its own parked
//! work first, then takes up to one max-size batch from the fullest
//! sibling — conservation (every request served or dropped exactly
//! once) is asserted by the `sharded_equivalence` property suite, and
//! the sequential engine carries a deterministic `rebalance` reference
//! of the same policy. Same-seed sequential and positionally-sharded
//! runs produce bucket-for-bucket identical merged metrics — asserted in
//! the engine tests and the `sharded_equivalence` property test. The
//! [`RecoveryPolicy`] trait requires `Send + Sync` so boxed policies can
//! cross onto worker threads; the PJRT-backed [`service::run`] path
//! stays on [`engine::serve_sequential`] because the real cluster holds
//! `RefCell` caches.
//!
//! # Observability
//!
//! Every engine transition is emitted into an
//! [`EventSink`](crate::obs::EventSink) (see [`crate::obs`] for the
//! event taxonomy: arrival, batch dispatch, stage start/done, raw
//! condition change, failover/recovery detection, quarantine
//! enter/exit, drop, completion). The engine is *generic* over the
//! sink, so the cost model is compile-time: the default
//! [`NoopSink`](crate::obs::NoopSink) monomorphizes every emission to
//! nothing (the zero-allocation steady state is untouched — the bench
//! guard in `benches/engine_scale.rs` asserts ≤1% overhead), while a
//! recording sink pays one `Vec` push per event. Sharded runs stream
//! events over a bounded channel ([`crate::obs::ChannelSink`]) drained
//! on the caller thread while the shards run — replica ids re-tagged at
//! the sink, buckets concatenated in replica order and stable
//! time-sorted on drain — so a recording run stays O(1) in in-flight
//! events per shard and the merged stream has the same track identities
//! (and byte-identical order) as the old whole-run buffers. Use
//! [`engine::serve_with_sink`] / [`engine::serve_routed_with_sink`] /
//! [`engine::serve_sequential_with_sink`] to observe a run, export it
//! with [`crate::obs::trace::chrome_trace`] (`continuer trace`, opens
//! in Perfetto), or fold it through
//! [`crate::obs::report::ReportModule`]s.

pub mod batcher;
pub mod engine;
pub mod estimator;
pub mod failover;
pub mod plan_cache;
pub mod policy;
pub mod profiler;
pub mod router;
pub mod scheduler;
pub mod service;

pub use engine::{
    serve, serve_routed, serve_routed_with_sink, serve_sequential, serve_sequential_with_sink,
    serve_with_sink, DeploymentConfig, EngineConfig, Execution, HealthMode, StageBackend,
    SyntheticBackend,
};
pub use plan_cache::PlanCache;
pub use estimator::{Estimator, MetricsSource, StaticMetrics};
pub use failover::{Failover, FailoverReport, Mode};
pub use policy::{Continuer, RecoveryPolicy};
pub use profiler::{fit_platform, platform_transform, DowntimeTable, LayerProfiler, PlatformLatencyModel};
pub use crate::util::eventq::QueueKind;
pub use router::{CachePadded, ReplicaLoad, RoutePolicy, Router, ShardRouter, WrrState};
pub use scheduler::{select, weight_sweep, CandidateMetrics, Decision};
pub use service::{
    Completion, DeployMode, DeployWindow, DroppedRequest, FailoverWindow, ServiceConfig,
    ServiceReport,
};
