//! Profiler phase (paper §IV-A): offline collection of everything the
//! runtime scheduler needs.
//!
//! - Layer latency sweep: compiles and times each single-layer micro
//!   artifact (Table I hyperparameter grid) on the real PJRT runtime —
//!   Platform 1. Platform 2 applies the deterministic slow-platform
//!   transform (DESIGN.md §1.2).
//! - Fits the Latency Prediction Model per platform (Table II quality).
//! - Fits the Accuracy Prediction Model from the training histories.
//! - Measures the empirical downtime of each technique (Table VIII).

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::Platform;
use crate::dnn::layers::LayerKind;
use crate::predict::{GbdtParams, KindQuality, LatencyModel, LayerSample};
use crate::runtime::{ArtifactStore, Engine, HostTensor, MicroEntry};
use crate::util::rng::Rng;

/// Profiles the layer micro-benchmarks through the PJRT runtime.
pub struct LayerProfiler<'a> {
    pub engine: &'a Engine,
    pub store: &'a ArtifactStore,
}

impl<'a> LayerProfiler<'a> {
    /// Measure every micro artifact: mean latency over `reps` runs after a
    /// warmup run (which also covers compilation).
    pub fn profile_micro(&self, reps: usize) -> Result<Vec<LayerSample>> {
        let mut rng = Rng::new(0x11AE);
        let mut out = Vec::with_capacity(self.store.micro.len());
        for entry in &self.store.micro {
            let ms = self.time_micro(entry, reps, &mut rng)?;
            out.push(LayerSample {
                spec: entry.spec.clone(),
                latency_ms: ms,
            });
        }
        Ok(out)
    }

    fn micro_inputs(&self, entry: &MicroEntry, rng: &mut Rng) -> Vec<HostTensor> {
        let s = &entry.spec;
        let shape = if s.kind == LayerKind::Dense {
            vec![1, s.input_c]
        } else {
            vec![1, s.input_h, s.input_w, s.input_c]
        };
        let n_inputs = if s.kind == LayerKind::Add { 2 } else { 1 };
        (0..n_inputs)
            .map(|_| {
                let n: usize = shape.iter().product();
                HostTensor {
                    shape: shape.clone(),
                    data: (0..n).map(|_| rng.normal() as f32).collect(),
                }
            })
            .collect()
    }

    fn time_micro(&self, entry: &MicroEntry, reps: usize, rng: &mut Rng) -> Result<f64> {
        let exe = self.engine.compile_file(&self.store.micro_path(entry))?;
        let inputs = self.micro_inputs(entry, rng);
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| self.engine.upload(t))
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let run_once = || -> Result<f64> {
            let t0 = Instant::now();
            let r = exe
                .execute_b(&refs)
                .map_err(|e| anyhow!("micro run {}: {e}", entry.artifact))?;
            // Synchronise: pull the result to host.
            let _ = r[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("micro sync {}: {e}", entry.artifact))?;
            Ok(t0.elapsed().as_secs_f64() * 1e3)
        };
        // warmup (also covers compilation effects)
        let first = run_once()?;
        // Adaptive repetition: tiny layers need many reps for a stable
        // median on a busy single-core host; cap total time per artifact.
        let target_total_ms = 25.0;
        let reps = ((target_total_ms / first.max(1e-3)) as usize)
            .clamp(reps.max(10), 400);
        let mut times = Vec::with_capacity(reps);
        for _ in 0..reps {
            times.push(run_once()?);
        }
        // Median: robust against scheduler-interrupt outliers.
        Ok(crate::util::stats::median(&times))
    }
}

/// Apply a platform model to measured samples (Platform 2 of DESIGN.md
/// §1.2): per-kind deterministic scale (slow cores hurt compute-dense
/// layers slightly more) plus bounded pseudo-random measurement noise.
pub fn platform_transform(
    samples: &[LayerSample],
    platform: &Platform,
    seed: u64,
) -> Vec<LayerSample> {
    match platform {
        Platform::Host => samples.to_vec(),
        Platform::Scaled { factor, noise } => {
            let mut rng = Rng::new(seed ^ 0x9F2C);
            samples
                .iter()
                .map(|s| {
                    // Deterministic per-kind modifier in [0.95, 1.10].
                    let k = s.spec.kind as usize;
                    let kind_mod = 0.95 + 0.015 * (k % 11) as f64;
                    let jitter = 1.0 + noise * rng.normal();
                    LayerSample {
                        spec: s.spec.clone(),
                        latency_ms: (s.latency_ms * factor * kind_mod * jitter).max(1e-6),
                    }
                })
                .collect()
        }
    }
}

/// A fitted per-platform latency model with its Table-II quality rows.
pub struct PlatformLatencyModel {
    pub platform: Platform,
    pub model: LatencyModel,
    pub quality: Vec<KindQuality>,
    pub samples: Vec<LayerSample>,
}

/// Fit the latency model for a platform from platform-1 measurements.
pub fn fit_platform(
    measured: &[LayerSample],
    platform: Platform,
    params: &GbdtParams,
    seed: u64,
) -> Result<PlatformLatencyModel> {
    let samples = platform_transform(measured, &platform, seed);
    let (model, quality) = LatencyModel::fit(&samples, params, seed)?;
    Ok(PlatformLatencyModel {
        platform,
        model,
        quality,
        samples,
    })
}

/// Empirical downtime per technique kind (paper Table VIII): measured as
/// the time to query both prediction models for every candidate plus the
/// scheduler selection, with the 0.99 ms connection-reinstate constant
/// added for repartition / skip. Keys are `Technique::kind_name()`s.
pub type DowntimeTable = BTreeMap<&'static str, f64>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::layers::LayerSpec;

    fn sample(kind: LayerKind, h: usize, ms: f64) -> LayerSample {
        LayerSample {
            spec: LayerSpec {
                kind,
                input_h: h,
                input_w: h,
                input_c: 8,
                kernel: 3,
                stride: 1,
                filters: 8,
            },
            latency_ms: ms,
        }
    }

    #[test]
    fn host_transform_is_identity() {
        let s = vec![sample(LayerKind::Conv, 8, 1.0)];
        let t = platform_transform(&s, &Platform::Host, 0);
        assert_eq!(t[0].latency_ms, 1.0);
    }

    #[test]
    fn scaled_transform_scales() {
        let s: Vec<LayerSample> = (0..50).map(|i| sample(LayerKind::Conv, 8, 1.0 + i as f64)).collect();
        let t = platform_transform(&s, &Platform::platform2(), 1);
        let ratio: f64 = t
            .iter()
            .zip(&s)
            .map(|(a, b)| a.latency_ms / b.latency_ms)
            .sum::<f64>()
            / s.len() as f64;
        assert!((1.8..2.5).contains(&ratio), "mean ratio {ratio}");
    }

    #[test]
    fn scaled_transform_deterministic() {
        let s = vec![sample(LayerKind::Relu, 16, 0.5)];
        let a = platform_transform(&s, &Platform::platform2(), 7);
        let b = platform_transform(&s, &Platform::platform2(), 7);
        assert_eq!(a[0].latency_ms, b[0].latency_ms);
    }
}
