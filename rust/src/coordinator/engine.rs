//! The event-driven serving engine.
//!
//! Replaces the old monolithic serving loop (one batch in flight, clock
//! advanced batch-by-batch) with a discrete-event simulation driven by a
//! min-queue of timestamped events: request arrivals, raw failures,
//! failure detections, batcher timeouts and per-stage start/completion.
//!
//! # Event core
//!
//! The queue behind the loop is pluggable ([`EngineConfig::event_queue`],
//! backed by [`crate::util::eventq`]): the [`QueueKind::Heap`] reference
//! is the original `BinaryHeap` (`O(log n)` per event), and
//! [`QueueKind::Calendar`] (the default) is an adaptive calendar queue —
//! power-of-two bucket array keyed by `at_ms`, bucket width retuned to
//! the observed inter-event gap on resize — giving `O(1)` amortized
//! push/pop at the million-event scale `benches/engine_scale.rs` drives.
//! Both order events by exact `(at_ms, seq)`, so pop order — and with it
//! every [`ServiceReport`] — is byte-identical whichever queue runs
//! (asserted per-operation in `tests/eventq_property.rs` and end-to-end
//! in `tests/sharded_equivalence.rs`). Each shard owns a queue of the
//! same kind.
//!
//! Two axes of concurrency the old loop structurally could not express:
//!
//! - **Stage-level pipelining** — every node in the chain is a resource
//!   with its own busy-until time, so up to [`EngineConfig::pipeline_depth`]
//!   batches are in flight per replica and steady-state throughput is set
//!   by the *bottleneck stage*, not the end-to-end path latency.
//! - **Replica sharding** — `R` independent pipeline replicas behind a
//!   [`Router`] (round-robin / join-shortest-queue). Failure plans are per
//!   replica: a node failure degrades one replica while the others keep
//!   serving at full accuracy.
//!
//! Compute stays *real* (PJRT wall-clock) through the [`StageBackend`]
//! abstraction; the [`SyntheticBackend`] swaps in fixed service times so
//! the engine's scheduling logic is testable and benchmarkable without
//! compiled artifacts.
//!
//! # Execution modes: sequential reference vs per-replica shards
//!
//! [`EngineConfig::execution`] picks how the event loop runs:
//!
//! - [`Execution::Sequential`] (the deterministic reference): one thread,
//!   one virtual-time heap over all replicas — the original engine.
//! - [`Execution::Sharded`]`(workers)`: one *shard* per replica, run on
//!   up to `workers` real threads ([`crate::util::threadpool`]). A shard
//!   is a 1-replica engine that owns its event heap, generational slab,
//!   plan cache and streaming metrics — all already per-replica state —
//!   so shards share nothing mutable and need no locks on the hot path.
//!
//! Arrivals reach shards through the router split: under the positional
//! policies (round-robin and weighted round-robin) they are routed *at
//! generation time* — request `i` → `i % R`, or along the smooth-WRR
//! schedule the sequential router walks — so every shard consumes a
//! preloaded, byte-identical schedule; under the join-shortest-queue
//! family a feeder thread routes live over per-replica atomic
//! outstanding counters ([`super::router::ShardRouter`]) and feeds each
//! shard over a channel, gated by an arrival-time watermark so a shard
//! never processes an event later than traffic it has not seen yet.
//! Failure and health events are scheduled per shard from the *global*
//! replica index and the *global* end of traffic, so monitored
//! detection streams are identical in both modes.
//!
//! # Fleet-aware routing: heterogeneous speeds and work stealing
//!
//! Real edge fleets are not uniform. Two mechanisms model (and exploit)
//! that:
//!
//! - **Heterogeneous replicas** — [`EngineConfig::speed_factors`] gives
//!   each replica a platform speed: every stage's service time is
//!   divided by the replica's factor after the backend returns it, so a
//!   0.5× replica genuinely runs its stages twice as slow (on top of
//!   any in-place degraded-node slowdown the backend already applies).
//!   The weighted policies ([`RoutePolicy::WeightedRoundRobin`],
//!   [`RoutePolicy::WeightedJoinShortestQueue`]) read the same factors,
//!   so fast replicas draw proportionally more traffic. Weighted JSQ
//!   additionally folds in the *detected condition*: the sequential
//!   router ranks replicas by expected drain time over exact state,
//!   while each shard publishes its effective speed (platform factor ÷
//!   worst observed degraded slowdown) into a per-replica `AtomicU32`
//!   the feeder reads — a Degraded replica sheds load before any
//!   failover trips.
//! - **Cross-replica work stealing** ([`EngineConfig::steal`]) — under
//!   live-routed sharding, a shard saturated past its pipeline depth
//!   offloads queued-but-undispatched requests into a per-shard
//!   injector pool; an idle shard reclaims its own offloads first
//!   (they are still its routing debt), then steals a batch from the
//!   most backlogged sibling, moving the outstanding-counter debt with
//!   the requests so the feeder's view stays truthful. The sequential
//!   engine runs the deterministic reference: a rebalance-at-arrival
//!   pass that moves queue tails from the most backlogged replica to
//!   idle ones, preserving same-seed reproducibility. Conservation —
//!   every request served or dropped exactly once, stolen or not — is
//!   asserted by the property tests in `tests/sharded_equivalence.rs`.
//!
//! After the shards run, their outcomes merge: histogram buckets add
//! (exact), Welford moments combine pairwise (exact up to float
//! accumulation order), failover windows concatenate and sort, drop and
//! completion records concatenate, counters sum. Same-seed equivalence —
//! merged sharded metrics bucket-for-bucket equal to the sequential
//! run's — holds under round-robin (or pre-routed streams, see
//! [`serve_routed`]) whenever each replica's failure events land while
//! that replica still has traffic in flight: both modes stop at the end
//! of work, but the sequential loop observes *global* end of work while
//! a shard observes its own, so only post-work events (which serve
//! nothing) can differ. JSQ sharding is live-routed and therefore not
//! bit-reproducible against the sequential JSQ router (conservation
//! still holds: every request completes or drops exactly once).
//!
//! The per-event hot path is allocation-free in steady state:
//!
//! - **Step plans are cached** — a per-replica
//!   [`PlanCache`](super::plan_cache::PlanCache) memoizes
//!   `backend.steps(technique, failed)` behind `Arc<[Step]>` (send-able,
//!   so shards own their caches), so after one miss per distinct
//!   (technique, failed-node) pair every dispatch and failover switches
//!   plans by pointer (the hit/miss counters surface in
//!   [`ServiceReport`]).
//! - **Synthetic activations are shape-only** — a non-materializing
//!   backend receives [`Activation::Shape`] handles (two integers), so
//!   batch building and per-stage "copies" move no row data; the real
//!   PJRT path still materializes tensors, gathered + padded in a single
//!   allocation.
//! - **In-flight batches live in a generational slab**
//!   ([`crate::util::slab::Slab`]) — free-list slot reuse, O(1) access,
//!   no hashing on stage start/done events, and stale events for retired
//!   batches miss by generation.
//! - **Metrics stream** — latency flows into a log-bucketed histogram +
//!   online moments ([`crate::util::histogram::Streaming`]), so run
//!   memory is O(1) in request count unless
//!   [`EngineConfig::record_completions`] asks for exact per-request
//!   records.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering as AtomicOrdering};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::Result;

use crate::cluster::failure::{Detector, FailurePlan, NodeCondition};
use crate::cluster::sim::{steps_for, steps_for_chain, EdgeCluster, Step};
use crate::dnn::variants::Technique;
use crate::health::monitor::{simulate as simulate_monitor, HealthConfig, HealthEventKind};
use crate::obs::{ChannelSink, EngineEvent, EngineEventKind, EventSink, NoopSink, EVENT_CHANNEL_CAP};
use crate::runtime::{Activation, HostTensor, ShapeOnly, UnitKind};
use crate::util::eventq::{AnyQueue, EventQueue, QueueKind};
use crate::util::histogram::Streaming;
use crate::util::slab::{Slab, SlabKey};
use crate::util::threadpool::parallel_map_with;
use crate::workload::{split_round_robin, split_with, Request};

use super::batcher::{decide, BatcherConfig, Dispatch};
use super::estimator::MetricsSource;
use super::failover::Failover;
use super::plan_cache::PlanCache;
use super::router::{
    CachePadded, ReplicaLoad, RoutePolicy, Router, ShardRouter, WrrState, SPEED_MILLI,
};
use super::service::{
    Completion, DeployMode, DeployWindow, DroppedRequest, FailoverWindow, ServiceReport,
};

/// Per-stage compute backend: the engine schedules *when* stages run;
/// the backend says *how long* they take (and produces the activation).
pub trait StageBackend {
    /// Number of chain nodes (1-based ids `1..=num_nodes`).
    fn num_nodes(&self) -> usize;
    /// Step sequence of a technique under an optional failure. Called
    /// once per distinct (technique, failure) pair — the engine caches
    /// plans behind `Arc<[Step]>` and dispatches by pointer.
    fn steps(&self, tech: Technique, failed: Option<usize>) -> Vec<Step>;
    /// Execute one step's unit on a batch; returns output + compute ms.
    fn run_stage(&mut self, step: Step, x: &Activation) -> Result<(Activation, f64)>;
    /// Modeled transfer time between hosts for an activation of `bytes`.
    fn transfer_ms(&mut self, from: usize, to: usize, bytes: usize) -> f64;
    /// Whether this backend consumes materialized tensor data. When
    /// `true` (the real cluster) the engine gathers request rows into a
    /// real padded batch; when `false` (synthetic) it dispatches
    /// shape-only handles and no row data is ever copied.
    fn materializes(&self) -> bool {
        true
    }
    /// Ground-truth condition of a node (degraded stages run slower).
    fn condition(&self, node: usize) -> NodeCondition;
    fn set_condition(&mut self, node: usize, condition: NodeCondition);
    fn is_up(&self, node: usize) -> bool {
        self.condition(node).is_up()
    }
    /// Size of a unit's weights in bytes — what a repartition deployment
    /// must move onto a host that didn't already serve the unit. Zero
    /// (the default) makes re-hosting that unit free.
    fn unit_weight_bytes(&self, _unit: UnitKind) -> usize {
        0
    }
    /// Modeled time to push `bytes` of weights onto `node` during a
    /// deployment. Must be deterministic (no RNG): the engine schedules
    /// the cut-over instant from it up front, and jitter here would
    /// desynchronise same-seed sequential and sharded runs.
    fn deploy_transfer_ms(&self, _node: usize, _bytes: usize) -> f64 {
        0.0
    }
}

impl StageBackend for EdgeCluster<'_> {
    fn num_nodes(&self) -> usize {
        self.meta.num_nodes
    }

    fn steps(&self, tech: Technique, failed: Option<usize>) -> Vec<Step> {
        steps_for(self.meta, tech, failed)
    }

    fn run_stage(&mut self, step: Step, x: &Activation) -> Result<(Activation, f64)> {
        let (y, ms) = EdgeCluster::execute_stage(self, step, x.tensor()?)?;
        Ok((Activation::Full(y), ms))
    }

    fn transfer_ms(&mut self, from: usize, to: usize, bytes: usize) -> f64 {
        EdgeCluster::stage_transfer_ms(self, from, to, bytes)
    }

    fn condition(&self, node: usize) -> NodeCondition {
        EdgeCluster::condition(self, node)
    }

    fn set_condition(&mut self, node: usize, condition: NodeCondition) {
        EdgeCluster::set_condition(self, node, condition);
    }

    fn unit_weight_bytes(&self, unit: UnitKind) -> usize {
        EdgeCluster::unit_weight_bytes(self, unit)
    }

    fn deploy_transfer_ms(&self, _node: usize, bytes: usize) -> f64 {
        EdgeCluster::deploy_transfer_ms(self, bytes)
    }
}

/// Deterministic stand-in for the PJRT cluster: fixed per-stage service
/// times, identity compute, jitter-free links. Lets the engine (and its
/// tests and benches) run without compiled artifacts, and makes same-seed
/// runs byte-identical.
#[derive(Debug, Clone)]
pub struct SyntheticBackend {
    /// Per-node compute time, ms; index 0 unused (1-based node ids).
    pub node_ms: Vec<f64>,
    /// Exit-head compute time, ms.
    pub exit_ms: f64,
    /// Per-hop transfer time, ms (a skip reroute pays two).
    pub hop_ms: f64,
    /// Per-node weight size in bytes (index 0 unused). All-zero by
    /// default, which keeps deployments instantaneous unless a test or
    /// experiment opts in via [`SyntheticBackend::with_deployment`].
    pub weight_bytes: Vec<usize>,
    /// Deterministic deployment link rate, bytes per millisecond. Zero
    /// (the default) means weight transfers take no modeled time.
    pub deploy_bytes_per_ms: f64,
    conditions: Vec<NodeCondition>,
}

impl SyntheticBackend {
    pub fn new(node_ms: Vec<f64>, exit_ms: f64, hop_ms: f64) -> SyntheticBackend {
        assert!(node_ms.len() >= 2, "need >= 1 node (index 0 unused)");
        let n = node_ms.len();
        SyntheticBackend {
            node_ms,
            exit_ms,
            hop_ms,
            weight_bytes: vec![0; n],
            deploy_bytes_per_ms: 0.0,
            conditions: vec![NodeCondition::Up; n],
        }
    }

    /// `num_nodes` identical stages of `node_ms` ms each.
    pub fn uniform(num_nodes: usize, node_ms: f64, hop_ms: f64) -> SyntheticBackend {
        SyntheticBackend::new(vec![node_ms; num_nodes + 1], node_ms / 2.0, hop_ms)
    }

    /// Give the chain weight sizes and a deployment link rate, so
    /// repartition deployments cost modeled transfer time.
    pub fn with_deployment(mut self, weight_bytes: Vec<usize>, bytes_per_ms: f64) -> SyntheticBackend {
        assert_eq!(
            weight_bytes.len(),
            self.node_ms.len(),
            "weight_bytes must be per-node (index 0 unused), same length as node_ms"
        );
        self.weight_bytes = weight_bytes;
        self.deploy_bytes_per_ms = bytes_per_ms;
        self
    }
}

impl StageBackend for SyntheticBackend {
    fn num_nodes(&self) -> usize {
        self.conditions.len() - 1
    }

    fn steps(&self, tech: Technique, failed: Option<usize>) -> Vec<Step> {
        steps_for_chain(self.num_nodes(), tech, failed)
    }

    fn run_stage(&mut self, step: Step, x: &Activation) -> Result<(Activation, f64)> {
        if !StageBackend::is_up(self, step.host) {
            anyhow::bail!("step {:?} hosted on failed node {}", step.unit, step.host);
        }
        let ms = match step.unit {
            UnitKind::Node(n) => self.node_ms[n],
            UnitKind::Exit(_) => self.exit_ms,
        };
        // A degraded host stretches its stage's service time in place.
        // Identity compute: the output keeps the input's geometry, and
        // cloning the shape-only handle the engine feeds this backend
        // copies two integers — no row data moves.
        Ok((x.clone(), ms * self.conditions[step.host].slowdown()))
    }

    fn transfer_ms(&mut self, from: usize, to: usize, _bytes: usize) -> f64 {
        if from == to {
            0.0
        } else if to > from + 1 {
            self.hop_ms * 2.0
        } else {
            self.hop_ms
        }
    }

    fn materializes(&self) -> bool {
        false
    }

    fn condition(&self, node: usize) -> NodeCondition {
        self.conditions[node]
    }

    fn set_condition(&mut self, node: usize, condition: NodeCondition) {
        self.conditions[node] = condition;
    }

    fn unit_weight_bytes(&self, unit: UnitKind) -> usize {
        match unit {
            UnitKind::Node(n) => self.weight_bytes.get(n).copied().unwrap_or(0),
            // Exit heads ride along with their host's block in this
            // synthetic model: re-hosting one is free.
            UnitKind::Exit(_) => 0,
        }
    }

    fn deploy_transfer_ms(&self, _node: usize, bytes: usize) -> f64 {
        if self.deploy_bytes_per_ms <= 0.0 {
            0.0
        } else {
            bytes as f64 / self.deploy_bytes_per_ms
        }
    }
}

/// How the engine learns about node failures.
#[derive(Debug, Clone)]
pub enum HealthMode {
    /// Oracle detection (the seed's model): every crash is detected at
    /// exactly the next heartbeat quantum plus a timeout, recoveries are
    /// seen instantly, degradations slow stages in place but never
    /// trigger a failover, and nothing is ever detected that didn't
    /// happen.
    Oracle(Detector),
    /// Detection through the [`crate::health`] monitor: heartbeats with
    /// jitter/loss/blackouts feed a fixed-timeout or phi-accrual
    /// detector, so detections are late, gray failures are failed over
    /// only past the slowdown threshold, false positives happen (and
    /// roll back), and recovered nodes wait out a quarantine before the
    /// path repartitions back onto them.
    Monitored(HealthConfig),
}

/// How the event loop executes (see the module docs for the full
/// threading story).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Execution {
    /// One thread, one global virtual-time heap over all replicas — the
    /// deterministic reference implementation.
    Sequential,
    /// One shard per replica, multiplexed onto up to this many worker
    /// threads. Round-robin routing (and pre-routed streams) stays
    /// deterministic and merge-equivalent to the sequential run;
    /// join-shortest-queue routes live over atomic counters and is only
    /// conservation-equivalent.
    Sharded(usize),
}

/// How repartition deployments are modeled (see
/// [`DeployMode`](super::service::DeployMode) for the three modes).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeploymentConfig {
    pub mode: DeployMode,
    /// Warm-up delay each newly assigned host pays after its weights
    /// land (allocator/compile/cache warm-up) before its units count as
    /// live.
    pub warmup_ms: f64,
}

impl Default for DeploymentConfig {
    /// The pre-deployment-model engine: repartition is a free swap.
    fn default() -> DeploymentConfig {
        DeploymentConfig {
            mode: DeployMode::Instantaneous,
            warmup_ms: 0.0,
        }
    }
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub batcher: BatcherConfig,
    pub health: HealthMode,
    /// Drop requests that queue longer than this (None = never drop).
    pub deadline_ms: Option<f64>,
    /// Max batches concurrently in flight per replica. 1 reproduces the
    /// seed's one-batch-at-a-time loop; > 1 enables stage pipelining.
    pub pipeline_depth: usize,
    pub route: RoutePolicy,
    /// When set, every failover window reports this fixed downtime
    /// instead of the measured predict+select wall time plus reinstate,
    /// keeping same-seed reports byte-identical (used by the determinism
    /// tests and benches).
    pub decision_ms_override: Option<f64>,
    /// Keep one exact [`Completion`] record per served request in
    /// [`ServiceReport::completed`]. Latency metrics always stream into
    /// an O(1) histogram/moments accumulator; with this off (the
    /// million-request serving regime) no per-request state accumulates
    /// at all. Tests and the accuracy experiments turn it on to inspect
    /// individual completions.
    pub record_completions: bool,
    /// Sequential reference loop or per-replica shards on real threads.
    pub execution: Execution,
    /// Repartition deployment model: instantaneous swap (the legacy
    /// behaviour), break-before-make (serving stalls through the
    /// transfer + warm-up window) or make-before-break (a fallback
    /// technique keeps the replica serving until the cut-over).
    pub deployment: DeploymentConfig,
    /// Per-replica platform speed factors: replica `r` runs every stage
    /// at `speed_factors[r]`× the backend's service time (0.5 = half
    /// speed). Missing entries (including the empty default) mean 1.0.
    /// The weighted route policies read the same factors as routing
    /// weights, so a heterogeneous fleet is described once.
    pub speed_factors: Vec<f64>,
    /// Enable cross-replica work stealing: queued-but-undispatched
    /// requests on a backlogged replica become stealable by idle ones.
    /// Deterministic rebalance-at-arrival under [`Execution::Sequential`];
    /// per-shard injector pools under live-routed sharding. Positional
    /// sharded schedules (round-robin / weighted-round-robin / pre-routed
    /// streams) never steal — their per-shard schedules stay exact.
    pub steal: bool,
    /// Which [`EventQueue`](crate::util::eventq::EventQueue)
    /// implementation drives the loop (and each shard): the `BinaryHeap`
    /// reference or the `O(1)` adaptive calendar queue (the default).
    /// Pop order is byte-identical either way — this knob trades only
    /// constant factors, never results.
    pub event_queue: QueueKind,
}

impl EngineConfig {
    /// Seed-equivalent configuration: one replica's worth of serving with
    /// no pipelining and measured decision times.
    pub fn sequential(batcher: BatcherConfig, detector: Detector, deadline_ms: Option<f64>) -> EngineConfig {
        EngineConfig {
            batcher,
            health: HealthMode::Oracle(detector),
            deadline_ms,
            pipeline_depth: 1,
            route: RoutePolicy::RoundRobin,
            decision_ms_override: None,
            record_completions: true,
            execution: Execution::Sequential,
            deployment: DeploymentConfig::default(),
            speed_factors: Vec::new(),
            steal: false,
            event_queue: QueueKind::default(),
        }
    }

    /// The same configuration with the event loop sharded per replica
    /// onto up to `workers` threads.
    pub fn sharded(mut self, workers: usize) -> EngineConfig {
        self.execution = Execution::Sharded(workers);
        self
    }

    /// The same configuration over a heterogeneous fleet: replica `r`
    /// runs at `factors[r]`× platform speed (missing entries mean 1.0),
    /// and the weighted route policies use the factors as weights.
    pub fn with_speed_factors(mut self, factors: Vec<f64>) -> EngineConfig {
        self.speed_factors = factors;
        self
    }

    /// The same configuration with cross-replica work stealing on or off.
    pub fn stealing(mut self, on: bool) -> EngineConfig {
        self.steal = on;
        self
    }
}

// ---------------------------------------------------------------------------
// Event queue
// ---------------------------------------------------------------------------

#[derive(Debug)]
enum EventKind {
    /// A request arrives. `replica` pins it (pre-routed streams and
    /// shards, whose only local replica is 0); `None` asks the router.
    Arrival { req: Request, replica: Option<usize> },
    /// Ground truth: the node's condition flips (the backend feels it
    /// immediately; the controller only reacts to Detect* events).
    RawCondition { replica: usize, node: usize, condition: NodeCondition },
    /// The monitor (or oracle) concluded the node must be failed over.
    DetectFailover { replica: usize, node: usize, false_positive: bool },
    /// The monitor (or oracle) cleared the node for reintegration.
    DetectRecovery { replica: usize, node: usize },
    BatcherTimeout { replica: usize },
    StageStart { replica: usize, batch: SlabKey },
    StageDone { replica: usize, batch: SlabKey },
    /// Deployment lifecycle (transfer/warm-up/cut-over). Boxed: these
    /// fire a handful of times per *failover* while the variants above
    /// fire per request/stage, so their payload must not set the size
    /// every queued event pays — the budget test below pins it.
    Deploy(Box<DeployEvent>),
}

/// Payload of the rare deployment events, boxed out of [`EventKind`].
#[derive(Debug)]
struct DeployEvent {
    replica: usize,
    /// Stale ids (superseded or cancelled deployments) are ignored.
    deploy_id: u64,
    phase: DeployPhase,
}

#[derive(Debug)]
enum DeployPhase {
    /// One host finished receiving its re-hosted weights.
    TransferDone { node: usize },
    /// One host finished warming the units it received.
    WarmupDone { node: usize },
    /// Every transfer + warm-up finished: switch dispatch to the new
    /// partition atomically.
    Cutover,
}

// ---------------------------------------------------------------------------
// Engine state
// ---------------------------------------------------------------------------

struct ReplicaState {
    queue: VecDeque<Request>,
    /// Per-host busy-until time, ms (index 0 unused; 1-based node ids).
    busy_until: Vec<f64>,
    in_flight_batches: usize,
    in_flight_reqs: usize,
    /// Deduplicates pending batcher-timeout events.
    timeout_at: Option<f64>,
}

impl ReplicaState {
    fn new(num_nodes: usize) -> ReplicaState {
        ReplicaState {
            queue: VecDeque::new(),
            busy_until: vec![0.0; num_nodes + 1],
            in_flight_batches: 0,
            in_flight_reqs: 0,
            timeout_at: None,
        }
    }

    /// Put a failed batch's requests back, merging by arrival time so the
    /// queue keeps its arrival-order invariant (prune_expired and the
    /// batcher's head-age both rely on it) even when several in-flight
    /// batches requeue in stage order rather than dispatch order.
    fn requeue_sorted(&mut self, reqs: Vec<Request>) {
        let old: Vec<Request> = self.queue.drain(..).collect();
        let mut merged = VecDeque::with_capacity(old.len() + reqs.len());
        let mut a = reqs.into_iter().peekable();
        let mut b = old.into_iter().peekable();
        loop {
            match (a.peek(), b.peek()) {
                (Some(x), Some(y)) => {
                    if x.arrival_ms <= y.arrival_ms {
                        merged.push_back(a.next().unwrap());
                    } else {
                        merged.push_back(b.next().unwrap());
                    }
                }
                (Some(_), None) => merged.push_back(a.next().unwrap()),
                (None, Some(_)) => merged.push_back(b.next().unwrap()),
                (None, None) => break,
            }
        }
        self.queue = merged;
    }
}

struct BatchInFlight {
    requests: Vec<Request>,
    /// Current activation (input at stage 0, transformed stage by stage).
    /// Shape-only on the synthetic path — see [`StageBackend::materializes`].
    x: Activation,
    /// Cached step plan, shared by pointer with the replica's
    /// [`PlanCache`] — dispatching a batch allocates no plan.
    steps: Arc<[Step]>,
    /// Index of the next stage to start (or the one currently running,
    /// between its StageStart and StageDone events).
    stage: usize,
    technique: Option<Technique>,
    target_batch: usize,
    /// Per-replica dispatch ordinal, carried so stage start/done events
    /// in the observability stream name a stable batch identity.
    trace_seq: usize,
}

struct Engine<'a, B: StageBackend, S: EventSink> {
    backends: &'a mut [B],
    failovers: &'a mut [Failover],
    est: &'a dyn MetricsSource,
    cfg: &'a EngineConfig,
    inputs: &'a HostTensor,
    router: Router,
    /// The event core: heap or calendar per [`EngineConfig::event_queue`]
    /// — pop order is `(at_ms, seq)`-exact either way.
    events: AnyQueue<EventKind>,
    seq: u64,
    states: Vec<ReplicaState>,
    /// In-flight batches in a generational slab: slot reuse, O(1) access,
    /// and stale stage events for retired batches miss by generation.
    batches: Slab<BatchInFlight>,
    /// One step-plan memo per replica.
    plan_caches: Vec<PlanCache>,
    /// Scratch row-index buffer reused across materializing dispatches.
    pad_idxs: Vec<usize>,
    /// Streaming latency metrics (histogram + online moments): O(1)
    /// memory however many requests complete.
    latency: Streaming,
    completed: Vec<Completion>,
    completed_count: usize,
    dropped: Vec<DroppedRequest>,
    windows: Vec<FailoverWindow>,
    max_in_flight: usize,
    batches_dispatched: usize,
    events_processed: usize,
    clock_ms: f64,
    /// Arrival events in the heap not yet processed; when this hits zero,
    /// the intake (if any) is closed and no work remains, the run ends
    /// (later failure events never fire — the seed's "fail_at = never"
    /// idiom).
    pending_arrivals: usize,
    /// Live arrival feed for a channel-fed shard (JSQ sharding); `None`
    /// when all arrivals are preloaded into the heap.
    intake: Option<Intake>,
    /// Outstanding-request counter shared with the sharded router's
    /// feeder: decremented once per completion or drop so live routing
    /// sees this shard's backlog.
    outstanding: Option<Arc<CachePadded<AtomicUsize>>>,
    /// Per-replica platform speed factors (1.0 = nominal): every stage's
    /// service time is divided by its replica's factor. A shard's single
    /// entry carries its *global* replica's factor.
    speeds: Vec<f64>,
    /// Where a weighted-JSQ shard publishes its effective speed
    /// (platform factor ÷ worst observed degraded slowdown) on every
    /// raw condition change, for the feeder's drain-time ranking.
    speed_cell: Option<Arc<CachePadded<AtomicU32>>>,
    /// Cross-replica work-stealing handle (live-routed shards with
    /// [`EngineConfig::steal`] on). `None` everywhere else — the
    /// sequential engine rebalances its own queues directly.
    steal: Option<StealCtx>,
    /// Observability stream. Monomorphized: with [`NoopSink`] every
    /// emission compiles to nothing, keeping the hot path zero-cost.
    sink: &'a mut S,
    /// In-flight repartition deployment per replica (at most one: a new
    /// failure supersedes the old deployment).
    deploys: Vec<Option<DeployState>>,
    /// Monotone deployment id: stale Transfer/Warmup/Cutover heap events
    /// for cancelled or superseded deployments miss by id.
    deploy_seq: u64,
    deploy_windows: Vec<DeployWindow>,
}

/// One in-flight repartition deployment on a replica: weights are in
/// transit / warming toward the repartitioned plan, dispatch runs on
/// `fallback` (make-before-break) or stalls (`None`, break-before-make)
/// until the cut-over event fires.
#[derive(Debug, Clone, Copy)]
struct DeployState {
    id: u64,
    /// The failed node the deployment routes around.
    node: usize,
    start_ms: f64,
    fallback: Option<Technique>,
    /// Index of this deployment's window in `deploy_windows`, patched
    /// on cut-over or cancellation.
    window_idx: usize,
}

/// A shard's live arrival feed, with the watermark that makes it safe:
/// the feeder sends requests in nondecreasing arrival time, so any heap
/// event at or before the last received arrival time can be processed —
/// no later-sent request can precede it. When the channel closes the
/// watermark is effectively infinite and the shard drains its heap.
struct Intake {
    rx: mpsc::Receiver<Request>,
    open: bool,
    watermark_ms: f64,
}

fn validate<B: StageBackend>(
    backends: &[B],
    failovers: &[Failover],
    cfg: &EngineConfig,
    plans: &[FailurePlan],
) -> Result<()> {
    anyhow::ensure!(!backends.is_empty(), "engine needs >= 1 replica");
    anyhow::ensure!(
        backends.len() == failovers.len(),
        "one failover controller per replica ({} vs {})",
        backends.len(),
        failovers.len()
    );
    anyhow::ensure!(
        plans.len() <= backends.len(),
        "more failure plans ({}) than replicas ({})",
        plans.len(),
        backends.len()
    );
    anyhow::ensure!(cfg.pipeline_depth >= 1, "pipeline_depth must be >= 1");
    anyhow::ensure!(
        cfg.speed_factors.iter().all(|s| s.is_finite() && *s > 0.0),
        "speed factors must be positive and finite: {:?}",
        cfg.speed_factors
    );
    Ok(())
}

/// Run the serving simulation: `backends[r]`, `failovers[r]` and
/// `plans.get(r)` describe replica `r` (plans may be shorter than the
/// replica count; missing plans mean no failures). `requests` must be
/// sorted by arrival time.
///
/// Dispatches on [`EngineConfig::execution`]: the sequential reference
/// loop, or per-replica shards on real threads (which is why this entry
/// point needs `B: Send` and a `Sync` metrics source — callers whose
/// backend cannot cross threads, like the PJRT [`EdgeCluster`], use
/// [`serve_sequential`] directly).
pub fn serve<B: StageBackend + Send>(
    backends: &mut [B],
    est: &(dyn MetricsSource + Sync),
    failovers: &mut [Failover],
    cfg: &EngineConfig,
    requests: &[Request],
    inputs: &HostTensor,
    plans: &[FailurePlan],
) -> Result<ServiceReport> {
    serve_with_sink(
        backends,
        est,
        failovers,
        cfg,
        requests,
        inputs,
        plans,
        &mut NoopSink,
    )
}

/// [`serve`] with an observability stream: every engine transition is
/// emitted into `sink` (see [`crate::obs`] for the event taxonomy). The
/// sequential loop streams events live; each shard streams through a
/// bounded [`ChannelSink`] that the calling thread drains while the
/// shards run (re-tagged replica ids, stable time sort — byte-identical
/// to the old whole-run per-shard buffers, without the whole-run
/// memory), then replays the merged stream into `sink` — unless
/// [`EventSink::wants_events`] is false, in which case the shards run
/// with [`NoopSink`] and stay allocation-free.
#[allow(clippy::too_many_arguments)]
pub fn serve_with_sink<B: StageBackend + Send, S: EventSink>(
    backends: &mut [B],
    est: &(dyn MetricsSource + Sync),
    failovers: &mut [Failover],
    cfg: &EngineConfig,
    requests: &[Request],
    inputs: &HostTensor,
    plans: &[FailurePlan],
    sink: &mut S,
) -> Result<ServiceReport> {
    validate(backends, failovers, cfg, plans)?;
    let last_arrival = requests.last().map(|r| r.arrival_ms).unwrap_or(0.0);
    match cfg.execution {
        Execution::Sequential => run_sequential(
            backends,
            est,
            failovers,
            cfg,
            SeqArrivals::Merged(requests),
            inputs,
            plans,
            last_arrival,
            sink,
        ),
        Execution::Sharded(workers) => {
            let n = backends.len();
            let (outcome, events) = if cfg.route.is_positional() {
                // Positional policies route at "generation time":
                // round-robin splits request `i` → `i % R` and weighted
                // round-robin walks the same smooth-WRR schedule the
                // sequential router does, so every shard gets a
                // preloaded, deterministic schedule and no channels are
                // needed for arrivals.
                let streams = match cfg.route {
                    RoutePolicy::RoundRobin => split_round_robin(requests, n),
                    _ => split_weighted(requests, n, &cfg.speed_factors),
                };
                if sink.wants_events() {
                    let (sinks, rx) = event_channel(n);
                    serve_sharded_preloaded(
                        workers, backends, est, failovers, cfg, streams, inputs, plans,
                        last_arrival, sinks, move || drain_events(rx, n),
                    )?
                } else {
                    serve_sharded_preloaded(
                        workers, backends, est, failovers, cfg, streams, inputs, plans,
                        last_arrival, vec![NoopSink; n], Vec::new,
                    )?
                }
            } else if sink.wants_events() {
                // The JSQ family needs live load: a feeder on the
                // calling thread routes over the shards' atomic
                // outstanding counters (and published speeds).
                let (sinks, rx) = event_channel(n);
                serve_sharded_jsq(
                    workers, backends, est, failovers, cfg, requests, inputs, plans,
                    last_arrival, sinks, move || drain_events(rx, n),
                )?
            } else {
                serve_sharded_jsq(
                    workers, backends, est, failovers, cfg, requests, inputs, plans,
                    last_arrival, vec![NoopSink; n], Vec::new,
                )?
            };
            for ev in &events {
                sink.on_event(ev);
            }
            Ok(finalize(outcome))
        }
    }
}

/// The single-threaded reference engine, usable with non-`Send` backends
/// (the PJRT cluster holds host-side caches behind `RefCell`). Always
/// runs sequentially regardless of [`EngineConfig::execution`].
pub fn serve_sequential<B: StageBackend>(
    backends: &mut [B],
    est: &dyn MetricsSource,
    failovers: &mut [Failover],
    cfg: &EngineConfig,
    requests: &[Request],
    inputs: &HostTensor,
    plans: &[FailurePlan],
) -> Result<ServiceReport> {
    serve_sequential_with_sink(
        backends,
        est,
        failovers,
        cfg,
        requests,
        inputs,
        plans,
        &mut NoopSink,
    )
}

/// [`serve_sequential`] with a live observability stream (the non-`Send`
/// backend counterpart of [`serve_with_sink`]).
#[allow(clippy::too_many_arguments)]
pub fn serve_sequential_with_sink<B: StageBackend, S: EventSink>(
    backends: &mut [B],
    est: &dyn MetricsSource,
    failovers: &mut [Failover],
    cfg: &EngineConfig,
    requests: &[Request],
    inputs: &HostTensor,
    plans: &[FailurePlan],
    sink: &mut S,
) -> Result<ServiceReport> {
    validate(backends, failovers, cfg, plans)?;
    let last_arrival = requests.last().map(|r| r.arrival_ms).unwrap_or(0.0);
    run_sequential(
        backends,
        est,
        failovers,
        cfg,
        SeqArrivals::Merged(requests),
        inputs,
        plans,
        last_arrival,
        sink,
    )
}

/// Serve pre-routed per-replica arrival streams: `streams[r]` (sorted by
/// arrival time) is pinned to replica `r` in both execution modes,
/// bypassing the router. This is the workload-level counterpart of
/// round-robin routing (see [`crate::workload::generate_per_replica`]):
/// a sequential run and a sharded run consume byte-identical per-replica
/// schedules, which the equivalence tests exploit.
pub fn serve_routed<B: StageBackend + Send>(
    backends: &mut [B],
    est: &(dyn MetricsSource + Sync),
    failovers: &mut [Failover],
    cfg: &EngineConfig,
    streams: &[Vec<Request>],
    inputs: &HostTensor,
    plans: &[FailurePlan],
) -> Result<ServiceReport> {
    serve_routed_with_sink(
        backends,
        est,
        failovers,
        cfg,
        streams,
        inputs,
        plans,
        &mut NoopSink,
    )
}

/// [`serve_routed`] with an observability stream; buffering/merge
/// semantics match [`serve_with_sink`].
#[allow(clippy::too_many_arguments)]
pub fn serve_routed_with_sink<B: StageBackend + Send, S: EventSink>(
    backends: &mut [B],
    est: &(dyn MetricsSource + Sync),
    failovers: &mut [Failover],
    cfg: &EngineConfig,
    streams: &[Vec<Request>],
    inputs: &HostTensor,
    plans: &[FailurePlan],
    sink: &mut S,
) -> Result<ServiceReport> {
    validate(backends, failovers, cfg, plans)?;
    anyhow::ensure!(
        streams.len() == backends.len(),
        "one arrival stream per replica ({} vs {})",
        streams.len(),
        backends.len()
    );
    let last_arrival = streams
        .iter()
        .filter_map(|s| s.last())
        .map(|r| r.arrival_ms)
        .fold(0.0, f64::max);
    match cfg.execution {
        Execution::Sequential => run_sequential(
            backends,
            est,
            failovers,
            cfg,
            SeqArrivals::PerReplica(streams),
            inputs,
            plans,
            last_arrival,
            sink,
        ),
        Execution::Sharded(workers) => {
            let n = backends.len();
            let (outcome, events) = if sink.wants_events() {
                let (sinks, rx) = event_channel(n);
                serve_sharded_preloaded(
                    workers,
                    backends,
                    est,
                    failovers,
                    cfg,
                    streams.to_vec(),
                    inputs,
                    plans,
                    last_arrival,
                    sinks,
                    move || drain_events(rx, n),
                )?
            } else {
                serve_sharded_preloaded(
                    workers,
                    backends,
                    est,
                    failovers,
                    cfg,
                    streams.to_vec(),
                    inputs,
                    plans,
                    last_arrival,
                    vec![NoopSink; n],
                    Vec::new,
                )?
            };
            for ev in &events {
                sink.on_event(ev);
            }
            Ok(finalize(outcome))
        }
    }
}

/// Arrival input to the sequential loop: one merged stream the router
/// spreads, or per-replica streams already pinned.
enum SeqArrivals<'r> {
    Merged(&'r [Request]),
    PerReplica(&'r [Vec<Request>]),
}

#[allow(clippy::too_many_arguments)]
fn run_sequential<B: StageBackend, S: EventSink>(
    backends: &mut [B],
    est: &dyn MetricsSource,
    failovers: &mut [Failover],
    cfg: &EngineConfig,
    arrivals: SeqArrivals<'_>,
    inputs: &HostTensor,
    plans: &[FailurePlan],
    last_arrival_ms: f64,
    sink: &mut S,
) -> Result<ShardResultReport> {
    let mut eng = Engine::new(backends, failovers, est, cfg, inputs, sink);
    match arrivals {
        SeqArrivals::Merged(reqs) => {
            eng.pending_arrivals = reqs.len();
            for req in reqs {
                eng.push(req.arrival_ms, EventKind::Arrival { req: *req, replica: None });
            }
        }
        SeqArrivals::PerReplica(streams) => {
            eng.pending_arrivals = streams.iter().map(Vec::len).sum();
            for (r, stream) in streams.iter().enumerate() {
                for req in stream {
                    eng.push(
                        req.arrival_ms,
                        EventKind::Arrival { req: *req, replica: Some(r) },
                    );
                }
            }
        }
    }
    let empty_plan = FailurePlan::none();
    let n_replicas = eng.backends.len();
    for r in 0..n_replicas {
        let plan = plans.get(r).unwrap_or(&empty_plan);
        eng.schedule_failure_events(r, r, plan, last_arrival_ms);
    }
    Ok(finalize(eng.run()?))
}

/// One replica's work order for a sharded run.
struct ShardTask<'a, B, S> {
    /// The replica's index in the caller's arrays — the shard's local
    /// index is always 0, but monitor seeding and report re-tagging need
    /// the global identity.
    global_replica: usize,
    backend: &'a mut B,
    failover: &'a mut Failover,
    plan: &'a FailurePlan,
    arrivals: ShardArrivals,
    outstanding: Option<Arc<CachePadded<AtomicUsize>>>,
    /// The replica's platform speed factor (1.0 = nominal).
    speed: f64,
    /// Where the shard publishes its effective speed (platform factor ÷
    /// worst observed degraded slowdown) for the weighted-JSQ feeder.
    speed_cell: Option<Arc<CachePadded<AtomicU32>>>,
    /// Work-stealing handle (live-routed sharding with stealing on).
    steal: Option<StealCtx>,
    /// The shard's observability sink, owned: a [`ChannelSink`] when the
    /// caller records events, [`NoopSink`] otherwise.
    sink: S,
}

enum ShardArrivals {
    /// The shard's full schedule, known up front (positional routing /
    /// pre-routed streams).
    Preloaded(Vec<Request>),
    /// Live feed from the JSQ feeder, gated by the arrival watermark.
    Channel(mpsc::Receiver<Request>),
}

/// One shard's injector: queued-but-undispatched requests it offered up
/// for stealing. `len` mirrors the deque size so siblings can pick a
/// victim by scanning sizes without taking every lock.
struct StealPool {
    len: AtomicUsize,
    items: Mutex<VecDeque<Request>>,
}

impl StealPool {
    fn new() -> StealPool {
        StealPool {
            len: AtomicUsize::new(0),
            items: Mutex::new(VecDeque::new()),
        }
    }

    fn push(&self, reqs: VecDeque<Request>) {
        let mut items = self.items.lock().unwrap();
        // Relaxed: `len` is only a victim-selection hint; the deque
        // itself is mutated under the mutex, whose unlock/lock already
        // orders the data for whoever takes the items.
        self.len.fetch_add(reqs.len(), AtomicOrdering::Relaxed);
        items.extend(reqs);
    }

    fn take_all(&self) -> Vec<Request> {
        let mut items = self.items.lock().unwrap();
        // Relaxed: hint only, updated under the same mutex as the deque
        // (see push) — a racing reader can pick a stale victim, never a
        // wrong request.
        self.len.store(0, AtomicOrdering::Relaxed);
        items.drain(..).collect()
    }

    fn take_up_to(&self, n: usize) -> Vec<Request> {
        let mut items = self.items.lock().unwrap();
        let take = n.min(items.len());
        // Relaxed: hint only, updated under the deque mutex (see push).
        self.len.fetch_sub(take, AtomicOrdering::Relaxed);
        items.drain(..take).collect()
    }
}

/// A shard's view of the fleet's stealing state: its own pool index,
/// every shard's pool, and every shard's outstanding counter (a steal
/// moves the routing debt from victim to thief so the feeder's load
/// view stays truthful).
struct StealCtx {
    me: usize,
    pools: Arc<Vec<StealPool>>,
    outstanding: Vec<Arc<CachePadded<AtomicUsize>>>,
}

/// Build the per-shard [`ChannelSink`]s plus the receiver the caller
/// thread drains; dropping the last sink closes the channel.
fn event_channel(replicas: usize) -> (Vec<ChannelSink>, mpsc::Receiver<EngineEvent>) {
    let (tx, rx) = mpsc::sync_channel(EVENT_CHANNEL_CAP);
    let sinks = (0..replicas).map(|r| ChannelSink::new(tx.clone(), r)).collect();
    (sinks, rx)
}

/// Drain the shards' streaming event channel on the caller thread:
/// bucket per replica (each sender is FIFO), concatenate in replica
/// order, stable-sort by timestamp — exactly the order the old
/// whole-run per-shard buffers merged to, so recorded streams are
/// byte-identical while in-flight memory stays bounded by the channel.
fn drain_events(rx: mpsc::Receiver<EngineEvent>, replicas: usize) -> Vec<EngineEvent> {
    let mut per: Vec<Vec<EngineEvent>> = vec![Vec::new(); replicas];
    while let Ok(ev) = rx.recv() {
        per[ev.replica].push(ev);
    }
    let mut all: Vec<EngineEvent> = Vec::with_capacity(per.iter().map(Vec::len).sum());
    for bucket in per {
        all.extend(bucket);
    }
    all.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms));
    all
}

/// Split a stream along the smooth-WRR schedule the sequential
/// [`Router`] walks for [`RoutePolicy::WeightedRoundRobin`], so both
/// execution modes assign every request to the same replica.
fn split_weighted(requests: &[Request], replicas: usize, speed_factors: &[f64]) -> Vec<Vec<Request>> {
    let weights: Vec<f64> = (0..replicas)
        .map(|r| speed_factors.get(r).copied().unwrap_or(1.0))
        .collect();
    let mut wrr = WrrState::new(&weights);
    split_with(requests, replicas, || wrr.next())
}

#[allow(clippy::too_many_arguments)]
fn serve_sharded_preloaded<B: StageBackend + Send, S: EventSink>(
    workers: usize,
    backends: &mut [B],
    est: &(dyn MetricsSource + Sync),
    failovers: &mut [Failover],
    cfg: &EngineConfig,
    streams: Vec<Vec<Request>>,
    inputs: &HostTensor,
    plans: &[FailurePlan],
    last_arrival_ms: f64,
    sinks: Vec<S>,
    drain: impl FnOnce() -> Vec<EngineEvent>,
) -> Result<(ShardOutcome, Vec<EngineEvent>)> {
    let empty_plan = FailurePlan::none();
    let tasks: Vec<ShardTask<'_, B, S>> = backends
        .iter_mut()
        .zip(failovers.iter_mut())
        .zip(streams)
        .zip(sinks)
        .enumerate()
        .map(|(r, (((backend, failover), stream), sink))| ShardTask {
            global_replica: r,
            backend,
            failover,
            plan: plans.get(r).unwrap_or(&empty_plan),
            arrivals: ShardArrivals::Preloaded(stream),
            outstanding: None,
            speed: cfg.speed_factors.get(r).copied().unwrap_or(1.0),
            speed_cell: None,
            // Positional schedules are the determinism surface: they
            // never steal, whatever cfg.steal says.
            steal: None,
            sink,
        })
        .collect();
    run_shards(workers, tasks, est, cfg, inputs, last_arrival_ms, drain)
}

#[allow(clippy::too_many_arguments)]
fn serve_sharded_jsq<B: StageBackend + Send, S: EventSink>(
    workers: usize,
    backends: &mut [B],
    est: &(dyn MetricsSource + Sync),
    failovers: &mut [Failover],
    cfg: &EngineConfig,
    requests: &[Request],
    inputs: &HostTensor,
    plans: &[FailurePlan],
    last_arrival_ms: f64,
    sinks: Vec<S>,
    drain: impl FnOnce() -> Vec<EngineEvent>,
) -> Result<(ShardOutcome, Vec<EngineEvent>)> {
    let replicas = backends.len();
    let factors: Vec<f64> = (0..replicas)
        .map(|r| cfg.speed_factors.get(r).copied().unwrap_or(1.0))
        .collect();
    let mut router = ShardRouter::with_speeds(cfg.route, &factors);
    let weighted = cfg.route == RoutePolicy::WeightedJoinShortestQueue;
    let pools: Option<Arc<Vec<StealPool>>> = if cfg.steal && replicas > 1 {
        Some(Arc::new((0..replicas).map(|_| StealPool::new()).collect()))
    } else {
        None
    };
    let counters: Vec<Arc<CachePadded<AtomicUsize>>> =
        (0..replicas).map(|r| router.counter(r)).collect();
    let empty_plan = FailurePlan::none();
    let mut txs = Vec::with_capacity(replicas);
    let mut tasks = Vec::with_capacity(replicas);
    for (r, ((backend, failover), sink)) in backends
        .iter_mut()
        .zip(failovers.iter_mut())
        .zip(sinks)
        .enumerate()
    {
        let (tx, rx) = mpsc::channel();
        txs.push(tx);
        tasks.push(ShardTask {
            global_replica: r,
            backend,
            failover,
            plan: plans.get(r).unwrap_or(&empty_plan),
            arrivals: ShardArrivals::Channel(rx),
            outstanding: Some(router.counter(r)),
            speed: factors[r],
            // Only weighted JSQ reads published speeds; plain JSQ shards
            // skip the per-condition-event atomic store.
            speed_cell: weighted.then(|| router.speed_cell(r)),
            steal: pools.as_ref().map(|p| StealCtx {
                me: r,
                pools: Arc::clone(p),
                outstanding: counters.clone(),
            }),
            sink,
        });
    }
    // The feeder runs on the calling thread while the shards run on the
    // scoped workers: it routes each arrival to the replica with the
    // fewest outstanding requests — weighted by published effective
    // speed under weighted JSQ — and never blocks (request channels are
    // unbounded), so shards multiplexed onto fewer workers than replicas
    // simply find their traffic buffered when a worker picks them up.
    // The event drain follows on the same thread once feeding is done;
    // the bounded event channel holds what shards emit meanwhile.
    run_shards(workers, tasks, est, cfg, inputs, last_arrival_ms, move || {
        for req in requests {
            let r = router.route();
            // A shard that died early dropped its receiver; its error
            // surfaces through run_shards, so the send result is moot.
            let _ = txs[r].send(*req);
        }
        // Dropping the senders closes every intake: watermark → ∞ and
        // the shards drain.
        drop(txs);
        drain()
    })
}

fn run_shards<B: StageBackend + Send, S: EventSink>(
    workers: usize,
    tasks: Vec<ShardTask<'_, B, S>>,
    est: &(dyn MetricsSource + Sync),
    cfg: &EngineConfig,
    inputs: &HostTensor,
    last_arrival_ms: f64,
    foreground: impl FnOnce() -> Vec<EngineEvent>,
) -> Result<(ShardOutcome, Vec<EngineEvent>)> {
    let (outcomes, events) = parallel_map_with(
        tasks,
        workers,
        |task| run_shard(task, est, cfg, inputs, last_arrival_ms),
        foreground,
    );
    let shards: Vec<ShardOutcome> = outcomes.into_iter().collect::<Result<_>>()?;
    Ok((merge_outcomes(shards), events))
}

/// Run one replica as a 1-replica engine (its own heap, slab, plan
/// cache and metrics). Local replica index is 0; the global index seeds
/// the monitored channel identically to the sequential run.
fn run_shard<B: StageBackend, S: EventSink>(
    task: ShardTask<'_, B, S>,
    est: &(dyn MetricsSource + Sync),
    cfg: &EngineConfig,
    inputs: &HostTensor,
    last_arrival_ms: f64,
) -> Result<ShardOutcome> {
    let ShardTask {
        global_replica,
        backend,
        failover,
        plan,
        arrivals,
        outstanding,
        speed,
        speed_cell,
        steal,
        mut sink,
    } = task;
    let mut eng = Engine::new(
        std::slice::from_mut(backend),
        std::slice::from_mut(failover),
        est,
        cfg,
        inputs,
        &mut sink,
    );
    eng.outstanding = outstanding;
    eng.speeds = vec![speed];
    eng.speed_cell = speed_cell;
    eng.steal = steal;
    match arrivals {
        ShardArrivals::Preloaded(reqs) => {
            eng.pending_arrivals = reqs.len();
            for req in &reqs {
                eng.push(req.arrival_ms, EventKind::Arrival { req: *req, replica: Some(0) });
            }
        }
        ShardArrivals::Channel(rx) => {
            eng.intake = Some(Intake {
                rx,
                open: true,
                watermark_ms: f64::NEG_INFINITY,
            });
        }
    }
    eng.schedule_failure_events(0, global_replica, plan, last_arrival_ms);
    eng.run()
}

/// What one shard (or the whole sequential run) accumulates; replica
/// indices in the records are shard-local until [`merge_outcomes`]
/// re-tags them.
struct ShardOutcome {
    latency: Streaming,
    completed: Vec<Completion>,
    completed_count: usize,
    dropped: Vec<DroppedRequest>,
    windows: Vec<FailoverWindow>,
    max_in_flight: usize,
    batches_dispatched: usize,
    events_processed: usize,
    clock_ms: f64,
    plan_hits: usize,
    plan_misses: usize,
    deploy_windows: Vec<DeployWindow>,
}

type ShardResultReport = ServiceReport;

/// Combine per-shard outcomes into one run-level outcome: bucket-exact
/// histogram merge, pairwise Welford combine, counter sums, window
/// concat (sorted by start time then replica — the order the sequential
/// loop emits same-time windows in), record concat with replica indices
/// re-tagged from shard-local 0 to global. Observability events are not
/// merged here: they stream through [`ChannelSink`]s already re-tagged,
/// and [`drain_events`] restores the deterministic order.
fn merge_outcomes(shards: Vec<ShardOutcome>) -> ShardOutcome {
    let mut merged = ShardOutcome {
        latency: Streaming::default(),
        completed: Vec::new(),
        completed_count: 0,
        dropped: Vec::new(),
        windows: Vec::new(),
        max_in_flight: 0,
        batches_dispatched: 0,
        events_processed: 0,
        clock_ms: 0.0,
        plan_hits: 0,
        plan_misses: 0,
        deploy_windows: Vec::new(),
    };
    for (r, mut o) in shards.into_iter().enumerate() {
        for c in &mut o.completed {
            c.replica = r;
        }
        for d in &mut o.dropped {
            d.replica = r;
        }
        for w in &mut o.windows {
            w.replica = r;
        }
        for w in &mut o.deploy_windows {
            w.replica = r;
        }
        merged.latency.merge(&o.latency);
        merged.completed.extend(o.completed);
        merged.completed_count += o.completed_count;
        merged.dropped.extend(o.dropped);
        merged.windows.extend(o.windows);
        merged.max_in_flight = merged.max_in_flight.max(o.max_in_flight);
        merged.batches_dispatched += o.batches_dispatched;
        merged.events_processed += o.events_processed;
        merged.clock_ms = merged.clock_ms.max(o.clock_ms);
        merged.plan_hits += o.plan_hits;
        merged.plan_misses += o.plan_misses;
        merged.deploy_windows.extend(o.deploy_windows);
    }
    merged
        .windows
        .sort_by(|a, b| a.start_ms.total_cmp(&b.start_ms).then(a.replica.cmp(&b.replica)));
    merged
        .deploy_windows
        .sort_by(|a, b| a.start_ms.total_cmp(&b.start_ms).then(a.replica.cmp(&b.replica)));
    merged
}

fn finalize(o: ShardOutcome) -> ServiceReport {
    let span = o.clock_ms.max(1e-9);
    ServiceReport {
        throughput_rps: o.completed_count as f64 / (span / 1e3),
        latency: o.latency.summary(),
        latency_stream: o.latency,
        completed: o.completed,
        completed_count: o.completed_count,
        dropped: o.dropped,
        failovers: o.windows,
        sim_span_ms: span,
        max_in_flight: o.max_in_flight,
        events_processed: o.events_processed,
        batches_dispatched: o.batches_dispatched,
        plan_cache_hits: o.plan_hits,
        plan_cache_misses: o.plan_misses,
        deploy_windows: o.deploy_windows,
    }
}

impl<'a, B: StageBackend, S: EventSink> Engine<'a, B, S> {
    fn new(
        backends: &'a mut [B],
        failovers: &'a mut [Failover],
        est: &'a dyn MetricsSource,
        cfg: &'a EngineConfig,
        inputs: &'a HostTensor,
        sink: &'a mut S,
    ) -> Engine<'a, B, S> {
        let states: Vec<ReplicaState> = backends
            .iter()
            .map(|b| ReplicaState::new(b.num_nodes()))
            .collect();
        let plan_caches: Vec<PlanCache> = backends.iter().map(|_| PlanCache::new()).collect();
        let deploys = backends.iter().map(|_| None).collect();
        let speeds: Vec<f64> = (0..backends.len())
            .map(|r| cfg.speed_factors.get(r).copied().unwrap_or(1.0))
            .collect();
        Engine {
            backends,
            failovers,
            est,
            cfg,
            inputs,
            router: Router::with_speeds(cfg.route, &cfg.speed_factors),
            events: AnyQueue::new(cfg.event_queue),
            seq: 0,
            states,
            batches: Slab::new(),
            plan_caches,
            pad_idxs: Vec::new(),
            latency: Streaming::default(),
            completed: Vec::new(),
            completed_count: 0,
            dropped: Vec::new(),
            windows: Vec::new(),
            max_in_flight: 0,
            batches_dispatched: 0,
            events_processed: 0,
            clock_ms: 0.0,
            pending_arrivals: 0,
            intake: None,
            outstanding: None,
            speeds,
            speed_cell: None,
            steal: None,
            sink,
            deploys,
            deploy_seq: 0,
            deploy_windows: Vec::new(),
        }
    }
}

impl<B: StageBackend, S: EventSink> Engine<'_, B, S> {
    /// Emit one observability event. With [`NoopSink`] (the default)
    /// this inlines to nothing and the event is never constructed.
    #[inline]
    fn emit(&mut self, at_ms: f64, replica: usize, kind: EngineEventKind) {
        self.sink.on_event(&EngineEvent {
            at_ms,
            replica,
            kind,
        });
    }

    /// Schedule replica `local_r`'s ground-truth failure flips and its
    /// detection stream. `global_r` is the replica's index in the
    /// caller's arrays and `last_arrival_ms` the *global* end of traffic:
    /// a shard (where `local_r` is 0) seeds its monitored channel and
    /// bounds its horizon exactly as the sequential run does for the same
    /// replica, so both modes see identical detection streams.
    fn schedule_failure_events(
        &mut self,
        local_r: usize,
        global_r: usize,
        plan: &FailurePlan,
        last_arrival_ms: f64,
    ) {
        // Ground truth: the node flips at at_ms regardless of how (or
        // whether) the controller finds out.
        for e in &plan.events {
            self.push(
                e.at_ms,
                EventKind::RawCondition {
                    replica: local_r,
                    node: e.node,
                    condition: e.condition,
                },
            );
        }
        let cfg = self.cfg;
        match &cfg.health {
            HealthMode::Oracle(det) => {
                // Seed behaviour: crashes detected at the quantised
                // detection time, recoveries seen instantly, gray
                // failures slow stages in place without a failover.
                for e in &plan.events {
                    match e.condition {
                        NodeCondition::Down => self.push(
                            det.detection_time(e.at_ms),
                            EventKind::DetectFailover {
                                replica: local_r,
                                node: e.node,
                                false_positive: false,
                            },
                        ),
                        NodeCondition::Up => self.push(
                            e.at_ms,
                            EventKind::DetectRecovery { replica: local_r, node: e.node },
                        ),
                        NodeCondition::Degraded(_) => {}
                    }
                }
            }
            HealthMode::Monitored(health) => {
                // Per-replica monitor with an independent seeded channel,
                // keyed by the *global* replica index.
                let mut hcfg = health.clone();
                hcfg.seed = health.seed.wrapping_add(global_r as u64);
                let horizon = hcfg.horizon_for(plan, last_arrival_ms);
                let num_nodes = self.backends[local_r].num_nodes();
                for ev in simulate_monitor(&hcfg, plan, num_nodes, horizon) {
                    match ev.kind {
                        HealthEventKind::Failover { false_positive } => self.push(
                            ev.at_ms,
                            EventKind::DetectFailover {
                                replica: local_r,
                                node: ev.node,
                                false_positive,
                            },
                        ),
                        HealthEventKind::Recovery => self.push(
                            ev.at_ms,
                            EventKind::DetectRecovery { replica: local_r, node: ev.node },
                        ),
                    }
                }
            }
        }
    }

    fn push(&mut self, at_ms: f64, kind: EventKind) {
        self.seq += 1;
        self.events.push(at_ms, self.seq, kind);
    }

    fn run(mut self) -> Result<ShardOutcome> {
        loop {
            // Top up from the live intake (if any) until the earliest
            // queued event is at or before the arrival watermark.
            self.pull_arrivals();
            // All traffic served and nothing queued or in flight: stop.
            // Matching the seed loop, failure events scheduled after the
            // stream ends never fire and do not stretch the sim span.
            if self.is_done() {
                break;
            }
            let Some((at_ms, _seq, kind)) = self.events.pop() else {
                // An empty queue with stealing on can still mean work:
                // our own offloads (reclaimable) or a backlogged
                // sibling's pool. Dispatching refills from the pools and
                // pushes stage events back onto the queue.
                if self.steal.is_some() {
                    for r in 0..self.states.len() {
                        self.try_dispatch(r, self.clock_ms)?;
                    }
                    if !self.events.is_empty() {
                        continue;
                    }
                }
                break;
            };
            self.events_processed += 1;
            // Every event the engine schedules is at or after the event
            // being processed (the intake watermark extends that to
            // channel-fed arrivals), so pops are non-decreasing in time
            // whatever queue implementation runs.
            debug_assert!(
                at_ms >= self.clock_ms,
                "event queue popped t={at_ms} behind the clock {}",
                self.clock_ms
            );
            self.clock_ms = self.clock_ms.max(at_ms);
            let t = self.clock_ms;
            match kind {
                EventKind::Arrival { req, replica } => {
                    self.pending_arrivals -= 1;
                    let (r, routed) = match replica {
                        // Pinned: pre-routed streams and shards (whose
                        // one local replica is 0) bypass the router.
                        Some(r) => (r, false),
                        None if self.states.len() == 1 => (0, false),
                        None => {
                            // Expired requests must not inflate a replica's
                            // apparent load before the router reads it.
                            for r in 0..self.states.len() {
                                self.prune_expired(r, t);
                            }
                            let loads: Vec<ReplicaLoad> = self
                                .states
                                .iter()
                                .map(|s| ReplicaLoad {
                                    queued: s.queue.len(),
                                    in_flight: s.in_flight_reqs,
                                })
                                .collect();
                            // Weighted JSQ ranks by expected drain time
                            // over *effective* speed — a replica with a
                            // degraded node sheds load before any
                            // failover trips.
                            let eff: Vec<f64> =
                                if self.cfg.route == RoutePolicy::WeightedJoinShortestQueue {
                                    (0..self.states.len())
                                        .map(|i| self.effective_speed(i))
                                        .collect()
                                } else {
                                    Vec::new()
                                };
                            (self.router.route(&loads, &eff), true)
                        }
                    };
                    self.emit(t, r, EngineEventKind::Arrival { id: req.id });
                    self.states[r].queue.push_back(req);
                    self.try_dispatch(r, t)?;
                    if routed && self.cfg.steal {
                        self.rebalance(t)?;
                    }
                }
                EventKind::RawCondition { replica, node, condition } => {
                    // Only flip the node: a recovery is dispatched by its
                    // DetectRecovery event (same timestamp, later seq in
                    // oracle mode), which first clears the degraded mode —
                    // dispatching here would serve the recovery-instant
                    // batch on the stale degraded path.
                    self.backends[replica].set_condition(node, condition);
                    self.emit(t, replica, EngineEventKind::Condition { node, condition });
                    // A weighted-JSQ shard advertises its new effective
                    // speed so the feeder reroutes around degradation.
                    self.publish_speed(replica);
                    // Back up but still failed over: the node sits in
                    // the reintegration gate until the health layer
                    // clears it (DetectRecovery below).
                    if matches!(condition, NodeCondition::Up)
                        && self.failovers[replica].failed_node() == Some(node)
                    {
                        self.emit(t, replica, EngineEventKind::QuarantineEnter { node });
                    }
                }
                EventKind::DetectFailover { replica, node, false_positive } => {
                    // With a deployment model active, compute the weight
                    // movement repartitioning would need *before* the
                    // decision, so the policy prices its downtime: the
                    // full transfer + warm-up span under break-before-make
                    // (serving stalls through it), nothing under
                    // make-before-break (a fallback keeps serving).
                    let deploy_plan = if self.cfg.deployment.mode != DeployMode::Instantaneous {
                        Some(self.plan_deploy(replica, node))
                    } else {
                        None
                    };
                    let extra = match &deploy_plan {
                        Some((_, span)) if self.cfg.deployment.mode == DeployMode::BreakBeforeMake => *span,
                        _ => 0.0,
                    };
                    let report = self.failovers[replica].on_failure_priced(self.est, node, extra)?;
                    let downtime = self
                        .cfg
                        .decision_ms_override
                        .unwrap_or_else(|| report.downtime_ms());
                    let technique = report.decision.chosen;
                    self.windows.push(FailoverWindow {
                        replica,
                        node,
                        start_ms: t,
                        end_ms: t + downtime,
                        technique,
                        false_positive,
                    });
                    self.emit(
                        t,
                        replica,
                        EngineEventKind::Failover {
                            node,
                            technique,
                            false_positive,
                            end_ms: t + downtime,
                        },
                    );
                    match deploy_plan {
                        Some((transfers, span))
                            if technique == Technique::Repartition && span > 0.0 =>
                        {
                            self.start_deploy(replica, node, transfers, span, t)?;
                        }
                        // The chosen technique needs no weight movement
                        // (early-exit/skip, or the new plan's units all
                        // sit where they already were): live immediately.
                        _ => self.cancel_deploy(replica, t),
                    }
                    self.try_dispatch(replica, t)?;
                }
                EventKind::DetectRecovery { replica, node } => {
                    // `on_recovery` reports whether the failover mode
                    // actually cleared — only then did the node leave
                    // the path (and any quarantine window close). The
                    // rollback itself is a routing flip, not a weight
                    // move — the recovered node kept its weights — so
                    // it stays instantaneous, and any deployment still
                    // in flight for the failure is moot.
                    if self.failovers[replica].on_recovery(node) {
                        self.cancel_deploy(replica, t);
                        self.emit(t, replica, EngineEventKind::QuarantineExit { node });
                        self.emit(t, replica, EngineEventKind::Recovery { node });
                    }
                    self.try_dispatch(replica, t)?;
                }
                EventKind::BatcherTimeout { replica } => {
                    self.states[replica].timeout_at = None;
                    self.try_dispatch(replica, t)?;
                }
                EventKind::StageStart { replica, batch } => {
                    self.on_stage_start(replica, batch, t)?;
                }
                EventKind::StageDone { replica, batch } => {
                    self.on_stage_done(replica, batch, t)?;
                }
                EventKind::Deploy(ev) => {
                    let DeployEvent { replica, deploy_id, phase } = *ev;
                    if !self.deploys[replica].as_ref().is_some_and(|d| d.id == deploy_id) {
                        continue; // stale: superseded or cancelled deployment
                    }
                    match phase {
                        DeployPhase::TransferDone { node } => {
                            self.emit(t, replica, EngineEventKind::TransferDone { node });
                        }
                        DeployPhase::WarmupDone { node } => {
                            self.emit(t, replica, EngineEventKind::WarmupDone { node });
                        }
                        DeployPhase::Cutover => {
                            let d = self.deploys[replica].take().unwrap();
                            let w = &mut self.deploy_windows[d.window_idx];
                            w.cutover_ms = t;
                            w.completed = true;
                            // Break-before-make stalled dispatch for the whole
                            // window; make-before-break served on the fallback
                            // and stalls nothing.
                            let stalled_ms = if d.fallback.is_none() { t - d.start_ms } else { 0.0 };
                            self.emit(t, replica, EngineEventKind::Cutover { node: d.node, stalled_ms });
                            // The atomic switch: dispatch now uses the failover
                            // mode's repartitioned plan. In-flight fallback
                            // batches drain untouched; nothing requeues.
                            self.try_dispatch(replica, t)?;
                        }
                    }
                }
            }
        }

        // Requests a wedged replica could never serve (e.g. a second
        // overlapping failure on the recovery path) are recorded as drops.
        // A wedged shard first reclaims its own steal pool: those
        // requests are still its debt and must be accounted exactly once.
        if let Some(ctx) = self.steal.take() {
            let mine = ctx.pools[ctx.me].take_all();
            if !mine.is_empty() {
                let mut mine = mine;
                mine.sort_by(|a, b| a.arrival_ms.total_cmp(&b.arrival_ms));
                self.states[0].requeue_sorted(mine);
            }
        }
        let t_end = self.clock_ms;
        for r in 0..self.states.len() {
            let degraded = self.failovers[r].technique().is_some();
            while let Some(q) = self.states[r].queue.pop_front() {
                self.dropped.push(DroppedRequest {
                    id: q.id,
                    replica: r,
                    arrival_ms: q.arrival_ms,
                    dropped_at_ms: t_end,
                    degraded,
                });
                self.emit(
                    t_end,
                    r,
                    EngineEventKind::Drop {
                        id: q.id,
                        arrival_ms: q.arrival_ms,
                        degraded,
                    },
                );
                self.note_request_retired();
            }
        }

        let (plan_hits, plan_misses) = self
            .plan_caches
            .iter()
            .fold((0, 0), |(h, m), c| (h + c.hits(), m + c.misses()));
        Ok(ShardOutcome {
            latency: self.latency,
            completed: self.completed,
            completed_count: self.completed_count,
            dropped: self.dropped,
            windows: self.windows,
            max_in_flight: self.max_in_flight,
            batches_dispatched: self.batches_dispatched,
            events_processed: self.events_processed,
            clock_ms: self.clock_ms,
            plan_hits,
            plan_misses,
            deploy_windows: self.deploy_windows,
        })
    }

    /// Compute the weight movement repartitioning around `failed` needs
    /// on replica `r`: every unit of the new plan not already hosted on
    /// the same node under the plan being served *now* must have its
    /// weights pushed to its new host. Returns per-host transfer times
    /// and the deployment span (slowest transfer plus warm-up; zero when
    /// nothing moves — then no host warms up either).
    ///
    /// Plans are computed directly from the backend, NOT through the
    /// replica's [`PlanCache`]: deployment planning must never perturb
    /// the cache hit/miss counters the report surfaces, or the
    /// instantaneous-swap degenerate config would stop reproducing
    /// pre-deployment reports byte-for-byte.
    fn plan_deploy(&self, r: usize, failed: usize) -> (Vec<(usize, f64)>, f64) {
        let backend = &self.backends[r];
        let prev_tech = self.failovers[r].technique().unwrap_or(Technique::Repartition);
        let prev_failed = self.failovers[r].failed_node();
        let old = backend.steps(prev_tech, prev_failed);
        let new = backend.steps(Technique::Repartition, Some(failed));
        let mut per_host: Vec<(usize, usize)> = Vec::new();
        for step in &new {
            let already_there = old.iter().any(|o| o.unit == step.unit && o.host == step.host);
            if already_there {
                continue;
            }
            let bytes = backend.unit_weight_bytes(step.unit);
            if bytes == 0 {
                continue;
            }
            match per_host.iter_mut().find(|(h, _)| *h == step.host) {
                Some((_, b)) => *b += bytes,
                None => per_host.push((step.host, bytes)),
            }
        }
        let mut transfers: Vec<(usize, f64)> = Vec::with_capacity(per_host.len());
        let mut slowest: f64 = 0.0;
        for (host, bytes) in per_host {
            let ms = backend.deploy_transfer_ms(host, bytes);
            slowest = slowest.max(ms);
            transfers.push((host, ms));
        }
        if transfers.is_empty() {
            (transfers, 0.0)
        } else {
            let span = slowest + self.cfg.deployment.warmup_ms;
            (transfers, span)
        }
    }

    /// Begin a repartition deployment on replica `r` around failed
    /// `node`: schedule per-host transfer/warm-up completions and the
    /// cut-over, pick the make-before-break fallback (if the mode asks
    /// for one and a repartition-free candidate exists), and open the
    /// report's deployment window. A deployment already in flight is
    /// superseded — the newer failure's plan wins.
    fn start_deploy(
        &mut self,
        r: usize,
        node: usize,
        transfers: Vec<(usize, f64)>,
        span: f64,
        t: f64,
    ) -> Result<()> {
        self.cancel_deploy(r, t);
        self.deploy_seq += 1;
        let id = self.deploy_seq;
        let fallback = match self.cfg.deployment.mode {
            DeployMode::MakeBeforeBreak => self.failovers[r].fallback_technique(self.est, node)?,
            _ => None,
        };
        let cutover_ms = t + span;
        self.emit(
            t,
            r,
            EngineEventKind::DeployStart {
                node,
                make_before_break: fallback.is_some(),
                transfers: transfers.len(),
                cutover_ms,
            },
        );
        let warmup = self.cfg.deployment.warmup_ms;
        let deploy_ev = |phase: DeployPhase| {
            EventKind::Deploy(Box::new(DeployEvent { replica: r, deploy_id: id, phase }))
        };
        for &(host, ms) in &transfers {
            self.push(t + ms, deploy_ev(DeployPhase::TransferDone { node: host }));
            self.push(t + ms + warmup, deploy_ev(DeployPhase::WarmupDone { node: host }));
        }
        self.push(cutover_ms, deploy_ev(DeployPhase::Cutover));
        let window_idx = self.deploy_windows.len();
        self.deploy_windows.push(DeployWindow {
            replica: r,
            node,
            mode: self.cfg.deployment.mode,
            start_ms: t,
            transfer_ms: span - warmup,
            warmup_ms: warmup,
            cutover_ms,
            fallback,
            completed: false,
        });
        self.deploys[r] = Some(DeployState { id, node, start_ms: t, fallback, window_idx });
        Ok(())
    }

    /// Abandon replica `r`'s in-flight deployment, if any: the failed
    /// node recovered first, a newer failure superseded it, or the new
    /// decision needs no deployment. The window keeps `completed: false`
    /// and records the abandonment time as its end; stale heap events
    /// for it miss by id.
    fn cancel_deploy(&mut self, r: usize, t: f64) {
        if let Some(d) = self.deploys[r].take() {
            let w = &mut self.deploy_windows[d.window_idx];
            w.cutover_ms = t;
            w.completed = false;
        }
    }

    /// The run is over when no arrival can still come in (heap arrivals
    /// exhausted and the live intake, if any, closed) and nothing is
    /// queued or in flight anywhere. Failure events left in the heap
    /// never fire — the seed's "failures after the stream ends don't
    /// count" idiom.
    fn is_done(&self) -> bool {
        self.pending_arrivals == 0
            && self.intake.as_ref().is_none_or(|i| !i.open)
            && self.batches.is_empty()
            && self.states.iter().all(|s| s.queue.is_empty())
            // Own offloads are still this shard's debt: it cannot exit
            // while they sit unreclaimed in its steal pool (a sibling
            // may still take them, but the owner is the backstop).
            // Relaxed load: only the owner pushes into its own pool, and
            // a thief's decrement moved the debt to the thief's counter
            // under the pool mutex before this read can see it — a stale
            // non-zero merely delays exit by one loop turn; zero is
            // always truthful.
            && self
                .steal
                .as_ref()
                .is_none_or(|c| c.pools[c.me].len.load(AtomicOrdering::Relaxed) == 0)
    }

    /// Drain the live intake into the event queue until its earliest
    /// event is safely processable: the feeder sends arrivals in
    /// nondecreasing time, so once the watermark reaches the earliest
    /// queued event no later-fed request can precede it. Blocks on the
    /// channel while the queue is empty or still ahead of the watermark;
    /// channel close lifts the watermark to infinity (the shard drains).
    /// No-op without an intake (preloaded shards and the sequential
    /// engine).
    fn pull_arrivals(&mut self) {
        loop {
            let msg = {
                let Some(intake) = self.intake.as_mut() else { return };
                if !intake.open {
                    return;
                }
                let watermark = intake.watermark_ms;
                if self.events.peek_time().is_some_and(|at| at <= watermark) {
                    return;
                }
                intake.rx.recv()
            };
            match msg {
                Ok(req) => {
                    self.pending_arrivals += 1;
                    let at = req.arrival_ms;
                    self.push(at, EventKind::Arrival { req, replica: Some(0) });
                    if let Some(intake) = self.intake.as_mut() {
                        intake.watermark_ms = at;
                    }
                }
                Err(_) => {
                    if let Some(intake) = self.intake.as_mut() {
                        intake.open = false;
                    }
                }
            }
        }
    }

    /// Tell the sharded router's feeder this shard retired one request
    /// (served or dropped); live JSQ routing reads these counters. No-op
    /// outside channel-fed sharding.
    fn note_request_retired(&self) {
        if let Some(c) = &self.outstanding {
            // Relaxed: the counter is a routing heuristic the feeder
            // samples — request hand-off itself synchronizes through the
            // mpsc channel, so no data is published by this store. A
            // momentarily stale count only skews one routing choice.
            c.fetch_sub(1, AtomicOrdering::Relaxed);
        }
    }

    /// Replica `r`'s effective speed: its platform factor divided by the
    /// worst degraded slowdown currently observed on any of its nodes.
    /// Down nodes don't factor in — they stop the path entirely and are
    /// the failover layer's problem, not a routing weight.
    fn effective_speed(&self, r: usize) -> f64 {
        let b = &self.backends[r];
        let mut worst = 1.0f64;
        for node in 1..=b.num_nodes() {
            if let NodeCondition::Degraded(s) = b.condition(node) {
                worst = worst.max(s);
            }
        }
        self.speeds[r] / worst.max(1.0)
    }

    /// Publish replica `r`'s effective speed to the sharded router's
    /// feeder (fixed-point, ×[`SPEED_MILLI`]). No-op outside
    /// weighted-JSQ sharding.
    fn publish_speed(&self, r: usize) {
        if let Some(cell) = &self.speed_cell {
            let eff = self.effective_speed(r).max(1e-3);
            // Relaxed: advisory weight for the feeder's drain-time
            // ranking; no other data hangs off this store, and reading
            // the previous speed for a moment routes suboptimally, not
            // incorrectly.
            cell.store((eff * SPEED_MILLI) as u32, AtomicOrdering::Relaxed);
        }
    }

    /// Largest supported batch size: the unit of work moved per steal.
    fn max_batch(&self) -> usize {
        self.cfg.batcher.supported.iter().copied().max().unwrap_or(1).max(1)
    }

    /// Queue depth a replica keeps for itself before offering the rest
    /// for stealing: enough to refill its whole pipeline with full
    /// batches, so stealing never starves the donor.
    fn steal_keep(&self) -> usize {
        self.max_batch() * self.cfg.pipeline_depth
    }

    /// Move this saturated shard's queue tail (beyond [`Self::steal_keep`])
    /// into its own injector pool, where siblings can take it. The owner
    /// reclaims unstolen offloads before it can exit, so every offloaded
    /// request is still served or dropped exactly once.
    fn offload_excess(&mut self, r: usize) {
        let Some(ctx) = self.steal.take() else { return };
        let keep = self.steal_keep();
        if self.states[r].queue.len() > keep {
            let tail = self.states[r].queue.split_off(keep);
            ctx.pools[ctx.me].push(tail);
        }
        self.steal = Some(ctx);
    }

    /// Refill an idle shard's queue from the steal pools: reclaim *all*
    /// of its own offloads first (they are its routing debt), else steal
    /// up to one max-size batch from the fullest sibling pool, moving
    /// the outstanding-counter debt from victim to thief. Returns true
    /// if anything was requeued. Stolen chunks are sorted by arrival
    /// before the merge — successive offloads need not be globally
    /// ordered once mid-run requeues have interleaved the queue.
    fn refill_from_steal(&mut self, r: usize) -> bool {
        let Some(ctx) = self.steal.take() else { return false };
        let mut got = ctx.pools[ctx.me].take_all();
        if got.is_empty() {
            let mut victim = None;
            let mut fullest = 0usize;
            for (i, p) in ctx.pools.iter().enumerate() {
                // Relaxed: victim selection is heuristic — take_up_to
                // re-checks the real deque under its mutex, so a stale
                // size costs at worst a suboptimal (or empty) steal.
                let l = p.len.load(AtomicOrdering::Relaxed);
                if i != ctx.me && l > fullest {
                    fullest = l;
                    victim = Some(i);
                }
            }
            if let Some(v) = victim {
                got = ctx.pools[v].take_up_to(self.max_batch());
                if !got.is_empty() {
                    // Relaxed: moves routing debt between two advisory
                    // counters the feeder samples independently; the
                    // requests themselves were handed over under the
                    // pool mutex. The transient where both (or neither)
                    // counter holds the debt only nudges one JSQ choice.
                    ctx.outstanding[v].fetch_sub(got.len(), AtomicOrdering::Relaxed);
                    ctx.outstanding[ctx.me].fetch_add(got.len(), AtomicOrdering::Relaxed);
                }
            }
        }
        let refilled = !got.is_empty();
        if refilled {
            got.sort_by(|a, b| a.arrival_ms.total_cmp(&b.arrival_ms));
            self.states[r].requeue_sorted(got);
        }
        self.steal = Some(ctx);
        refilled
    }

    /// The sequential reference for cross-replica work stealing: after
    /// each routed arrival and each batch completion, every idle
    /// replica (empty queue, spare pipeline depth) pulls up to one
    /// max-size batch of
    /// queued-but-undispatched requests off the front of the most
    /// backlogged replica's queue (beyond what that donor needs to keep
    /// its own pipeline full). Pure virtual-time state — no atomics, no
    /// races — so same-seed runs stay byte-identical.
    fn rebalance(&mut self, t: f64) -> Result<()> {
        if self.states.len() < 2 {
            return Ok(());
        }
        let keep = self.steal_keep();
        let max_take = self.max_batch();
        loop {
            let Some(thief) = (0..self.states.len()).find(|&i| {
                self.states[i].queue.is_empty()
                    && self.states[i].in_flight_batches < self.cfg.pipeline_depth
            }) else {
                return Ok(());
            };
            // Donor: deepest backlog beyond its keep, ties to the
            // lowest index.
            let mut donor = None;
            let mut deepest = keep;
            for i in 0..self.states.len() {
                if i != thief && self.states[i].queue.len() > deepest {
                    deepest = self.states[i].queue.len();
                    donor = Some(i);
                }
            }
            let Some(d) = donor else { return Ok(()) };
            let take = max_take.min(self.states[d].queue.len() - keep);
            for _ in 0..take {
                let q = self.states[d].queue.pop_front().unwrap();
                // The thief's queue is empty, so donor-front order (the
                // oldest requests) keeps it arrival-sorted.
                self.states[thief].queue.push_back(q);
            }
            self.try_dispatch(thief, t)?;
            // A thief that could not actually dispatch (batcher wait,
            // wedged path) keeps the work queued; stop rather than
            // shuffle more onto it.
            if !self.states[thief].queue.is_empty() {
                return Ok(());
            }
        }
    }

    /// A batch reaches stage `b.stage`: requeue it if the host died while
    /// it was in flight, wait if the host is busy with an earlier batch,
    /// else run the real unit and schedule the stage completion.
    fn on_stage_start(&mut self, replica: usize, batch: SlabKey, t: f64) -> Result<()> {
        let step = match self.batches.get(batch) {
            Some(b) => b.steps[b.stage],
            None => return Ok(()),
        };
        if !self.backends[replica].is_up(step.host) {
            let b = self.batches.remove(batch).unwrap();
            let st = &mut self.states[replica];
            st.in_flight_batches -= 1;
            st.in_flight_reqs -= b.requests.len();
            st.requeue_sorted(b.requests);
            // Re-dispatch happens once the failover switches the path (the
            // detection event calls try_dispatch); if the path is already
            // healthy again this re-dispatches immediately.
            return self.try_dispatch(replica, t);
        }
        let free_at = self.states[replica].busy_until[step.host];
        if free_at > t + 1e-9 {
            self.push(free_at, EventKind::StageStart { replica, batch });
            return Ok(());
        }
        // Run the stage in place: the batch stays in its slab slot (the
        // old HashMap path removed and reinserted it around every stage).
        let b = self.batches.get_mut(batch).unwrap();
        let (y, ms) = self.backends[replica].run_stage(step, &b.x)?;
        // Platform heterogeneity: the backend prices the stage at nominal
        // speed (with any degraded-node slowdown already applied); the
        // replica's speed factor scales it — a 0.5× replica takes twice
        // as long on every stage.
        let ms = ms / self.speeds[replica];
        b.x = y;
        let (batch_seq, stage) = (b.trace_seq, b.stage);
        self.states[replica].busy_until[step.host] = t + ms;
        self.push(t + ms, EventKind::StageDone { replica, batch });
        self.emit(
            t,
            replica,
            EngineEventKind::StageStart {
                batch_seq,
                stage,
                node: step.host,
            },
        );
        Ok(())
    }

    /// A batch's current stage finished: move to the next stage (after the
    /// modeled transfer) or complete every request in the batch.
    fn on_stage_done(&mut self, replica: usize, batch: SlabKey, t: f64) -> Result<()> {
        let finished = match self.batches.get_mut(batch) {
            Some(b) => {
                let (batch_seq, stage, node) = (b.trace_seq, b.stage, b.steps[b.stage].host);
                b.stage += 1;
                let finished = b.stage >= b.steps.len();
                self.emit(
                    t,
                    replica,
                    EngineEventKind::StageDone {
                        batch_seq,
                        stage,
                        node,
                    },
                );
                finished
            }
            None => return Ok(()),
        };
        if finished {
            let b = self.batches.remove(batch).unwrap();
            let st = &mut self.states[replica];
            st.in_flight_batches -= 1;
            st.in_flight_reqs -= b.requests.len();
            for q in &b.requests {
                let latency_ms = t - q.arrival_ms;
                self.latency.record(latency_ms);
                self.completed_count += 1;
                self.note_request_retired();
                self.emit(
                    t,
                    replica,
                    EngineEventKind::Completion {
                        id: q.id,
                        latency_ms,
                    },
                );
                if self.cfg.record_completions {
                    self.completed.push(Completion {
                        id: q.id,
                        replica,
                        latency_ms,
                        technique: b.technique,
                        batch_size: b.target_batch,
                    });
                }
            }
            self.try_dispatch(replica, t)?;
            // Freed capacity is a stealing opportunity: the sequential
            // reference rebalances here as well as at routed arrivals,
            // so an idle replica keeps draining siblings after the
            // arrival stream ends. No-op on 1-replica engines (shards
            // steal through their pools in try_dispatch instead).
            if self.cfg.steal {
                self.rebalance(t)?;
            }
            Ok(())
        } else {
            let b = self.batches.get(batch).unwrap();
            let from = b.steps[b.stage - 1].host;
            let to = b.steps[b.stage].host;
            let bytes = b.x.bytes();
            let tr = self.backends[replica].transfer_ms(from, to, bytes);
            self.push(t + tr, EventKind::StageStart { replica, batch });
            Ok(())
        }
    }

    /// Dispatch as many batches as depth and the batcher allow on `r`.
    fn try_dispatch(&mut self, r: usize, t: f64) -> Result<()> {
        loop {
            // Prune before the depth check: even a saturated replica must
            // record expiries at the time they are observed, not at the
            // later dispatch that would otherwise first touch the queue.
            self.prune_expired(r, t);
            if self.states[r].in_flight_batches >= self.cfg.pipeline_depth {
                // A saturated shard's excess backlog becomes stealable.
                self.offload_excess(r);
                return Ok(());
            }
            if self.states[r].queue.is_empty() {
                if !self.refill_from_steal(r) {
                    return Ok(());
                }
                // Stolen (or reclaimed) work may already be past its
                // deadline: go round again so it is pruned before batching.
                continue;
            }
            // An in-flight deployment overrides the dispatch plan: the
            // repartitioned plan is not live until its cut-over, so serve
            // on the fallback technique (make-before-break) or stall
            // dispatch entirely (break-before-make — requests queue or
            // expire against their deadlines; the cut-over event resumes).
            let (technique, failed, technique_tag) = match self.deploys[r] {
                Some(DeployState { fallback: Some(fb), node, .. }) => (fb, Some(node), Some(fb)),
                Some(DeployState { fallback: None, .. }) => return Ok(()),
                None => (
                    self.failovers[r].technique().unwrap_or(Technique::Repartition),
                    self.failovers[r].failed_node(),
                    self.failovers[r].technique(),
                ),
            };
            // Cached: after warm-up this is a pointer copy, not a fresh
            // Vec<Step> per batch.
            let steps = self.plan_caches[r].plan(&self.backends[r], technique, failed);
            if steps.iter().any(|s| !self.backends[r].is_up(s.host)) {
                // A raw failure the controller has not yet detected (or an
                // overlapping failure the mode cannot route around): hold
                // dispatch; the detection/restore event retries.
                return Ok(());
            }
            let head_age = t - self.states[r].queue.front().unwrap().arrival_ms;
            match decide(&self.cfg.batcher, self.states[r].queue.len(), head_age) {
                Dispatch::Now(n) => {
                    let take = n.min(self.states[r].queue.len());
                    let mut reqs = Vec::with_capacity(take);
                    for _ in 0..take {
                        reqs.push(self.states[r].queue.pop_front().unwrap());
                    }
                    let target = self
                        .cfg
                        .batcher
                        .supported
                        .iter()
                        .copied()
                        .find(|&s| s >= take)
                        .unwrap_or(take);
                    let x = if self.backends[r].materializes() {
                        // Real path: gather the request rows, padded to
                        // the compiled batch size by repeating the first,
                        // in ONE output allocation (the old loop sliced a
                        // tensor per row and padded with deep clones).
                        self.pad_idxs.clear();
                        self.pad_idxs.extend(reqs.iter().map(|q| q.input_idx));
                        Activation::Full(self.inputs.gather_pad_rows0(&self.pad_idxs, target)?)
                    } else {
                        // Synthetic path: the scheduler only reads batch
                        // geometry — no row data is copied, ever.
                        Activation::Shape(ShapeOnly {
                            rows: target,
                            row_elems: self.inputs.row_elems(),
                        })
                    };
                    self.states[r].in_flight_batches += 1;
                    self.states[r].in_flight_reqs += reqs.len();
                    if self.states[r].in_flight_batches > self.max_in_flight {
                        self.max_in_flight = self.states[r].in_flight_batches;
                    }
                    let trace_seq = self.batches_dispatched;
                    self.batches_dispatched += 1;
                    self.emit(
                        t,
                        r,
                        EngineEventKind::BatchDispatch {
                            seq: trace_seq,
                            size: take,
                            target,
                        },
                    );
                    let key = self.batches.insert(BatchInFlight {
                        requests: reqs,
                        x,
                        steps,
                        stage: 0,
                        technique: technique_tag,
                        target_batch: target,
                        trace_seq,
                    });
                    self.push(t, EventKind::StageStart { replica: r, batch: key });
                }
                Dispatch::Wait => {
                    // decide() only waits while the head is younger than
                    // the batcher timeout, so `due` is in the future.
                    let head_arrival = self.states[r].queue.front().unwrap().arrival_ms;
                    let due = (head_arrival + self.cfg.batcher.timeout_ms).max(t + 1e-9);
                    if self.states[r].timeout_at != Some(due) {
                        self.states[r].timeout_at = Some(due);
                        self.push(due, EventKind::BatcherTimeout { replica: r });
                    }
                    return Ok(());
                }
            }
        }
    }

    /// Drop timed-out requests from the head of `r`'s queue (FIFO order
    /// means expired requests form a prefix).
    fn prune_expired(&mut self, r: usize, t: f64) {
        let Some(deadline) = self.cfg.deadline_ms else {
            return;
        };
        let degraded = self.failovers[r].technique().is_some();
        while let Some(front) = self.states[r].queue.front() {
            if t - front.arrival_ms > deadline {
                let q = self.states[r].queue.pop_front().unwrap();
                self.dropped.push(DroppedRequest {
                    id: q.id,
                    replica: r,
                    arrival_ms: q.arrival_ms,
                    dropped_at_ms: t,
                    degraded,
                });
                self.emit(
                    t,
                    r,
                    EngineEventKind::Drop {
                        id: q.id,
                        arrival_ms: q.arrival_ms,
                        degraded,
                    },
                );
                self.note_request_retired();
            } else {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Objectives;
    use crate::coordinator::estimator::StaticMetrics;
    use crate::workload::{generate, Arrival};

    fn cfg(depth: usize, route: RoutePolicy) -> EngineConfig {
        EngineConfig {
            batcher: BatcherConfig::new(vec![1], 2.0, 1),
            health: HealthMode::Oracle(Detector::default()),
            deadline_ms: None,
            pipeline_depth: depth,
            route,
            decision_ms_override: Some(1.5),
            record_completions: true,
            execution: Execution::Sequential,
            deployment: DeploymentConfig::default(),
            speed_factors: Vec::new(),
            steal: false,
            // CI sweeps the whole module under both queues by exporting
            // CONTINUER_QUEUE — results must not depend on the choice.
            event_queue: QueueKind::from_env(),
        }
    }

    /// Monitored health over a deterministic channel (no jitter/loss).
    fn monitored(depth: usize, health: HealthConfig) -> EngineConfig {
        EngineConfig {
            batcher: BatcherConfig::new(vec![1], 2.0, 1),
            health: HealthMode::Monitored(health),
            deadline_ms: None,
            pipeline_depth: depth,
            route: RoutePolicy::RoundRobin,
            decision_ms_override: Some(1.5),
            record_completions: true,
            execution: Execution::Sequential,
            deployment: DeploymentConfig::default(),
            speed_factors: Vec::new(),
            steal: false,
            event_queue: QueueKind::from_env(),
        }
    }

    #[test]
    fn event_payload_stays_within_hot_path_budget() {
        // The compaction contract: boxing the deployment payload keeps
        // Arrival (Request + Option<usize>) the widest variant, and one
        // queued entry — key plus payload — within a single cache line.
        assert!(
            std::mem::size_of::<EventKind>() <= 48,
            "EventKind grew to {} bytes — box the new variant's payload",
            std::mem::size_of::<EventKind>()
        );
        assert!(
            crate::util::eventq::entry_size::<EventKind>() <= 64,
            "a queued event entry is {} bytes — over one cache line",
            crate::util::eventq::entry_size::<EventKind>()
        );
    }

    #[test]
    fn heap_and_calendar_reports_are_byte_identical() {
        // Same seed, same fixture, both queue kinds: the full report —
        // counters, histogram, windows, completions — must not differ by
        // one byte (tests/sharded_equivalence.rs covers more modes).
        let run = |kind: QueueKind| {
            let mut backends = vec![
                SyntheticBackend::uniform(4, 5.0, 1.0),
                SyntheticBackend::uniform(4, 5.0, 1.0),
            ];
            let mut failovers = vec![
                Failover::new(Objectives::default()),
                Failover::new(Objectives::default()),
            ];
            let reqs = generate(80, Arrival::Poisson { rate_rps: 400.0 }, 8, 19);
            let plans = vec![FailurePlan::crash_recover(2, 20.0, 60.0)];
            let mut c = cfg(2, RoutePolicy::RoundRobin);
            c.deadline_ms = Some(60.0);
            c.event_queue = kind;
            serve(&mut backends, &StaticMetrics, &mut failovers, &c, &reqs, &pool(), &plans)
                .unwrap()
        };
        assert_eq!(
            format!("{:?}", run(QueueKind::Heap)),
            format!("{:?}", run(QueueKind::Calendar)),
            "queue choice must never change a report"
        );
    }

    fn clean_channel(detector: crate::health::DetectorKind, quarantine_ms: f64) -> HealthConfig {
        HealthConfig {
            heartbeat: crate::health::HeartbeatConfig {
                interval_ms: 10.0,
                jitter_ms: 0.0,
                loss_prob: 0.0,
                blackout: None,
            },
            detector,
            failover_slowdown: 3.0,
            quarantine_ms,
            slowdown_window: 8,
            seed: 7,
        }
    }

    fn pool() -> HostTensor {
        HostTensor::zeros(vec![8, 4])
    }

    fn two_replica_run(seed: u64) -> ServiceReport {
        let mut backends = vec![
            SyntheticBackend::uniform(4, 5.0, 1.0),
            SyntheticBackend::uniform(4, 5.0, 1.0),
        ];
        let mut failovers = vec![
            Failover::new(Objectives::default()),
            Failover::new(Objectives::default()),
        ];
        let reqs = generate(40, Arrival::Poisson { rate_rps: 400.0 }, 8, seed);
        let plans = vec![FailurePlan::crash(2, 20.0), FailurePlan::crash(3, 30.0)];
        serve(
            &mut backends,
            &StaticMetrics,
            &mut failovers,
            &cfg(2, RoutePolicy::RoundRobin),
            &reqs,
            &pool(),
            &plans,
        )
        .unwrap()
    }

    #[test]
    fn same_seed_runs_are_byte_identical() {
        let a = format!("{:?}", two_replica_run(7));
        let b = format!("{:?}", two_replica_run(7));
        assert_eq!(a, b, "same-seed reports must be byte-identical");
    }

    #[test]
    fn overlapping_failures_on_distinct_replicas() {
        let report = two_replica_run(13);
        // Both replicas failed over, once each, and the windows overlap
        // the raw failure times.
        assert_eq!(report.failovers.len(), 2);
        let mut replicas: Vec<usize> = report.failovers.iter().map(|w| w.replica).collect();
        replicas.sort_unstable();
        assert_eq!(replicas, vec![0, 1]);
        for w in &report.failovers {
            assert!(w.start_ms >= 20.0, "detection after the raw failure");
            assert!(w.downtime_ms() > 0.0);
        }
        // Every request was still served (no deadline, survivors recover).
        assert_eq!(report.completed.len(), 40, "dropped={}", report.dropped.len());
        assert!(report.dropped.is_empty());
        // Each replica served degraded traffic after its own failover.
        for r in [0usize, 1] {
            assert!(
                report
                    .completed
                    .iter()
                    .any(|c| c.replica == r && c.technique.is_some()),
                "replica {r} must serve degraded requests"
            );
        }
    }

    fn throughput_run(depth: usize) -> ServiceReport {
        let mut backends = vec![SyntheticBackend::uniform(4, 5.0, 1.0)];
        let mut failovers = vec![Failover::new(Objectives::default())];
        // Saturating load: arrivals far faster than the 23 ms path.
        let reqs = generate(50, Arrival::Uniform { gap_ms: 1.0 }, 8, 11);
        serve(
            &mut backends,
            &StaticMetrics,
            &mut failovers,
            &cfg(depth, RoutePolicy::RoundRobin),
            &reqs,
            &pool(),
            &[],
        )
        .unwrap()
    }

    #[test]
    fn pipelining_overlaps_batches_and_scales_throughput() {
        let seq = throughput_run(1);
        let pipe = throughput_run(4);
        assert_eq!(seq.completed.len(), 50);
        assert_eq!(pipe.completed.len(), 50);
        // The non-pipelined engine reproduces the seed's one-batch-at-a-time
        // behaviour; the pipelined engine genuinely overlaps batches.
        assert_eq!(seq.max_in_flight, 1);
        assert!(
            pipe.max_in_flight > 1,
            "pipelined run must sustain > 1 batch in flight (got {})",
            pipe.max_in_flight
        );
        // Throughput is set by the bottleneck stage (5 ms), not the path
        // (23 ms): >= 2x is the acceptance floor, ~4x expected.
        assert!(
            pipe.throughput_rps >= 2.0 * seq.throughput_rps,
            "pipelined {} rps vs sequential {} rps",
            pipe.throughput_rps,
            seq.throughput_rps
        );
    }

    #[test]
    fn replica_sharding_scales_throughput() {
        let run = |n_replicas: usize| {
            let mut backends: Vec<SyntheticBackend> = (0..n_replicas)
                .map(|_| SyntheticBackend::uniform(4, 5.0, 1.0))
                .collect();
            let mut failovers: Vec<Failover> = (0..n_replicas)
                .map(|_| Failover::new(Objectives::default()))
                .collect();
            let reqs = generate(60, Arrival::Uniform { gap_ms: 1.0 }, 8, 3);
            serve(
                &mut backends,
                &StaticMetrics,
                &mut failovers,
                &cfg(1, RoutePolicy::JoinShortestQueue),
                &reqs,
                &pool(),
                &[],
            )
            .unwrap()
        };
        let one = run(1);
        let four = run(4);
        assert_eq!(four.completed.len(), 60);
        assert!(
            four.throughput_rps >= 3.0 * one.throughput_rps,
            "4 replicas {} rps vs 1 replica {} rps",
            four.throughput_rps,
            one.throughput_rps
        );
    }

    #[test]
    fn deadline_drops_record_arrival_and_mode() {
        let mut backends = vec![SyntheticBackend::uniform(4, 5.0, 1.0)];
        let mut failovers = vec![Failover::new(Objectives::default())];
        // Saturating load with a tight deadline: the tail of the queue
        // times out while the pipeline grinds through earlier batches.
        let reqs = generate(30, Arrival::Uniform { gap_ms: 1.0 }, 8, 5);
        let report = serve(
            &mut backends,
            &StaticMetrics,
            &mut failovers,
            &EngineConfig {
                deadline_ms: Some(40.0),
                ..cfg(1, RoutePolicy::RoundRobin)
            },
            &reqs,
            &pool(),
            &[],
        )
        .unwrap();
        assert!(!report.dropped.is_empty(), "tight deadline must drop");
        assert_eq!(report.completed.len() + report.dropped.len(), 30);
        for d in &report.dropped {
            assert!(d.dropped_at_ms - d.arrival_ms > 40.0);
            assert!(!d.degraded, "healthy run: drops attributed to healthy mode");
        }
    }

    #[test]
    fn steady_state_dispatch_allocates_no_plans_after_warmup() {
        // Healthy run: exactly one step-plan allocation total, however
        // many batches dispatch — everything after warm-up is a cache hit.
        let mut backends = vec![SyntheticBackend::uniform(4, 5.0, 1.0)];
        let mut failovers = vec![Failover::new(Objectives::default())];
        let reqs = generate(200, Arrival::Uniform { gap_ms: 1.0 }, 8, 17);
        let report = serve(
            &mut backends,
            &StaticMetrics,
            &mut failovers,
            &cfg(2, RoutePolicy::RoundRobin),
            &reqs,
            &pool(),
            &[],
        )
        .unwrap();
        assert_eq!(report.completed_count, 200);
        assert!(report.batches_dispatched >= 200, "batch size 1");
        assert_eq!(report.plan_cache_misses, 1, "one allocation at warm-up");
        assert_eq!(
            report.plan_cache_hits,
            report.batches_dispatched - 1,
            "every post-warm-up dispatch reuses the cached plan"
        );
    }

    #[test]
    fn plan_allocations_scale_with_distinct_plans_not_load() {
        // Crash + recovery touches exactly two plans (healthy, degraded);
        // 8x the traffic must not add a single further allocation.
        let run = |n: usize| {
            let mut backends = vec![SyntheticBackend::uniform(4, 5.0, 1.0)];
            let mut failovers = vec![Failover::new(Objectives::default())];
            let reqs = generate(n, Arrival::Uniform { gap_ms: 1.0 }, 8, 23);
            serve(
                &mut backends,
                &StaticMetrics,
                &mut failovers,
                &cfg(2, RoutePolicy::RoundRobin),
                &reqs,
                &pool(),
                &[FailurePlan::crash_recover(3, 20.0, 60.0)],
            )
            .unwrap()
        };
        let small = run(50);
        let large = run(400);
        assert_eq!(small.failovers.len(), 1);
        assert_eq!(small.plan_cache_misses, 2, "healthy + degraded");
        assert_eq!(
            large.plan_cache_misses, small.plan_cache_misses,
            "plan allocations are per distinct plan, not per batch"
        );
        assert!(large.plan_cache_hits > small.plan_cache_hits);
    }

    #[test]
    fn streaming_mode_keeps_no_per_request_records() {
        let run = |record: bool| {
            let mut backends = vec![SyntheticBackend::uniform(4, 5.0, 1.0)];
            let mut failovers = vec![Failover::new(Objectives::default())];
            let reqs = generate(60, Arrival::Poisson { rate_rps: 300.0 }, 8, 31);
            let mut c = cfg(2, RoutePolicy::RoundRobin);
            c.record_completions = record;
            serve(
                &mut backends,
                &StaticMetrics,
                &mut failovers,
                &c,
                &reqs,
                &pool(),
                &[FailurePlan::crash_recover(2, 30.0, 50.0)],
            )
            .unwrap()
        };
        let on = run(true);
        let off = run(false);
        assert_eq!(on.completed.len(), on.completed_count);
        assert!(off.completed.is_empty(), "streaming keeps no Completion records");
        assert_eq!(off.completed_count, on.completed_count);
        // The streamed summary and counters are byte-identical to the
        // recording run's — recording only adds the per-request vector.
        assert_eq!(format!("{:?}", on.latency), format!("{:?}", off.latency));
        assert_eq!(on.throughput_rps, off.throughput_rps);
        assert_eq!(on.batches_dispatched, off.batches_dispatched);
        assert_eq!(on.events_processed, off.events_processed);
        assert_eq!(format!("{:?}", on.failovers), format!("{:?}", off.failovers));
    }

    #[test]
    fn failure_mid_flight_requeues_and_recovers() {
        // Single replica, failure while batches are pipelining through the
        // failed node: in-flight batches requeue and everything completes
        // under the degraded path.
        let mut backends = vec![SyntheticBackend::uniform(4, 5.0, 1.0)];
        let mut failovers = vec![Failover::new(Objectives::default())];
        let reqs = generate(20, Arrival::Uniform { gap_ms: 2.0 }, 8, 9);
        let report = serve(
            &mut backends,
            &StaticMetrics,
            &mut failovers,
            &cfg(3, RoutePolicy::RoundRobin),
            &reqs,
            &pool(),
            &[FailurePlan::crash(3, 12.0)],
        )
        .unwrap();
        assert_eq!(report.completed.len(), 20, "dropped={}", report.dropped.len());
        assert_eq!(report.failovers.len(), 1);
        let tech = report.failovers[0].technique;
        assert!(
            report
                .completed
                .iter()
                .filter(|c| c.technique.is_some())
                .all(|c| c.technique == Some(tech)),
            "degraded completions carry the chosen technique"
        );
    }

    // --- monitored-health scenarios (all deterministic: clean channel) ---

    use crate::health::DetectorKind;

    /// 12 requests every 40 ms on an idle pipeline: dispatch happens at
    /// arrival, so each completion's serving mode cleanly reflects the
    /// controller state at its arrival time.
    fn sparse_requests() -> Vec<Request> {
        generate(12, Arrival::Uniform { gap_ms: 40.0 }, 8, 21)
    }

    #[test]
    fn false_positive_failover_rolls_back() {
        // A monitoring-path blackout over [100, 160): the nodes keep
        // serving, but their heartbeats stop arriving — the detector
        // fails over healthy nodes (false positives) and the quarantine
        // gate rolls the path back once beats resume.
        let mut health = clean_channel(DetectorKind::FixedTimeout { timeout_ms: 25.0 }, 40.0);
        health.heartbeat.blackout = Some((100.0, 160.0));
        let mut backends = vec![SyntheticBackend::uniform(2, 5.0, 1.0)];
        let mut failovers = vec![Failover::new(Objectives::default())];
        let report = serve(
            &mut backends,
            &StaticMetrics,
            &mut failovers,
            &monitored(1, health),
            &sparse_requests(),
            &pool(),
            &[], // no ground-truth failures at all
        )
        .unwrap();

        // Both (healthy!) nodes got failed over at the 120 ms check.
        assert_eq!(report.failovers.len(), 2, "{:?}", report.failovers);
        assert_eq!(report.false_failovers(), 2);
        for w in &report.failovers {
            assert!(w.false_positive);
            assert!((w.start_ms - 120.0).abs() < 1e-9);
        }
        // Nothing was actually broken, so nothing is lost...
        assert_eq!(report.completed.len(), 12, "dropped={}", report.dropped.len());
        assert!(report.dropped.is_empty());
        for c in &report.completed {
            let arrival = 40.0 * (c.id + 1) as f64;
            // ...but traffic during the episode pays the degraded path,
            if (130.0..190.0).contains(&arrival) {
                assert!(c.technique.is_some(), "req {} must serve degraded", c.id);
            }
            // and the rollback (recovery at 200 ms) restores the full
            // pipeline.
            if arrival >= 240.0 {
                assert!(c.technique.is_none(), "req {} must be healthy again", c.id);
            }
        }
        assert!(
            report.completed.iter().any(|c| c.technique.is_some()),
            "the false positive must actually degrade some traffic"
        );
    }

    #[test]
    fn degraded_node_slows_stage_in_place_below_threshold() {
        // Node 2 runs 2x slower over [100, 400) — beats stretch to 20 ms
        // (under the 35 ms timeout) and the estimated slowdown stays
        // below the 3x failover threshold: no failover, just a slower
        // stage.
        let health = clean_channel(DetectorKind::FixedTimeout { timeout_ms: 35.0 }, 50.0);
        let mut backends = vec![SyntheticBackend::uniform(4, 5.0, 1.0)];
        let mut failovers = vec![Failover::new(Objectives::default())];
        let report = serve(
            &mut backends,
            &StaticMetrics,
            &mut failovers,
            &monitored(1, health),
            &sparse_requests(),
            &pool(),
            &[FailurePlan::degraded(2, 100.0, 2.0, 300.0)],
        )
        .unwrap();

        assert!(report.failovers.is_empty(), "{:?}", report.failovers);
        assert_eq!(report.completed.len(), 12);
        for c in &report.completed {
            let arrival = 40.0 * (c.id + 1) as f64;
            assert!(c.technique.is_none(), "never failed over");
            // Healthy path: 4x5 compute + 3x1 hops = 23 ms; with node 2
            // at 2x: 28 ms.
            if (110.0..360.0).contains(&arrival) {
                assert!(c.latency_ms > 26.0, "req {} slowed in place: {}", c.id, c.latency_ms);
            } else if !(100.0..420.0).contains(&arrival) {
                assert!(c.latency_ms < 25.0, "req {} full speed: {}", c.id, c.latency_ms);
            }
        }
    }

    #[test]
    fn flapping_node_quarantined_until_stable() {
        // Node 3 flaps: down 50-90, up 90-190, down 190-230, up after.
        // One failover at the 70 ms check; the mid-quarantine second
        // outage resets the stability clock silently; reintegration only
        // at 390 ms (beats resume at 240 + 150 ms quarantine).
        let health = clean_channel(DetectorKind::FixedTimeout { timeout_ms: 25.0 }, 150.0);
        let mut backends = vec![SyntheticBackend::uniform(4, 5.0, 1.0)];
        let mut failovers = vec![Failover::new(Objectives::default())];
        let report = serve(
            &mut backends,
            &StaticMetrics,
            &mut failovers,
            &monitored(2, health),
            &sparse_requests(),
            &pool(),
            &[FailurePlan::intermittent(3, 50.0, 40.0, 100.0, 2)],
        )
        .unwrap();

        assert_eq!(report.failovers.len(), 1, "flaps must not re-fail-over");
        let w = &report.failovers[0];
        assert!(!w.false_positive);
        assert!((w.start_ms - 70.0).abs() < 1e-9);
        assert_eq!(report.completed.len(), 12, "dropped={}", report.dropped.len());
        for c in &report.completed {
            let arrival = 40.0 * (c.id + 1) as f64;
            // The node is up over 90-190, but quarantine keeps the path
            // off it the whole time.
            if (100.0..360.0).contains(&arrival) {
                assert!(
                    c.technique.is_some(),
                    "req {} (t={arrival}) must stay on the degraded path through quarantine",
                    c.id
                );
            }
            if arrival >= 400.0 {
                assert!(c.technique.is_none(), "req {} healthy after reintegration", c.id);
            }
        }
    }

    #[test]
    fn lossy_channel_runs_are_reproducible() {
        let phi = DetectorKind::PhiAccrual {
            threshold: 5.0,
            window: 32,
            min_std_ms: 0.5,
        };
        let mut health = clean_channel(phi, 60.0);
        health.heartbeat.jitter_ms = 2.0;
        health.heartbeat.loss_prob = 0.2;
        let run = || {
            let mut backends = vec![
                SyntheticBackend::uniform(4, 5.0, 1.0),
                SyntheticBackend::uniform(4, 5.0, 1.0),
            ];
            let mut failovers = vec![
                Failover::new(Objectives::default()),
                Failover::new(Objectives::default()),
            ];
            let reqs = generate(30, Arrival::Poisson { rate_rps: 100.0 }, 8, 5);
            serve(
                &mut backends,
                &StaticMetrics,
                &mut failovers,
                &monitored(2, health.clone()),
                &reqs,
                &pool(),
                &[FailurePlan::crash_recover(2, 80.0, 120.0)],
            )
            .unwrap()
        };
        let a = format!("{:?}", run());
        let b = format!("{:?}", run());
        assert_eq!(a, b, "same-seed monitored runs must be byte-identical");
    }

    // --- sharded execution: same-seed equivalence + JSQ conservation ---

    /// Assert a merged sharded report matches the sequential reference:
    /// exact on every counter, histogram bucket and record, except
    /// mean/std (float accumulation order differs by a few ulps) and
    /// drop timestamps/modes — the sequential router prunes *every*
    /// replica's queue at each routed arrival while a shard prunes only
    /// at its own events, so expired requests are identical as a set of
    /// (id, replica, arrival) but can be logged at different times.
    fn assert_equivalent(seq: &ServiceReport, shard: &ServiceReport) {
        assert_eq!(seq.completed_count, shard.completed_count);
        assert_eq!(seq.batches_dispatched, shard.batches_dispatched);
        assert_eq!(seq.events_processed, shard.events_processed);
        assert_eq!(seq.max_in_flight, shard.max_in_flight);
        assert_eq!(seq.plan_cache_hits, shard.plan_cache_hits);
        assert_eq!(seq.plan_cache_misses, shard.plan_cache_misses);
        assert_eq!(seq.sim_span_ms, shard.sim_span_ms);
        // Histogram merge is exact: bucket for bucket.
        let (seq_low, seq_buckets) = seq.latency_stream.hist().buckets();
        let (sh_low, sh_buckets) = shard.latency_stream.hist().buckets();
        assert_eq!(seq_low, sh_low);
        assert_eq!(seq_buckets, sh_buckets, "histograms must match bucket-for-bucket");
        assert_eq!(seq.latency_stream.n(), shard.latency_stream.n());
        assert_eq!(seq.latency_stream.min(), shard.latency_stream.min());
        assert_eq!(seq.latency_stream.max(), shard.latency_stream.max());
        assert_eq!(seq.latency.p50, shard.latency.p50);
        assert_eq!(seq.latency.p95, shard.latency.p95);
        assert_eq!(seq.latency.p99, shard.latency.p99);
        let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1e-12);
        assert!(
            rel(shard.latency.mean, seq.latency.mean) < 1e-9,
            "mean {} vs {}",
            shard.latency.mean,
            seq.latency.mean
        );
        assert!(
            rel(shard.latency.std, seq.latency.std) < 1e-9,
            "std {} vs {}",
            shard.latency.std,
            seq.latency.std
        );
        // Failover windows: identical set (merge sorts by start time).
        let windows = |r: &ServiceReport| {
            let mut v: Vec<String> = r.failovers.iter().map(|w| format!("{w:?}")).collect();
            v.sort();
            v
        };
        assert_eq!(windows(seq), windows(shard));
        // Completions: identical records, order-independent.
        let completions = |r: &ServiceReport| {
            let mut v: Vec<String> = r.completed.iter().map(|c| format!("{c:?}")).collect();
            v.sort();
            v
        };
        assert_eq!(completions(seq), completions(shard));
        // Drops: identical (id, replica, arrival) set.
        let drops = |r: &ServiceReport| {
            let mut v: Vec<(usize, usize, u64)> = r
                .dropped
                .iter()
                .map(|d| (d.id, d.replica, d.arrival_ms.to_bits()))
                .collect();
            v.sort_unstable();
            v
        };
        assert_eq!(drops(seq), drops(shard));
    }

    fn equivalence_fixture() -> (Vec<SyntheticBackend>, Vec<Failover>, Vec<FailurePlan>) {
        let backends = vec![
            SyntheticBackend::uniform(4, 5.0, 1.0),
            SyntheticBackend::uniform(4, 5.0, 1.0),
        ];
        let failovers = vec![
            Failover::new(Objectives::default()),
            Failover::new(Objectives::default()),
        ];
        // Both plans land (and recover) while their replica still has
        // traffic in flight — the equivalence precondition the module
        // docs spell out.
        let plans = vec![
            FailurePlan::crash_recover(2, 40.0, 120.0),
            FailurePlan::crash_recover(3, 60.0, 140.0),
        ];
        (backends, failovers, plans)
    }

    #[test]
    fn sharded_rr_matches_sequential_bucket_for_bucket() {
        // Oversaturated (250 rps offered per replica vs the 200 rps
        // bottleneck) with a tight deadline: completions, drops and two
        // mid-stream failovers all in play.
        let reqs = generate(300, Arrival::Poisson { rate_rps: 500.0 }, 8, 71);
        let run = |execution: Execution| {
            let (mut backends, mut failovers, plans) = equivalence_fixture();
            let mut c = cfg(2, RoutePolicy::RoundRobin);
            c.deadline_ms = Some(100.0);
            c.execution = execution;
            serve(&mut backends, &StaticMetrics, &mut failovers, &c, &reqs, &pool(), &plans)
                .unwrap()
        };
        let seq = run(Execution::Sequential);
        assert!(seq.completed_count > 0);
        assert!(!seq.dropped.is_empty(), "deadline must bite for a meaningful test");
        assert_eq!(seq.failovers.len(), 2);
        // Worker count must not change results — shards multiplex.
        for workers in [1, 2, 4] {
            let shard = run(Execution::Sharded(workers));
            assert_equivalent(&seq, &shard);
        }
    }

    #[test]
    fn sharded_monitored_matches_sequential() {
        // Monitored health: each shard re-derives its replica's detection
        // stream from the global replica index and traffic horizon.
        let health = clean_channel(DetectorKind::FixedTimeout { timeout_ms: 25.0 }, 40.0);
        let reqs = generate(200, Arrival::Poisson { rate_rps: 400.0 }, 8, 29);
        let run = |execution: Execution| {
            let (mut backends, mut failovers, plans) = equivalence_fixture();
            let mut c = monitored(2, health.clone());
            c.execution = execution;
            serve(&mut backends, &StaticMetrics, &mut failovers, &c, &reqs, &pool(), &plans)
                .unwrap()
        };
        let seq = run(Execution::Sequential);
        assert_eq!(seq.failovers.len(), 2);
        assert_equivalent(&seq, &run(Execution::Sharded(2)));
    }

    #[test]
    fn routed_streams_sequential_and_sharded_agree() {
        // Pre-routed per-replica streams: both modes consume byte-identical
        // schedules, the strongest equivalence surface.
        let streams = crate::workload::generate_per_replica(
            120,
            Arrival::Poisson { rate_rps: 250.0 },
            8,
            83,
            2,
        );
        let run = |execution: Execution| {
            let (mut backends, mut failovers, plans) = equivalence_fixture();
            let mut c = cfg(2, RoutePolicy::RoundRobin);
            c.execution = execution;
            serve_routed(&mut backends, &StaticMetrics, &mut failovers, &c, &streams, &pool(), &plans)
                .unwrap()
        };
        let seq = run(Execution::Sequential);
        assert_eq!(seq.completed_count, 240, "no deadline: everything serves");
        assert_equivalent(&seq, &run(Execution::Sharded(2)));
    }

    #[test]
    fn sharded_jsq_conserves_and_completes() {
        // 3 replicas multiplexed onto 2 workers: the non-blocking feeder
        // must not deadlock even while one shard has no worker yet, and
        // every request must be served or dropped by exactly one shard.
        let mut backends: Vec<SyntheticBackend> =
            (0..3).map(|_| SyntheticBackend::uniform(4, 5.0, 1.0)).collect();
        let mut failovers: Vec<Failover> =
            (0..3).map(|_| Failover::new(Objectives::default())).collect();
        let reqs = generate(120, Arrival::Uniform { gap_ms: 1.0 }, 8, 37);
        let mut c = cfg(2, RoutePolicy::JoinShortestQueue);
        c.execution = Execution::Sharded(2);
        let report = serve(
            &mut backends,
            &StaticMetrics,
            &mut failovers,
            &c,
            &reqs,
            &pool(),
            &[FailurePlan::crash_recover(2, 20.0, 60.0)],
        )
        .unwrap();
        assert_eq!(report.completed_count + report.dropped.len(), 120, "conservation");
        let mut ids: Vec<usize> = report
            .completed
            .iter()
            .map(|c| c.id)
            .chain(report.dropped.iter().map(|d| d.id))
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..120).collect::<Vec<_>>(), "each request exactly once");
        assert!(report.dropped.is_empty(), "no deadline: nothing drops");
        assert_eq!(report.latency_stream.n(), 120);
        // A saturating stream spreads across all three shards.
        for r in 0..3 {
            assert!(
                report.completed.iter().any(|c| c.replica == r),
                "replica {r} served nothing"
            );
        }
    }

    #[test]
    fn sharded_zero_requests_is_empty_report() {
        let (mut backends, mut failovers, plans) = equivalence_fixture();
        let c = cfg(1, RoutePolicy::RoundRobin).sharded(2);
        let report =
            serve(&mut backends, &StaticMetrics, &mut failovers, &c, &[], &pool(), &plans)
                .unwrap();
        assert_eq!(report.completed_count, 0);
        assert!(report.dropped.is_empty());
        assert_eq!(report.latency_stream.n(), 0);
    }

    // --- heterogeneous fleets, weighted routing and work stealing ---

    #[test]
    fn speed_factor_scales_stage_times_in_place() {
        // Sparse arrivals on an idle pipeline: healthy path is 4x5 ms
        // compute + 3x1 ms hops = 23 ms. At 0.5x platform speed the
        // compute doubles (40 ms) but the hops don't: 43 ms.
        let run = |factors: Vec<f64>| {
            let mut backends = vec![SyntheticBackend::uniform(4, 5.0, 1.0)];
            let mut failovers = vec![Failover::new(Objectives::default())];
            let reqs = generate(5, Arrival::Uniform { gap_ms: 100.0 }, 8, 41);
            serve(
                &mut backends,
                &StaticMetrics,
                &mut failovers,
                &cfg(1, RoutePolicy::RoundRobin).with_speed_factors(factors),
                &reqs,
                &pool(),
                &[],
            )
            .unwrap()
        };
        let nominal = run(vec![]);
        let half = run(vec![0.5]);
        assert_eq!(nominal.completed.len(), 5);
        assert_eq!(half.completed.len(), 5);
        for c in &nominal.completed {
            assert!((c.latency_ms - 23.0).abs() < 1e-6, "nominal {}", c.latency_ms);
        }
        for c in &half.completed {
            assert!((c.latency_ms - 43.0).abs() < 1e-6, "half speed {}", c.latency_ms);
        }
    }

    #[test]
    fn weighted_rr_sequential_and_sharded_agree() {
        // Weighted round-robin is positional: the sharded split walks
        // the same smooth-WRR schedule as the sequential router, so the
        // full equivalence surface holds on a heterogeneous fleet.
        let reqs = generate(300, Arrival::Poisson { rate_rps: 500.0 }, 8, 47);
        let run = |execution: Execution| {
            let (mut backends, mut failovers, plans) = equivalence_fixture();
            let mut c = cfg(2, RoutePolicy::WeightedRoundRobin)
                .with_speed_factors(vec![1.5, 0.5]);
            c.deadline_ms = Some(100.0);
            c.execution = execution;
            serve(&mut backends, &StaticMetrics, &mut failovers, &c, &reqs, &pool(), &plans)
                .unwrap()
        };
        let seq = run(Execution::Sequential);
        assert!(seq.completed_count > 0);
        // The 3:1 weight split routes ~3/4 of arrivals to replica 0.
        let assigned0 = seq
            .completed
            .iter()
            .filter(|c| c.replica == 0)
            .count()
            + seq.dropped.iter().filter(|d| d.replica == 0).count();
        let total = seq.completed_count + seq.dropped.len();
        assert!(
            assigned0 * 10 >= total * 6,
            "fast replica got {assigned0}/{total}, expected ~3/4"
        );
        for workers in [1, 2] {
            let shard = run(Execution::Sharded(workers));
            assert_equivalent(&seq, &shard);
        }
    }

    #[test]
    fn sequential_stealing_rebalances_off_the_slow_replica() {
        // Round-robin over a 1.0x / 0.25x fleet: half the traffic lands
        // on a replica that serves a request in 83 ms instead of 23 ms.
        // Work stealing lets the fast replica pull the slow one's
        // backlog, so the run finishes far sooner and the fast replica
        // serves well over its round-robin half.
        let run = |steal: bool| {
            let mut backends = vec![
                SyntheticBackend::uniform(4, 5.0, 1.0),
                SyntheticBackend::uniform(4, 5.0, 1.0),
            ];
            let mut failovers = vec![
                Failover::new(Objectives::default()),
                Failover::new(Objectives::default()),
            ];
            let reqs = generate(60, Arrival::Uniform { gap_ms: 1.0 }, 8, 53);
            serve(
                &mut backends,
                &StaticMetrics,
                &mut failovers,
                &cfg(1, RoutePolicy::RoundRobin)
                    .with_speed_factors(vec![1.0, 0.25])
                    .stealing(steal),
                &reqs,
                &pool(),
                &[],
            )
            .unwrap()
        };
        let off = run(false);
        let on = run(true);
        assert_eq!(off.completed_count, 60);
        assert_eq!(on.completed_count, 60, "stealing must not lose requests");
        let served0 = |r: &ServiceReport| r.completed.iter().filter(|c| c.replica == 0).count();
        assert_eq!(served0(&off), 30, "round-robin halves without stealing");
        assert!(
            served0(&on) > 40,
            "the fast replica steals the slow one's backlog (served {})",
            served0(&on)
        );
        assert!(
            on.sim_span_ms < 0.6 * off.sim_span_ms,
            "stealing must shorten the run: {} vs {} ms",
            on.sim_span_ms,
            off.sim_span_ms
        );
        // Still a deterministic reference: same seed, same bytes.
        let again = run(true);
        assert_eq!(format!("{on:?}"), format!("{again:?}"));
    }

    #[test]
    fn sharded_weighted_jsq_with_stealing_conserves() {
        // Heterogeneous fleet, a mid-run crash, live weighted routing
        // AND stealing, multiplexed onto fewer workers than replicas:
        // every request is still served or dropped exactly once.
        let mut backends: Vec<SyntheticBackend> =
            (0..3).map(|_| SyntheticBackend::uniform(4, 5.0, 1.0)).collect();
        let mut failovers: Vec<Failover> =
            (0..3).map(|_| Failover::new(Objectives::default())).collect();
        let reqs = generate(150, Arrival::Uniform { gap_ms: 1.0 }, 8, 59);
        let mut c = cfg(2, RoutePolicy::WeightedJoinShortestQueue)
            .with_speed_factors(vec![1.0, 0.5, 1.5])
            .stealing(true);
        c.execution = Execution::Sharded(2);
        let report = serve(
            &mut backends,
            &StaticMetrics,
            &mut failovers,
            &c,
            &reqs,
            &pool(),
            &[FailurePlan::crash_recover(2, 20.0, 60.0)],
        )
        .unwrap();
        assert_eq!(report.completed_count + report.dropped.len(), 150, "conservation");
        let mut ids: Vec<usize> = report
            .completed
            .iter()
            .map(|c| c.id)
            .chain(report.dropped.iter().map(|d| d.id))
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..150).collect::<Vec<_>>(), "each request exactly once");
        assert!(report.dropped.is_empty(), "no deadline: nothing drops");
        for r in 0..3 {
            assert!(
                report.completed.iter().any(|c| c.replica == r),
                "replica {r} served nothing"
            );
        }
    }
}
