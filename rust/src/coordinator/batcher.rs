//! Dynamic batcher for the serving pipeline.
//!
//! The AOT artifacts are compiled at fixed batch sizes (manifest
//! `batch_sizes`, typically {1, 32}), so the batcher's job is to pick, for
//! the current queue depth and age, which compiled batch size to dispatch
//! — batch as aggressively as the queue allows without letting the head of
//! the queue exceed its timeout.

/// Batching policy configuration.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Batch sizes with compiled artifacts, ascending (e.g. [1, 32]).
    pub supported: Vec<usize>,
    /// Max time the queue head may wait for a bigger batch, ms.
    pub timeout_ms: f64,
    /// Upper bound on dispatch size (<= max supported).
    pub max_batch: usize,
}

impl BatcherConfig {
    pub fn new(mut supported: Vec<usize>, timeout_ms: f64, max_batch: usize) -> BatcherConfig {
        supported.sort_unstable();
        supported.dedup();
        assert!(!supported.is_empty(), "batcher needs >= 1 batch size");
        BatcherConfig {
            supported,
            timeout_ms,
            max_batch: max_batch.max(1),
        }
    }
}

/// A dispatch decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatch {
    /// Run the first `n` queued requests as one batch of compiled size `n`.
    Now(usize),
    /// Keep waiting (queue too small and head not timed out).
    Wait,
}

/// Decide what to dispatch given queue depth and the head's age.
///
/// Policy: take the largest supported size `<= min(queue_len, max_batch)`;
/// if none fits (queue smaller than the smallest supported size), dispatch
/// the smallest supported size anyway once the head is older than
/// `timeout_ms` **and** the queue has at least one request; otherwise wait
/// for more arrivals. Note the smallest supported size is typically 1, so
/// a timed-out head always goes out alone rather than waiting for a batch.
pub fn decide(cfg: &BatcherConfig, queue_len: usize, head_age_ms: f64) -> Dispatch {
    if queue_len == 0 {
        return Dispatch::Wait;
    }
    let cap = queue_len.min(cfg.max_batch);
    let fit = cfg.supported.iter().rev().find(|&&s| s <= cap).copied();
    match fit {
        Some(s) => {
            // A bigger batch exists and could still fill: wait unless the
            // head is timing out or nothing bigger is possible.
            let bigger_possible = cfg
                .supported
                .iter()
                .any(|&b| b > s && b <= cfg.max_batch);
            if bigger_possible && head_age_ms < cfg.timeout_ms {
                Dispatch::Wait
            } else {
                Dispatch::Now(s)
            }
        }
        None => {
            // queue smaller than smallest compiled batch
            if head_age_ms >= cfg.timeout_ms {
                Dispatch::Now(*cfg.supported.first().unwrap())
            } else {
                Dispatch::Wait
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> BatcherConfig {
        BatcherConfig::new(vec![1, 32], 2.0, 32)
    }

    #[test]
    fn empty_queue_waits() {
        assert_eq!(decide(&cfg(), 0, 100.0), Dispatch::Wait);
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        assert_eq!(decide(&cfg(), 32, 0.0), Dispatch::Now(32));
        assert_eq!(decide(&cfg(), 50, 0.0), Dispatch::Now(32));
    }

    #[test]
    fn small_queue_waits_until_timeout() {
        assert_eq!(decide(&cfg(), 3, 0.5), Dispatch::Wait);
        assert_eq!(decide(&cfg(), 3, 2.5), Dispatch::Now(1));
    }

    #[test]
    fn max_batch_caps_dispatch() {
        let c = BatcherConfig::new(vec![1, 32], 2.0, 8);
        // 32 not allowed (max 8); largest supported <= 8 is 1
        assert_eq!(decide(&c, 40, 0.0), Dispatch::Now(1));
    }

    #[test]
    fn single_size_always_dispatches() {
        let c = BatcherConfig::new(vec![1], 5.0, 4);
        assert_eq!(decide(&c, 3, 0.0), Dispatch::Now(1));
    }

    #[test]
    fn prop_dispatch_is_supported_and_fits() {
        use crate::util::proptest::{check, prop_assert};
        check(300, 77, |g| {
            let mut sizes = vec![1usize];
            if g.bool() {
                sizes.push(g.usize(2, 64));
            }
            let c = BatcherConfig::new(sizes, g.f64(0.1, 10.0), g.usize(1, 64));
            let qlen = g.usize(0, 100);
            let age = g.f64(0.0, 20.0);
            match decide(&c, qlen, age) {
                Dispatch::Wait => Ok(()),
                Dispatch::Now(n) => {
                    prop_assert(c.supported.contains(&n), "dispatch size must be compiled")?;
                    prop_assert(n <= qlen.max(1), "cannot dispatch more than queued")?;
                    prop_assert(n <= c.max_batch.max(1), "must respect max_batch")
                }
            }
        });
    }
}
