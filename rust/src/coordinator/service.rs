//! The serving loop: a deterministic discrete-event simulation that drives
//! the real pipeline (PJRT compute) under a request stream and a failure
//! plan, with the dynamic batcher and the failover controller in the loop.
//!
//! Time model: the simulation clock advances to each request arrival; a
//! dispatched batch occupies the pipeline for its *measured* wall-clock
//! compute time plus modeled network time (the cluster is a chain, one
//! batch in flight at a time — matching the paper's single-pipeline
//! deployment). Failure events interleave at their scheduled times; a
//! failover consumes real decision time plus the detector delay.

use anyhow::Result;

use crate::cluster::failure::{Detector, FailurePlan, NodeStatus};
use crate::cluster::sim::{steps_for, EdgeCluster};
use crate::dnn::variants::Technique;
use crate::runtime::HostTensor;
use crate::util::stats::Summary;
use crate::workload::Request;

use super::batcher::{decide, BatcherConfig, Dispatch};
use super::estimator::Estimator;
use super::failover::{Failover, Mode};

/// Per-request outcome.
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    pub id: usize,
    /// End-to-end latency including queueing, ms.
    pub latency_ms: f64,
    /// Which technique served it (None = healthy full pipeline).
    pub technique: Option<Technique>,
    pub batch_size: usize,
}

/// Aggregate report of one serving run.
#[derive(Debug, Clone)]
pub struct ServiceReport {
    pub completed: Vec<Completion>,
    pub dropped: usize,
    pub latency: Summary,
    pub throughput_rps: f64,
    /// Downtime windows: (start_ms, end_ms, technique chosen).
    pub failovers: Vec<(f64, f64, Technique)>,
    pub sim_span_ms: f64,
}

/// Serving-loop configuration.
pub struct ServiceConfig {
    pub batcher: BatcherConfig,
    pub detector: Detector,
    /// Drop requests that queue longer than this (None = never drop).
    pub deadline_ms: Option<f64>,
}

/// Run the service simulation.
pub fn run(
    cluster: &mut EdgeCluster,
    est: &Estimator,
    failover: &mut Failover,
    cfg: &ServiceConfig,
    requests: &[Request],
    inputs: &HostTensor, // pool of eval images [n, ...]
    plan: &FailurePlan,
) -> Result<ServiceReport> {
    let meta = cluster.meta;
    let mut completed = Vec::new();
    let mut dropped = 0usize;
    let mut failovers = Vec::new();

    let mut clock_ms = 0.0f64;
    let mut queue: Vec<Request> = Vec::new();
    let mut next_req = 0usize;
    let mut plan_cursor = 0usize;

    // Pending failure events become visible at detection time.
    let mut pending: Vec<(f64, usize, NodeStatus)> = plan
        .events
        .iter()
        .map(|e| {
            let t = match e.status {
                NodeStatus::Down => cfg.detector.detection_time(e.at_ms),
                NodeStatus::Up => e.at_ms,
            };
            (t, e.node, e.status)
        })
        .collect();
    pending.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    loop {
        // Apply raw failure events up to the clock (the node actually dies
        // at at_ms; detection lags).
        for e in plan.due(plan_cursor, clock_ms) {
            match e.status {
                NodeStatus::Down => cluster.fail(e.node),
                NodeStatus::Up => cluster.restore(e.node),
            }
            plan_cursor += 1;
        }
        // Handle detections due.
        while let Some(&(t, node, status)) = pending.first() {
            if t > clock_ms {
                break;
            }
            pending.remove(0);
            match status {
                NodeStatus::Down => {
                    let report = failover.on_failure(est, node)?;
                    failovers.push((t, t + report.downtime_ms(), report.decision.chosen));
                }
                NodeStatus::Up => failover.on_recovery(node),
            }
        }

        // Admit arrivals up to the clock.
        while next_req < requests.len() && requests[next_req].arrival_ms <= clock_ms {
            queue.push(requests[next_req]);
            next_req += 1;
        }

        // Drop timed-out requests.
        if let Some(deadline) = cfg.deadline_ms {
            let before = queue.len();
            queue.retain(|r| clock_ms - r.arrival_ms <= deadline);
            dropped += before - queue.len();
        }

        // Dispatch?
        let head_age = queue.first().map(|r| clock_ms - r.arrival_ms).unwrap_or(0.0);
        match decide(&cfg.batcher, queue.len(), head_age) {
            Dispatch::Now(n) => {
                let batch: Vec<Request> = queue.drain(..n.min(queue.len())).collect();
                let n = batch.len();
                // Build the input tensor for this batch.
                let rows: Vec<HostTensor> = batch
                    .iter()
                    .map(|r| inputs.slice0(r.input_idx, r.input_idx + 1))
                    .collect::<Result<_>>()?;
                let mut x = HostTensor::concat0(&rows)?;
                // Pad to the compiled batch size if needed.
                let target = cfg
                    .batcher
                    .supported
                    .iter()
                    .copied()
                    .find(|&s| s >= n)
                    .unwrap_or(n);
                while x.shape[0] < target {
                    let pad = x.slice0(0, 1)?;
                    x = HostTensor::concat0(&[x, pad])?;
                }
                let (technique, failed) = match failover.mode {
                    Mode::Healthy => (Technique::Repartition, None),
                    Mode::Degraded { failed, technique } => (technique, Some(failed)),
                };
                let steps = steps_for(meta, technique, failed);
                let (_, timing) = cluster.execute_steps(&steps, &x)?;
                let service_ms = timing.total_ms();
                clock_ms += service_ms;
                for r in &batch {
                    completed.push(Completion {
                        id: r.id,
                        latency_ms: clock_ms - r.arrival_ms,
                        technique: failover.technique(),
                        batch_size: target,
                    });
                }
            }
            Dispatch::Wait => {
                // Advance to the next event: arrival, detection, raw
                // failure, or batcher timeout.
                let mut next_t = f64::INFINITY;
                if next_req < requests.len() {
                    next_t = next_t.min(requests[next_req].arrival_ms);
                }
                if let Some(&(t, _, _)) = pending.first() {
                    next_t = next_t.min(t);
                }
                if plan_cursor < plan.events.len() {
                    next_t = next_t.min(plan.events[plan_cursor].at_ms);
                }
                if !queue.is_empty() {
                    next_t = next_t.min(clock_ms + (cfg.batcher.timeout_ms - head_age).max(0.0));
                }
                if next_t.is_infinite() {
                    break; // nothing left to do
                }
                clock_ms = next_t.max(clock_ms + 1e-9);
            }
        }

        if next_req >= requests.len() && queue.is_empty() {
            // flush remaining detections for reporting, then stop
            break;
        }
    }

    let latencies: Vec<f64> = completed.iter().map(|c| c.latency_ms).collect();
    let span = clock_ms.max(1e-9);
    Ok(ServiceReport {
        throughput_rps: completed.len() as f64 / (span / 1e3),
        latency: Summary::of(&latencies),
        completed,
        dropped,
        failovers,
        sim_span_ms: span,
    })
}
