//! Serving report types and the single-pipeline entry point.
//!
//! The actual serving loop lives in [`super::engine`]: an event-driven
//! simulation with stage-level pipelining and replica sharding. [`run`]
//! keeps the seed's single-pipeline signature — one cluster, one failover
//! controller, one failure plan — and drives it through the engine in a
//! 1-replica, non-pipelined configuration, so every seed experiment
//! driver produces the same serving regime as before the refactor.
//!
//! Time model (unchanged): the clock is virtual; a dispatched batch
//! occupies each pipeline stage for its *measured* wall-clock compute
//! time plus modeled network time. Failure events interleave at their
//! scheduled times; a failover consumes real decision time plus the
//! detector delay.

use anyhow::Result;

use crate::cluster::failure::{Detector, FailurePlan};
use crate::cluster::sim::EdgeCluster;
use crate::dnn::variants::Technique;
use crate::runtime::HostTensor;
use crate::util::histogram::Streaming;
use crate::util::stats::Summary;
use crate::workload::Request;

use super::batcher::BatcherConfig;
use super::engine::{serve_sequential, EngineConfig};
use super::estimator::Estimator;
use super::failover::Failover;

/// Per-request outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Completion {
    pub id: usize,
    /// Replica that served the request.
    pub replica: usize,
    /// End-to-end latency including queueing, ms.
    pub latency_ms: f64,
    /// Which technique served it (None = healthy full pipeline).
    pub technique: Option<Technique>,
    pub batch_size: usize,
}

/// A request dropped after exceeding its queueing deadline (or stranded on
/// a replica no recovery technique could salvage).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DroppedRequest {
    pub id: usize,
    pub replica: usize,
    /// When the request arrived, ms — lets experiments attribute drops to
    /// failure windows.
    pub arrival_ms: f64,
    /// When it was abandoned, ms.
    pub dropped_at_ms: f64,
    /// Serving mode of its replica at drop time (true = degraded).
    pub degraded: bool,
}

/// One failover: the downtime window and the technique chosen.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailoverWindow {
    pub replica: usize,
    /// The node the controller failed over away from.
    pub node: usize,
    pub start_ms: f64,
    pub end_ms: f64,
    pub technique: Technique,
    /// Ground truth at detection time: true when the suspected node was
    /// in fact healthy (an unnecessary failover the monitor later rolls
    /// back). Always false under oracle detection.
    pub false_positive: bool,
}

impl FailoverWindow {
    pub fn downtime_ms(&self) -> f64 {
        self.end_ms - self.start_ms
    }
}

/// How a repartition becomes live after the failover decision picks it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeployMode {
    /// The legacy model: the repartitioned plan is live the instant the
    /// decision lands — weight movement and warm-up are free. The
    /// engine's behaviour (and reports) are byte-identical to before the
    /// deployment model existed.
    Instantaneous,
    /// The new partition deploys while serving is stalled: requests
    /// queue (or expire against their deadlines) from the decision until
    /// the cut-over at the end of transfer + warm-up.
    BreakBeforeMake,
    /// The old pipeline keeps draining on the surviving nodes via a
    /// repartition-free fallback (early-exit or skip) while the new
    /// partition transfers and warms in the background; dispatch cuts
    /// over atomically when it is live. Nothing stalls, nothing
    /// requeues.
    MakeBeforeBreak,
}

impl DeployMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            DeployMode::Instantaneous => "instantaneous",
            DeployMode::BreakBeforeMake => "break-before-make",
            DeployMode::MakeBeforeBreak => "make-before-break",
        }
    }
}

/// One repartition deployment: the window between the failover decision
/// choosing repartition and that partition going live (or being
/// abandoned).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeployWindow {
    pub replica: usize,
    /// The failed node the deployment routes around.
    pub node: usize,
    pub mode: DeployMode,
    /// When the deployment began (the failover decision instant), ms.
    pub start_ms: f64,
    /// Slowest per-host weight transfer in the plan, ms.
    pub transfer_ms: f64,
    /// Warm-up each newly assigned host pays after its weights land, ms.
    pub warmup_ms: f64,
    /// When the new partition went live — or, for an abandoned
    /// deployment (`completed: false`), when it was cancelled.
    pub cutover_ms: f64,
    /// Technique that kept the replica serving through the window
    /// (make-before-break); `None` means dispatch stalled
    /// (break-before-make, or no repartition-free candidate existed).
    pub fallback: Option<Technique>,
    /// Whether the cut-over actually happened (false = superseded by a
    /// newer failure, or the failed node recovered first).
    pub completed: bool,
}

impl DeployWindow {
    /// Wall time from decision to cut-over (or abandonment).
    pub fn duration_ms(&self) -> f64 {
        self.cutover_ms - self.start_ms
    }

    /// How long dispatch was stalled by this deployment: its whole
    /// duration when no fallback served through it, zero otherwise.
    pub fn stalled_ms(&self) -> f64 {
        if self.fallback.is_none() {
            self.duration_ms()
        } else {
            0.0
        }
    }
}

/// Aggregate report of one serving run.
#[derive(Debug, Clone)]
pub struct ServiceReport {
    /// Exact per-request records — populated only when
    /// [`EngineConfig::record_completions`](super::engine::EngineConfig)
    /// is on; empty in the default streaming-metrics regime, where
    /// [`Self::completed_count`] and [`Self::latency`] carry the same
    /// information in O(1) memory.
    pub completed: Vec<Completion>,
    /// Requests served, counted whether or not records are kept.
    pub completed_count: usize,
    /// Every dropped request with its arrival time and serving mode (the
    /// seed kept only a bare counter).
    pub dropped: Vec<DroppedRequest>,
    /// Latency summary: mean/std/min/max exact (streamed online),
    /// percentiles from the log-bucketed histogram (within one bucket's
    /// relative error, 2%).
    pub latency: Summary,
    /// The raw streaming accumulator behind [`Self::latency`] (histogram
    /// buckets + Welford moments). Exposed so callers can merge reports
    /// across runs and so the sharded-equivalence tests can compare a
    /// merged sharded run against the sequential reference
    /// bucket-for-bucket.
    pub latency_stream: Streaming,
    pub throughput_rps: f64,
    pub failovers: Vec<FailoverWindow>,
    pub sim_span_ms: f64,
    /// Peak number of batches concurrently in flight on any one replica
    /// (1 in the seed-equivalent non-pipelined configuration).
    pub max_in_flight: usize,
    /// Total events popped off the queue — the denominator for the
    /// engine's events/sec and allocations-per-event numbers.
    pub events_processed: usize,
    /// Batches sent down a pipeline (each reused a cached step plan).
    pub batches_dispatched: usize,
    /// Step-plan lookups served from the per-replica caches without
    /// allocating.
    pub plan_cache_hits: usize,
    /// Step plans actually derived and allocated (one per distinct
    /// technique/failed-node pair per replica — the warm-up cost).
    pub plan_cache_misses: usize,
    /// Repartition deployments (empty under
    /// [`DeployMode::Instantaneous`], where repartition is a free swap).
    pub deploy_windows: Vec<DeployWindow>,
}

impl ServiceReport {
    pub fn dropped_count(&self) -> usize {
        self.dropped.len()
    }

    /// Drops that happened while the owning replica served degraded.
    pub fn degraded_drops(&self) -> usize {
        self.dropped.iter().filter(|d| d.degraded).count()
    }

    /// Failovers triggered on nodes that were in fact healthy (the
    /// monitor's false positives; always 0 under oracle detection).
    pub fn false_failovers(&self) -> usize {
        self.failovers.iter().filter(|w| w.false_positive).count()
    }

    /// Total decision downtime across all failover windows, ms.
    pub fn total_downtime_ms(&self) -> f64 {
        self.failovers.iter().map(|w| w.downtime_ms()).sum()
    }

    /// Dispatch time stalled by break-before-make deployments, ms
    /// (zero under make-before-break with a feasible fallback — the
    /// headline the deployment model exists to show).
    pub fn deploy_stall_ms(&self) -> f64 {
        self.deploy_windows.iter().map(|w| w.stalled_ms()).sum()
    }

    /// Downtime attributed per technique: each failover window's
    /// decision downtime under its chosen technique's name, plus
    /// deployment stalls (which only repartition incurs) under
    /// `"repartition"`.
    pub fn downtime_by_technique(&self) -> std::collections::BTreeMap<&'static str, f64> {
        let mut by_tech: std::collections::BTreeMap<&'static str, f64> =
            std::collections::BTreeMap::new();
        for w in &self.failovers {
            *by_tech.entry(w.technique.kind_name()).or_insert(0.0) += w.downtime_ms();
        }
        let stall = self.deploy_stall_ms();
        if stall > 0.0 {
            *by_tech.entry("repartition").or_insert(0.0) += stall;
        }
        by_tech
    }
}

/// Single-pipeline serving configuration (the seed's shape).
pub struct ServiceConfig {
    pub batcher: BatcherConfig,
    pub detector: Detector,
    /// Drop requests that queue longer than this (None = never drop).
    pub deadline_ms: Option<f64>,
}

impl ServiceConfig {
    /// The engine configuration this maps to: 1 replica, no pipelining.
    pub fn engine_config(&self) -> EngineConfig {
        EngineConfig::sequential(self.batcher.clone(), self.detector.clone(), self.deadline_ms)
    }
}

/// Run the service simulation on a single pipeline (seed-compatible
/// entry point; multi-replica / pipelined serving goes through
/// [`super::engine::serve`] directly). Uses the sequential engine
/// unconditionally: the PJRT cluster and the estimator hold host-side
/// caches behind `RefCell` and cannot cross threads.
pub fn run(
    cluster: &mut EdgeCluster,
    est: &Estimator,
    failover: &mut Failover,
    cfg: &ServiceConfig,
    requests: &[Request],
    inputs: &HostTensor, // pool of eval images [n, ...]
    plan: &FailurePlan,
) -> Result<ServiceReport> {
    serve_sequential(
        std::slice::from_mut(cluster),
        est,
        std::slice::from_mut(failover),
        &cfg.engine_config(),
        requests,
        inputs,
        std::slice::from_ref(plan),
    )
}
