//! Request router across pipeline replicas. Each replica is an
//! independent copy of the distributed pipeline (own cluster state, own
//! failover controller); the router decides, per arriving request, which
//! replica's queue it joins.

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through replicas in index order.
    RoundRobin,
    /// Send each request to the replica with the fewest outstanding
    /// requests (queued + in flight); ties go to the lowest index.
    JoinShortestQueue,
}

/// Snapshot of one replica's load, as seen by the router at an arrival.
#[derive(Debug, Clone, Copy, Default)]
pub struct ReplicaLoad {
    /// Requests waiting in the replica's queue.
    pub queued: usize,
    /// Requests inside batches currently moving through the pipeline.
    pub in_flight: usize,
}

impl ReplicaLoad {
    pub fn total(&self) -> usize {
        self.queued + self.in_flight
    }
}

/// Stateful router (round-robin keeps a cursor).
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    next_rr: usize,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Router {
        Router { policy, next_rr: 0 }
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Pick the replica for the next request.
    pub fn route(&mut self, loads: &[ReplicaLoad]) -> usize {
        assert!(!loads.is_empty(), "router needs >= 1 replica");
        match self.policy {
            RoutePolicy::RoundRobin => {
                let r = self.next_rr % loads.len();
                self.next_rr = self.next_rr.wrapping_add(1);
                r
            }
            RoutePolicy::JoinShortestQueue => loads
                .iter()
                .enumerate()
                .min_by_key(|(i, l)| (l.total(), *i))
                .map(|(i, _)| i)
                .unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads(ls: &[(usize, usize)]) -> Vec<ReplicaLoad> {
        ls.iter()
            .map(|&(queued, in_flight)| ReplicaLoad { queued, in_flight })
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        let l = loads(&[(0, 0), (9, 9), (0, 0)]);
        assert_eq!(r.route(&l), 0);
        assert_eq!(r.route(&l), 1);
        assert_eq!(r.route(&l), 2);
        assert_eq!(r.route(&l), 0);
    }

    #[test]
    fn jsq_picks_least_loaded() {
        let mut r = Router::new(RoutePolicy::JoinShortestQueue);
        assert_eq!(r.route(&loads(&[(3, 1), (0, 2), (4, 0)])), 1);
        // counts queued + in-flight, not just queued
        assert_eq!(r.route(&loads(&[(0, 5), (2, 1), (1, 1)])), 2);
    }

    #[test]
    fn jsq_breaks_ties_low_index() {
        let mut r = Router::new(RoutePolicy::JoinShortestQueue);
        assert_eq!(r.route(&loads(&[(1, 1), (2, 0), (0, 2)])), 0);
    }
}
