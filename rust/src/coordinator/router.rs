//! Request router across pipeline replicas. Each replica is an
//! independent copy of the distributed pipeline (own cluster state, own
//! failover controller); the router decides, per arriving request, which
//! replica's queue it joins.
//!
//! Two routers live here:
//!
//! - [`Router`] is the sequential engine's: it reads exact per-replica
//!   load snapshots (and, for the weighted policies, exact effective
//!   speeds) at each arrival, inside the one event loop.
//! - [`ShardRouter`] is the sharded engine's arrival feeder: replicas run
//!   on worker threads, so exact queue lengths are not observable from
//!   the feeder. Round-robin needs no load at all (requests are routed
//!   positionally — at generation time), join-shortest-queue routes on
//!   per-replica [`AtomicUsize`] outstanding counters that the feeder
//!   increments at enqueue and each shard decrements at completion or
//!   drop, and the speed-weighted variant additionally reads a
//!   per-replica [`AtomicU32`] *effective speed* estimate (milli-units)
//!   that each shard publishes when it observes its own condition
//!   change — a replica that goes `Degraded(3.0)` starts shedding load
//!   the moment its shard sees the raw condition flip, long before any
//!   failover threshold trips.
//!
//! # Heterogeneous fleets
//!
//! A fleet where replica platforms differ (a 0.5× edge box next to a
//! 1.5× server) breaks the implicit assumption behind both round-robin
//! and plain JSQ: that equal backlog means equal drain time. The
//! weighted policies fix that:
//!
//! - [`RoutePolicy::WeightedRoundRobin`] interleaves replicas
//!   proportionally to their static speed factors using the smooth
//!   weighted round-robin scheme ([`WrrState`]) — deterministic and
//!   load-oblivious, so the sharded engine can still pre-split the
//!   arrival stream positionally and stay byte-equivalent to the
//!   sequential reference.
//! - [`RoutePolicy::WeightedJoinShortestQueue`] ranks replicas by
//!   *expected drain time* — `outstanding / effective_speed` — where
//!   effective speed folds the replica's detected condition into its
//!   static speed factor. A degraded replica looks slower, not shorter,
//!   and sheds load immediately.

use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::Arc;

/// Fixed-point scale for the shard-published effective-speed estimate:
/// an [`AtomicU32`] holds `speed * 1000` (1.0× = 1000 milli-units).
pub const SPEED_MILLI: f64 = 1000.0;

/// Pads (and aligns) a value to its own 64-byte cache line so two
/// replicas' hot atomic cells never share one. Without this, the
/// per-replica [`AtomicUsize`] counters allocate a few bytes apart and
/// every shard's decrement invalidates the line the feeder — and every
/// *other* shard — is hammering: classic false sharing. `Deref` keeps
/// call sites (`cell.load(..)`, `cell.fetch_sub(..)`) unchanged.
///
/// 64 bytes covers x86-64 and most aarch64 parts; on CPUs with larger
/// lines this merely under-pads — correctness never depends on it.
#[derive(Debug, Default)]
#[repr(align(64))]
pub struct CachePadded<T>(pub T);

impl<T> CachePadded<T> {
    pub const fn new(value: T) -> CachePadded<T> {
        CachePadded(value)
    }
}

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.0
    }
}

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through replicas in index order.
    RoundRobin,
    /// Send each request to the replica with the fewest outstanding
    /// requests (queued + in flight). The sequential router breaks ties
    /// toward the lowest index; the sharded router rotates a cursor
    /// through ties so equal counters don't hot-spot replica 0.
    JoinShortestQueue,
    /// Interleave replicas proportionally to their static speed factors
    /// (smooth weighted round-robin). Deterministic and positional, so
    /// sharded runs pre-split the stream and stay merge-equivalent to
    /// the sequential reference.
    WeightedRoundRobin,
    /// Rank replicas by expected drain time `outstanding /
    /// effective_speed`, where effective speed is the replica's static
    /// speed factor divided by its currently observed worst node
    /// slowdown. Degraded replicas shed load before failover trips.
    WeightedJoinShortestQueue,
}

impl RoutePolicy {
    /// Whether sharded execution can route this policy positionally at
    /// generation time (pre-split streams, deterministic and
    /// merge-equivalent to the sequential run). The JSQ family routes
    /// live over atomic counters instead.
    pub fn is_positional(&self) -> bool {
        matches!(self, RoutePolicy::RoundRobin | RoutePolicy::WeightedRoundRobin)
    }
}

/// Snapshot of one replica's load, as seen by the router at an arrival.
#[derive(Debug, Clone, Copy, Default)]
pub struct ReplicaLoad {
    /// Requests waiting in the replica's queue.
    pub queued: usize,
    /// Requests inside batches currently moving through the pipeline.
    pub in_flight: usize,
}

impl ReplicaLoad {
    pub fn total(&self) -> usize {
        self.queued + self.in_flight
    }
}

/// Smooth weighted round-robin state (the nginx scheme): each pick adds
/// every replica's weight to its running credit, takes the replica with
/// the most credit (ties to the lowest index), and subtracts the weight
/// total from the winner. Produces a smooth proportional interleave —
/// `[2, 1, 1]` yields `0 1 2 0` repeating — and with equal weights
/// degenerates to plain round-robin.
///
/// Shared by [`Router`], [`ShardRouter`] and the sharded engine's
/// positional stream split so all three produce the *same* schedule for
/// the same weights — the weighted equivalence contract depends on it.
#[derive(Debug, Clone)]
pub struct WrrState {
    weights: Vec<f64>,
    credit: Vec<f64>,
    total: f64,
}

impl WrrState {
    /// Weights are clamped to a small positive floor so a zero or
    /// negative factor cannot wedge the schedule.
    pub fn new(weights: &[f64]) -> WrrState {
        assert!(!weights.is_empty(), "WRR needs >= 1 replica");
        let weights: Vec<f64> = weights.iter().map(|w| w.max(1e-6)).collect();
        let total = weights.iter().sum();
        WrrState {
            credit: vec![0.0; weights.len()],
            weights,
            total,
        }
    }

    /// Uniform weights over `n` replicas (degenerates to round-robin).
    pub fn uniform(n: usize) -> WrrState {
        WrrState::new(&vec![1.0; n])
    }

    /// Pick the next replica in the weighted interleave.
    pub fn next(&mut self) -> usize {
        let mut best = 0;
        for i in 0..self.weights.len() {
            self.credit[i] += self.weights[i];
            if self.credit[i] > self.credit[best] + 1e-12 {
                best = i;
            }
        }
        self.credit[best] -= self.total;
        best
    }
}

/// Stateful router for the sequential engine (round-robin keeps a
/// cursor; the weighted policies keep smooth-WRR credit). The weighted
/// variants are built with [`Router::with_speeds`]; the plain ones
/// treat every replica as 1.0×.
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    next_rr: usize,
    /// Static per-replica speed factors (padded with 1.0 on demand).
    speeds: Vec<f64>,
    /// Lazily initialised when the replica count is first observed.
    wrr: Option<WrrState>,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Router {
        Router::with_speeds(policy, &[])
    }

    /// A router that knows the fleet's static speed factors. Shorter
    /// than the replica count pads with 1.0; extra entries are ignored.
    pub fn with_speeds(policy: RoutePolicy, speed_factors: &[f64]) -> Router {
        Router {
            policy,
            next_rr: 0,
            speeds: speed_factors.to_vec(),
            wrr: None,
        }
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    fn static_speed(&self, r: usize) -> f64 {
        self.speeds.get(r).copied().unwrap_or(1.0)
    }

    /// Pick the replica for the next request. `eff_speeds` is the
    /// per-replica *effective* speed (static factor over observed
    /// condition slowdown) and is only consulted by
    /// [`RoutePolicy::WeightedJoinShortestQueue`]; shorter slices pad
    /// with the static factor.
    pub fn route(&mut self, loads: &[ReplicaLoad], eff_speeds: &[f64]) -> usize {
        assert!(!loads.is_empty(), "router needs >= 1 replica");
        match self.policy {
            RoutePolicy::RoundRobin => {
                let r = self.next_rr % loads.len();
                self.next_rr = self.next_rr.wrapping_add(1);
                r
            }
            RoutePolicy::JoinShortestQueue => loads
                .iter()
                .enumerate()
                .min_by_key(|(i, l)| (l.total(), *i))
                .map(|(i, _)| i)
                .unwrap(),
            RoutePolicy::WeightedRoundRobin => {
                let n = loads.len();
                let wrr = self.wrr.get_or_insert_with(|| {
                    let w: Vec<f64> = (0..n)
                        .map(|r| self.speeds.get(r).copied().unwrap_or(1.0))
                        .collect();
                    WrrState::new(&w)
                });
                wrr.next()
            }
            RoutePolicy::WeightedJoinShortestQueue => {
                // Expected drain time: outstanding work over effective
                // speed. Ties go to the lowest index — the sequential
                // engine's determinism contract.
                let mut best = 0;
                let mut best_key = f64::INFINITY;
                for (i, l) in loads.iter().enumerate() {
                    let speed = eff_speeds
                        .get(i)
                        .copied()
                        .unwrap_or_else(|| self.static_speed(i))
                        .max(1e-6);
                    let key = l.total() as f64 / speed;
                    if key < best_key - 1e-12 {
                        best = i;
                        best_key = key;
                    }
                }
                best
            }
        }
    }
}

/// Live router for the sharded engine's arrival feeder: tracks each
/// replica's outstanding requests (enqueued but not yet completed or
/// dropped) in an atomic counter the shard's thread decrements, and —
/// for the speed-weighted policy — an effective-speed estimate each
/// shard publishes when its replica's condition changes.
///
/// Round-robin and weighted round-robin through this router reproduce
/// the sequential router's positional assignment exactly; the JSQ
/// family is a heuristic over racy counter reads and is therefore *not*
/// part of the sequential-vs-sharded determinism contract (conservation
/// still holds — every routed request is served or dropped by exactly
/// one shard). JSQ ties rotate a cursor across the tied replicas so
/// equal counters (the whole fleet, at low load) don't pile every
/// request onto replica 0.
#[derive(Debug)]
pub struct ShardRouter {
    policy: RoutePolicy,
    next_rr: usize,
    /// One cache line per replica ([`CachePadded`]): the feeder's scan
    /// of replica `i` must not stall on replica `j`'s shard retiring a
    /// request into an adjacent counter.
    outstanding: Vec<Arc<CachePadded<AtomicUsize>>>,
    /// Milli-units ([`SPEED_MILLI`]): 1000 = 1.0×. Initialised from the
    /// static speed factors; shards overwrite with condition-adjusted
    /// estimates as they observe degradations.
    speeds: Vec<Arc<CachePadded<AtomicU32>>>,
    wrr: WrrState,
}

impl ShardRouter {
    pub fn new(policy: RoutePolicy, replicas: usize) -> ShardRouter {
        ShardRouter::with_speeds(policy, &vec![1.0; replicas])
    }

    /// A feeder router over a heterogeneous fleet: one static speed
    /// factor per replica (also the initial published estimate).
    pub fn with_speeds(policy: RoutePolicy, speed_factors: &[f64]) -> ShardRouter {
        assert!(!speed_factors.is_empty(), "router needs >= 1 replica");
        ShardRouter {
            policy,
            next_rr: 0,
            outstanding: speed_factors
                .iter()
                .map(|_| Arc::new(CachePadded::new(AtomicUsize::new(0))))
                .collect(),
            speeds: speed_factors
                .iter()
                .map(|s| {
                    Arc::new(CachePadded::new(AtomicU32::new(
                        (s.max(1e-6) * SPEED_MILLI) as u32,
                    )))
                })
                .collect(),
            wrr: WrrState::new(speed_factors),
        }
    }

    /// Replica `r`'s outstanding counter, to hand to its shard (which
    /// decrements it once per completion or drop).
    pub fn counter(&self, r: usize) -> Arc<CachePadded<AtomicUsize>> {
        Arc::clone(&self.outstanding[r])
    }

    /// Replica `r`'s published effective-speed cell (milli-units), to
    /// hand to its shard — the shard stores `static_factor /
    /// worst_observed_slowdown` whenever a raw condition flips, and the
    /// weighted feeder reads it on every route.
    pub fn speed_cell(&self, r: usize) -> Arc<CachePadded<AtomicU32>> {
        Arc::clone(&self.speeds[r])
    }

    /// Route one arrival and charge the chosen replica's counter.
    pub fn route(&mut self) -> usize {
        let n = self.outstanding.len();
        let r = match self.policy {
            RoutePolicy::RoundRobin => {
                let r = self.next_rr % n;
                self.next_rr = self.next_rr.wrapping_add(1);
                r
            }
            RoutePolicy::WeightedRoundRobin => self.wrr.next(),
            RoutePolicy::JoinShortestQueue | RoutePolicy::WeightedJoinShortestQueue => {
                let weighted = self.policy == RoutePolicy::WeightedJoinShortestQueue;
                // Rotating tie cursor: scan from next_rr so exact key
                // ties spread across the fleet instead of hot-spotting
                // the lowest index at low load.
                let start = self.next_rr % n;
                self.next_rr = self.next_rr.wrapping_add(1);
                let mut best = start;
                let mut best_key = f64::INFINITY;
                for k in 0..n {
                    let i = (start + k) % n;
                    // Relaxed: a momentarily stale count mis-ranks one
                    // arrival, never loses one — request hand-off to the
                    // shard synchronizes through the mpsc channel, and
                    // conservation is property-tested independently.
                    let out = self.outstanding[i].load(Ordering::Relaxed) as f64;
                    let key = if weighted {
                        // Relaxed: advisory estimate; reading the
                        // pre-degradation speed routes suboptimally for
                        // a few arrivals, not incorrectly.
                        let milli = self.speeds[i].load(Ordering::Relaxed).max(1);
                        out / (milli as f64 / SPEED_MILLI)
                    } else {
                        out
                    };
                    if key < best_key - 1e-12 {
                        best = i;
                        best_key = key;
                    }
                }
                best
            }
        };
        // Relaxed: the charge only needs to be *eventually* visible to
        // the feeder's own later scans (same thread — program order) and
        // the shard's decrement (balanced via fetch_sub; the counter is
        // a routing hint, not the conservation ledger).
        self.outstanding[r].fetch_add(1, Ordering::Relaxed);
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads(ls: &[(usize, usize)]) -> Vec<ReplicaLoad> {
        ls.iter()
            .map(|&(queued, in_flight)| ReplicaLoad { queued, in_flight })
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        let l = loads(&[(0, 0), (9, 9), (0, 0)]);
        assert_eq!(r.route(&l, &[]), 0);
        assert_eq!(r.route(&l, &[]), 1);
        assert_eq!(r.route(&l, &[]), 2);
        assert_eq!(r.route(&l, &[]), 0);
    }

    #[test]
    fn jsq_picks_least_loaded() {
        let mut r = Router::new(RoutePolicy::JoinShortestQueue);
        assert_eq!(r.route(&loads(&[(3, 1), (0, 2), (4, 0)]), &[]), 1);
        // counts queued + in-flight, not just queued
        assert_eq!(r.route(&loads(&[(0, 5), (2, 1), (1, 1)]), &[]), 2);
    }

    #[test]
    fn jsq_breaks_ties_low_index() {
        let mut r = Router::new(RoutePolicy::JoinShortestQueue);
        assert_eq!(r.route(&loads(&[(1, 1), (2, 0), (0, 2)]), &[]), 0);
    }

    #[test]
    fn wrr_interleaves_proportionally_to_speed() {
        // 2:1:1 → the fast replica takes half the slots; over any full
        // cycle each replica's share matches its weight.
        let mut r = Router::with_speeds(RoutePolicy::WeightedRoundRobin, &[2.0, 1.0, 1.0]);
        let l = loads(&[(0, 0), (0, 0), (0, 0)]);
        let picks: Vec<usize> = (0..8).map(|_| r.route(&l, &[])).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 0, 1, 2, 0]);
        assert_eq!(picks.iter().filter(|&&p| p == 0).count(), 4);
    }

    #[test]
    fn wrr_with_uniform_speeds_is_round_robin() {
        let mut wrr = Router::with_speeds(RoutePolicy::WeightedRoundRobin, &[1.0, 1.0, 1.0]);
        let l = loads(&[(0, 0), (0, 0), (0, 0)]);
        let picks: Vec<usize> = (0..6).map(|_| wrr.route(&l, &[])).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn weighted_jsq_ranks_by_drain_time_not_count() {
        let mut r = Router::with_speeds(
            RoutePolicy::WeightedJoinShortestQueue,
            &[1.0, 2.0],
        );
        // Equal counts: the 2× replica drains in half the time.
        assert_eq!(r.route(&loads(&[(4, 0), (4, 0)]), &[1.0, 2.0]), 1);
        // The fast replica keeps winning until its backlog is twice as
        // deep (8/2 = 4/1), where the low-index tie-break reverts to 0.
        assert_eq!(r.route(&loads(&[(4, 0), (7, 0)]), &[1.0, 2.0]), 1);
        assert_eq!(r.route(&loads(&[(4, 0), (8, 0)]), &[1.0, 2.0]), 0);
    }

    #[test]
    fn weighted_jsq_sheds_load_off_degraded_replica() {
        let mut r = Router::with_speeds(
            RoutePolicy::WeightedJoinShortestQueue,
            &[1.0, 1.0],
        );
        // Same static speed, same backlog — but replica 0's effective
        // speed collapsed to 1/3 (a detected Degraded(3.0) condition).
        assert_eq!(
            r.route(&loads(&[(3, 0), (3, 0)]), &[1.0 / 3.0, 1.0]),
            1,
            "the degraded replica must shed load before failover trips"
        );
    }

    #[test]
    fn shard_router_rr_matches_positional_assignment() {
        let mut r = ShardRouter::new(RoutePolicy::RoundRobin, 3);
        let picks: Vec<usize> = (0..7).map(|_| r.route()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn shard_router_jsq_follows_outstanding_counters() {
        let mut r = ShardRouter::new(RoutePolicy::JoinShortestQueue, 3);
        // All zero: the rotating cursor spreads the first wave.
        assert_eq!(r.route(), 0);
        assert_eq!(r.route(), 1);
        assert_eq!(r.route(), 2);
        assert_eq!(r.counter(0).load(Ordering::Relaxed), 1);
        // A shard drains replica 1: it becomes the shortest queue.
        r.counter(1).fetch_sub(1, Ordering::Relaxed);
        assert_eq!(r.route(), 1);
        assert_eq!(r.counter(1).load(Ordering::Relaxed), 1);
    }

    #[test]
    fn shard_router_jsq_ties_rotate_instead_of_hotspotting() {
        // Zero load throughout (counters drained after every route):
        // the old lowest-index tie-break sent *every* request to
        // replica 0; the rotating cursor must cycle the fleet.
        let mut r = ShardRouter::new(RoutePolicy::JoinShortestQueue, 4);
        let mut picks = Vec::new();
        for _ in 0..8 {
            let p = r.route();
            picks.push(p);
            r.counter(p).fetch_sub(1, Ordering::Relaxed); // served instantly
        }
        assert_eq!(picks, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn shard_router_weighted_jsq_reads_published_speed() {
        let mut r = ShardRouter::with_speeds(
            RoutePolicy::WeightedJoinShortestQueue,
            &[1.0, 1.0],
        );
        // Equal backlogs...
        r.counter(0).fetch_add(3, Ordering::Relaxed);
        r.counter(1).fetch_add(3, Ordering::Relaxed);
        // ...but replica 0's shard published a 3× degradation.
        r.speed_cell(0)
            .store((1.0 / 3.0 * SPEED_MILLI) as u32, Ordering::Relaxed);
        assert_eq!(r.route(), 1, "drain time 9 vs 3: the healthy replica wins");
        // Replica 0 only wins again once replica 1's drain looks worse:
        // 3/0.333 = 9 < 10/1.
        for _ in 0..6 {
            r.counter(1).fetch_add(1, Ordering::Relaxed);
        }
        assert_eq!(r.route(), 0);
    }

    #[test]
    fn shard_router_wrr_matches_sequential_wrr_schedule() {
        let speeds = [1.5, 0.5, 1.0];
        let mut seq = Router::with_speeds(RoutePolicy::WeightedRoundRobin, &speeds);
        let mut shard = ShardRouter::with_speeds(RoutePolicy::WeightedRoundRobin, &speeds);
        let l = loads(&[(0, 0), (0, 0), (0, 0)]);
        for i in 0..24 {
            assert_eq!(seq.route(&l, &[]), shard.route(), "pick {i} diverged");
        }
    }
}
