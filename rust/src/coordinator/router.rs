//! Request router across pipeline replicas. Each replica is an
//! independent copy of the distributed pipeline (own cluster state, own
//! failover controller); the router decides, per arriving request, which
//! replica's queue it joins.
//!
//! Two routers live here:
//!
//! - [`Router`] is the sequential engine's: it reads exact per-replica
//!   load snapshots at each arrival, inside the one event loop.
//! - [`ShardRouter`] is the sharded engine's arrival feeder: replicas run
//!   on worker threads, so exact queue lengths are not observable from
//!   the feeder. Round-robin needs no load at all (requests are routed
//!   positionally — at generation time), and join-shortest-queue routes
//!   on per-replica [`AtomicUsize`] outstanding counters that the feeder
//!   increments at enqueue and each shard decrements at completion or
//!   drop.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through replicas in index order.
    RoundRobin,
    /// Send each request to the replica with the fewest outstanding
    /// requests (queued + in flight); ties go to the lowest index.
    JoinShortestQueue,
}

/// Snapshot of one replica's load, as seen by the router at an arrival.
#[derive(Debug, Clone, Copy, Default)]
pub struct ReplicaLoad {
    /// Requests waiting in the replica's queue.
    pub queued: usize,
    /// Requests inside batches currently moving through the pipeline.
    pub in_flight: usize,
}

impl ReplicaLoad {
    pub fn total(&self) -> usize {
        self.queued + self.in_flight
    }
}

/// Stateful router (round-robin keeps a cursor).
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    next_rr: usize,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Router {
        Router { policy, next_rr: 0 }
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Pick the replica for the next request.
    pub fn route(&mut self, loads: &[ReplicaLoad]) -> usize {
        assert!(!loads.is_empty(), "router needs >= 1 replica");
        match self.policy {
            RoutePolicy::RoundRobin => {
                let r = self.next_rr % loads.len();
                self.next_rr = self.next_rr.wrapping_add(1);
                r
            }
            RoutePolicy::JoinShortestQueue => loads
                .iter()
                .enumerate()
                .min_by_key(|(i, l)| (l.total(), *i))
                .map(|(i, _)| i)
                .unwrap(),
        }
    }
}

/// Live router for the sharded engine's arrival feeder: tracks each
/// replica's outstanding requests (enqueued but not yet completed or
/// dropped) in an atomic counter the shard's thread decrements.
///
/// Round-robin through this router reproduces the sequential router's
/// positional assignment exactly; join-shortest-queue is a heuristic over
/// racy counter reads and is therefore *not* part of the sequential-vs-
/// sharded determinism contract (conservation still holds — every routed
/// request is served or dropped by exactly one shard).
#[derive(Debug)]
pub struct ShardRouter {
    policy: RoutePolicy,
    next_rr: usize,
    outstanding: Vec<Arc<AtomicUsize>>,
}

impl ShardRouter {
    pub fn new(policy: RoutePolicy, replicas: usize) -> ShardRouter {
        assert!(replicas > 0, "router needs >= 1 replica");
        ShardRouter {
            policy,
            next_rr: 0,
            outstanding: (0..replicas).map(|_| Arc::new(AtomicUsize::new(0))).collect(),
        }
    }

    /// Replica `r`'s outstanding counter, to hand to its shard (which
    /// decrements it once per completion or drop).
    pub fn counter(&self, r: usize) -> Arc<AtomicUsize> {
        Arc::clone(&self.outstanding[r])
    }

    /// Route one arrival and charge the chosen replica's counter.
    pub fn route(&mut self) -> usize {
        let r = match self.policy {
            RoutePolicy::RoundRobin => {
                let r = self.next_rr % self.outstanding.len();
                self.next_rr = self.next_rr.wrapping_add(1);
                r
            }
            RoutePolicy::JoinShortestQueue => self
                .outstanding
                .iter()
                .enumerate()
                .min_by_key(|(i, c)| (c.load(Ordering::Relaxed), *i))
                .map(|(i, _)| i)
                .unwrap(),
        };
        self.outstanding[r].fetch_add(1, Ordering::Relaxed);
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads(ls: &[(usize, usize)]) -> Vec<ReplicaLoad> {
        ls.iter()
            .map(|&(queued, in_flight)| ReplicaLoad { queued, in_flight })
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        let l = loads(&[(0, 0), (9, 9), (0, 0)]);
        assert_eq!(r.route(&l), 0);
        assert_eq!(r.route(&l), 1);
        assert_eq!(r.route(&l), 2);
        assert_eq!(r.route(&l), 0);
    }

    #[test]
    fn jsq_picks_least_loaded() {
        let mut r = Router::new(RoutePolicy::JoinShortestQueue);
        assert_eq!(r.route(&loads(&[(3, 1), (0, 2), (4, 0)])), 1);
        // counts queued + in-flight, not just queued
        assert_eq!(r.route(&loads(&[(0, 5), (2, 1), (1, 1)])), 2);
    }

    #[test]
    fn jsq_breaks_ties_low_index() {
        let mut r = Router::new(RoutePolicy::JoinShortestQueue);
        assert_eq!(r.route(&loads(&[(1, 1), (2, 0), (0, 2)])), 0);
    }

    #[test]
    fn shard_router_rr_matches_positional_assignment() {
        let mut r = ShardRouter::new(RoutePolicy::RoundRobin, 3);
        let picks: Vec<usize> = (0..7).map(|_| r.route()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn shard_router_jsq_follows_outstanding_counters() {
        let mut r = ShardRouter::new(RoutePolicy::JoinShortestQueue, 3);
        // All zero: lowest index wins and gets charged.
        assert_eq!(r.route(), 0);
        assert_eq!(r.route(), 1);
        assert_eq!(r.route(), 2);
        assert_eq!(r.counter(0).load(Ordering::Relaxed), 1);
        // A shard drains replica 1: it becomes the shortest queue.
        r.counter(1).fetch_sub(1, Ordering::Relaxed);
        assert_eq!(r.route(), 1);
        assert_eq!(r.counter(1).load(Ordering::Relaxed), 1);
    }
}
