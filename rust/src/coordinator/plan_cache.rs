//! Step-plan cache for the serving hot path.
//!
//! A replica's step plan depends only on `(technique, failed_node)` — the
//! chain layout is fixed for the run — yet the engine used to re-derive
//! and re-allocate a fresh `Vec<Step>` from the backend on *every* batch
//! dispatch. [`PlanCache`] memoizes each plan behind an `Arc<[Step]>`, so
//! steady-state dispatch and failover switch plans by pointer: after
//! warm-up (one miss per distinct technique/failure pair) dispatch
//! performs zero step-plan allocations, which the hit/miss counters let
//! tests and benches assert directly.
//!
//! Plans are `Arc` rather than `Rc` so they are `Send`: the sharded
//! engine moves each replica's cache onto its worker thread, and a cache
//! warmed on one thread can seed another via [`PlanCache::share_warmed`]
//! (entries shared by pointer, counters reset so per-shard hit/miss
//! accounting stays correct under sharding).
//!
//! Lookup is a linear scan over the few plans a run ever sees (healthy
//! plus one per failover decision) — deliberately no hashing on the
//! per-batch path.

use std::sync::Arc;

use crate::cluster::sim::Step;
use crate::dnn::variants::Technique;

use super::engine::StageBackend;

/// Per-replica memo of `backend.steps(technique, failed)` results.
#[derive(Debug, Default, Clone)]
pub struct PlanCache {
    entries: Vec<((Technique, Option<usize>), Arc<[Step]>)>,
    hits: usize,
    misses: usize,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// The step plan for `(tech, failed)`, deriving and caching it on
    /// first sight. The returned `Arc` is a pointer copy on a hit.
    pub fn plan<B: StageBackend + ?Sized>(
        &mut self,
        backend: &B,
        tech: Technique,
        failed: Option<usize>,
    ) -> Arc<[Step]> {
        let key = (tech, failed);
        if let Some((_, steps)) = self.entries.iter().find(|(k, _)| *k == key) {
            self.hits += 1;
            return Arc::clone(steps);
        }
        self.misses += 1;
        let steps: Arc<[Step]> = backend.steps(tech, failed).into();
        self.entries.push((key, Arc::clone(&steps)));
        steps
    }

    /// A copy of this cache that shares every entry by pointer but starts
    /// its hit/miss counters at zero — the shape a shard wants when it
    /// inherits a warmed cache: plans resolve without re-deriving, and
    /// the shard's own counters measure only its own traffic.
    pub fn share_warmed(&self) -> PlanCache {
        PlanCache {
            entries: self.entries.clone(),
            hits: 0,
            misses: 0,
        }
    }

    /// Lookups served from the cache (no allocation).
    pub fn hits(&self) -> usize {
        self.hits
    }

    /// Lookups that derived a fresh plan (one allocation each).
    pub fn misses(&self) -> usize {
        self.misses
    }

    /// Distinct plans held.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::SyntheticBackend;

    #[test]
    fn repeated_lookups_hit_after_one_miss() {
        let backend = SyntheticBackend::uniform(4, 5.0, 1.0);
        let mut cache = PlanCache::new();
        let first = cache.plan(&backend, Technique::Repartition, None);
        for _ in 0..99 {
            let again = cache.plan(&backend, Technique::Repartition, None);
            assert!(Arc::ptr_eq(&first, &again), "hits must be pointer copies");
        }
        assert_eq!(cache.misses(), 1, "one allocation at warm-up");
        assert_eq!(cache.hits(), 99, "every later dispatch reuses it");
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_failure_keys_get_distinct_plans() {
        let backend = SyntheticBackend::uniform(4, 5.0, 1.0);
        let mut cache = PlanCache::new();
        let healthy = cache.plan(&backend, Technique::Repartition, None);
        let skip = cache.plan(&backend, Technique::SkipConnection(2), Some(2));
        let repart = cache.plan(&backend, Technique::Repartition, Some(2));
        assert_eq!(cache.misses(), 3);
        assert_eq!(cache.hits(), 0);
        assert_eq!(healthy.len(), 4);
        assert_eq!(skip.len(), 3, "skip drops the failed node's stage");
        assert!(repart.iter().all(|s| s.host != 2), "repartition re-hosts");
        // Returning to a previously seen key is a hit, not a new plan.
        cache.plan(&backend, Technique::Repartition, None);
        assert_eq!(cache.misses(), 3);
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn shared_warm_cache_hits_without_rederiving() {
        let backend = SyntheticBackend::uniform(4, 5.0, 1.0);
        let mut warm = PlanCache::new();
        let original = warm.plan(&backend, Technique::Repartition, None);

        let mut shard = warm.share_warmed();
        assert_eq!(shard.hits(), 0, "inherited counters start at zero");
        assert_eq!(shard.misses(), 0);
        let reused = shard.plan(&backend, Technique::Repartition, None);
        assert!(
            Arc::ptr_eq(&original, &reused),
            "warm entries are shared by pointer across caches"
        );
        assert_eq!(shard.hits(), 1);
        assert_eq!(shard.misses(), 0, "no re-derivation on a warm entry");
        // The donor cache's counters are untouched by the shard's traffic.
        assert_eq!(warm.hits(), 0);
        assert_eq!(warm.misses(), 1);
    }

    #[test]
    fn plans_are_send_for_sharding() {
        fn assert_send<T: Send>(_: &T) {}
        let backend = SyntheticBackend::uniform(4, 5.0, 1.0);
        let mut cache = PlanCache::new();
        let plan = cache.plan(&backend, Technique::Repartition, None);
        assert_send(&plan);
        assert_send(&cache);
    }
}
