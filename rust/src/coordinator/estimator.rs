//! Runtime estimator: turns the fitted prediction models into the
//! per-candidate metrics the Scheduler consumes (paper Fig. 1: the bridge
//! between the profiler phase and the runtime phase).

use std::cell::RefCell;
use std::collections::HashMap;

use anyhow::Result;

use crate::cluster::link::LinkModel;
use crate::cluster::sim::{expected_network_ms, steps_for};
use crate::dnn::model::ModelMeta;
use crate::dnn::variants::{candidates, Technique};
use crate::predict::{AccuracyModel, LatencyModel};
use crate::runtime::UnitKind;

use super::profiler::DowntimeTable;
use super::scheduler::CandidateMetrics;

/// What a failover controller needs from the prediction stack: candidate
/// metrics for a failure plus the reinstate constant. Abstracted from the
/// concrete [`Estimator`] so the serving engine and its tests can run
/// against stub predictors without fitted models or artifacts.
pub trait MetricsSource {
    /// Candidate metrics for the failure of `failed`, in the scheduler's
    /// canonical order.
    fn candidate_metrics(&self, failed: usize) -> Result<Vec<CandidateMetrics>>;
    /// Connection-reinstate constant (paper §IV-B-iii), ms.
    fn reinstate_ms(&self) -> f64;
}

impl MetricsSource for Estimator<'_> {
    fn candidate_metrics(&self, failed: usize) -> Result<Vec<CandidateMetrics>> {
        Estimator::candidate_metrics(self, failed)
    }

    fn reinstate_ms(&self) -> f64 {
        self.reinstate_ms
    }
}

/// Fixed two-candidate metrics (repartition vs skip the failed node) for
/// tests, benches and synthetic experiment drivers that run the serving
/// engine without fitted predictors or artifacts.
#[derive(Debug, Clone, Copy, Default)]
pub struct StaticMetrics;

impl MetricsSource for StaticMetrics {
    fn candidate_metrics(&self, failed: usize) -> Result<Vec<CandidateMetrics>> {
        Ok(vec![
            CandidateMetrics {
                technique: Technique::Repartition,
                accuracy: 90.0,
                latency_ms: 30.0,
                downtime_ms: 4.0,
            },
            CandidateMetrics {
                technique: Technique::SkipConnection(failed),
                accuracy: 85.0,
                latency_ms: 25.0,
                downtime_ms: 3.0,
            },
        ])
    }

    fn reinstate_ms(&self) -> f64 {
        1.0
    }
}

/// Bundles the two prediction models + the link/downtime constants for one
/// deployed model on one platform.
pub struct Estimator<'a> {
    pub meta: &'a ModelMeta,
    pub latency: &'a LatencyModel,
    pub accuracy: &'a AccuracyModel,
    pub link: &'a LinkModel,
    pub downtime: &'a DowntimeTable,
    /// Connection-reinstate constant (paper §IV-B-iii), ms.
    pub reinstate_ms: f64,
    /// Memoised per-unit compute predictions (the layer hyperparameters of
    /// a deployed unit never change, so its GBDT sum is a constant —
    /// caching it removes per-layer tree walks from the failover path;
    /// EXPERIMENTS.md §Perf).
    unit_cache: RefCell<HashMap<UnitKind, f64>>,
}

impl<'a> Estimator<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        meta: &'a ModelMeta,
        latency: &'a LatencyModel,
        accuracy: &'a AccuracyModel,
        link: &'a LinkModel,
        downtime: &'a DowntimeTable,
        reinstate_ms: f64,
    ) -> Estimator<'a> {
        Estimator {
            meta,
            latency,
            accuracy,
            link,
            downtime,
            reinstate_ms,
            unit_cache: RefCell::new(HashMap::new()),
        }
    }

    fn unit_compute_ms(&self, unit: UnitKind) -> f64 {
        if let Some(&v) = self.unit_cache.borrow().get(&unit) {
            return v;
        }
        let layers = match unit {
            UnitKind::Node(n) => self.meta.node(n).map(|m| &m.layers).ok(),
            UnitKind::Exit(e) => self.meta.exit(e).map(|m| &m.layers).ok(),
        };
        let v = layers
            .map(|ls| self.latency.predict_path(ls.iter()))
            .unwrap_or(0.0);
        self.unit_cache.borrow_mut().insert(unit, v);
        v
    }

    /// Predicted end-to-end latency (ms) of a technique under a failure:
    /// sum of per-layer latency predictions over every unit on the path,
    /// plus the analytic link time of the step sequence.
    pub fn predict_latency_ms(&self, tech: Technique, failed: Option<usize>) -> f64 {
        let steps = steps_for(self.meta, tech, failed);
        let compute: f64 = steps.iter().map(|s| self.unit_compute_ms(s.unit)).sum();
        compute + expected_network_ms(self.meta, self.link, &steps)
    }

    /// Predicted accuracy (%) of a technique.
    pub fn predict_accuracy(&self, tech: Technique) -> Result<f64> {
        self.accuracy.predict(self.meta, tech)
    }

    /// Empirical downtime (ms) of a technique: the profiled
    /// predict-and-select time plus the reinstate constant where the
    /// paper applies it (repartition, skip).
    pub fn downtime_ms(&self, tech: Technique) -> f64 {
        let base = self
            .downtime
            .get(tech.kind_name())
            .copied()
            .unwrap_or(1.0);
        match tech {
            Technique::EarlyExit(_) => base,
            _ => base + self.reinstate_ms,
        }
    }

    /// Full candidate metrics for a failure, in the scheduler's canonical
    /// order (repartition, early-exit, skip).
    pub fn candidate_metrics(&self, failed: usize) -> Result<Vec<CandidateMetrics>> {
        candidates(self.meta, failed)
            .into_iter()
            .map(|tech| {
                Ok(CandidateMetrics {
                    technique: tech,
                    accuracy: self.predict_accuracy(tech)?,
                    latency_ms: self.predict_latency_ms(tech, Some(failed)),
                    downtime_ms: self.downtime_ms(tech),
                })
            })
            .collect()
    }
}
