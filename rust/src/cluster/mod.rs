//! Edge-cluster simulator: nodes hosting real PJRT block executables,
//! modeled links, failure injection/detection (DESIGN.md §1.4).

pub mod failure;
pub mod link;
pub mod sim;

pub use failure::{Detector, FailureEvent, FailurePlan, NodeCondition};
pub use link::LinkModel;
pub use sim::{
    expected_network_ms, healthy_path, steps_for, steps_for_chain, EdgeCluster, PathTiming, Step,
};
