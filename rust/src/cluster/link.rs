//! Network link model between edge nodes (DESIGN.md §1.4): activations
//! move between nodes over links with base latency, bandwidth and jitter.
//! Compute is real (PJRT); only the network is modeled.

use crate::config::LinkConfig;
use crate::util::rng::Rng;

/// A link cost model.
#[derive(Debug, Clone)]
pub struct LinkModel {
    cfg: LinkConfig,
}

impl LinkModel {
    pub fn new(cfg: LinkConfig) -> LinkModel {
        LinkModel { cfg }
    }

    /// Expected (deterministic) transfer time for `bytes`, milliseconds.
    /// Used by the latency *predictor* so prediction error reflects only
    /// the compute models, as in the paper's fixed testbed network.
    pub fn expected_ms(&self, bytes: usize) -> f64 {
        let bw_bytes_per_ms = self.cfg.bandwidth_mbps * 1e6 / 1e3;
        self.cfg.latency_ms + bytes as f64 / bw_bytes_per_ms
    }

    /// Sampled transfer time with jitter (the *measured* path).
    pub fn sample_ms(&self, bytes: usize, rng: &mut Rng) -> f64 {
        let base = self.expected_ms(bytes);
        let j = self.cfg.jitter;
        if j <= 0.0 {
            return base;
        }
        base * (1.0 + rng.range(-j, j))
    }

    /// Number of link hops a path with `n_segments` boundary crossings
    /// pays when skipping `skipped` nodes: a skip reroutes over one longer
    /// hop (modelled as a single extra base latency).
    pub fn skip_extra_ms(&self) -> f64 {
        self.cfg.latency_ms
    }

    /// Time to push `bytes` of partition weights onto a node during a
    /// repartition *deployment*. Deliberately the deterministic expected
    /// path, never the jittered sample: the engine schedules the
    /// cut-over instant from this value up front, and consuming RNG
    /// state here would desynchronise same-seed sequential and sharded
    /// runs.
    pub fn deploy_ms(&self, bytes: usize) -> f64 {
        self.expected_ms(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> LinkModel {
        LinkModel::new(LinkConfig {
            latency_ms: 1.0,
            bandwidth_mbps: 100.0,
            jitter: 0.1,
        })
    }

    #[test]
    fn expected_scales_with_bytes() {
        let m = model();
        // 100 MB/s = 1e8 B/s = 1e5 B/ms; 1e5 bytes -> 1 ms + 1 ms
        assert!((m.expected_ms(100_000) - 2.0).abs() < 1e-9);
        assert!(m.expected_ms(200_000) > m.expected_ms(100_000));
    }

    #[test]
    fn sample_within_jitter_bounds() {
        let m = model();
        let mut rng = Rng::new(1);
        let base = m.expected_ms(50_000);
        for _ in 0..200 {
            let s = m.sample_ms(50_000, &mut rng);
            assert!(s >= base * 0.9 - 1e-9 && s <= base * 1.1 + 1e-9);
        }
    }

    #[test]
    fn deploy_is_deterministic_expected_time() {
        let m = model();
        assert_eq!(m.deploy_ms(100_000), m.expected_ms(100_000));
        // Jitter never leaks into deployment scheduling.
        assert_eq!(m.deploy_ms(100_000), m.deploy_ms(100_000));
    }

    #[test]
    fn zero_jitter_is_deterministic() {
        let m = LinkModel::new(LinkConfig {
            latency_ms: 0.5,
            bandwidth_mbps: 10.0,
            jitter: 0.0,
        });
        let mut rng = Rng::new(2);
        assert_eq!(m.sample_ms(1000, &mut rng), m.expected_ms(1000));
    }
}
