//! Failure injection for the simulated edge cluster: the *ground truth*
//! side of node health.
//!
//! Failures are no longer binary fail-stop. Each node carries a
//! [`NodeCondition`]:
//!
//! - `Up` — serving normally;
//! - `Degraded(slowdown)` — a *gray failure*: the node is alive (it
//!   heartbeats, it answers) but its stage runs `slowdown`× slower, and
//!   its heartbeats stretch by the same factor. Whether a degradation is
//!   worth failing over is the monitor's call, not the injector's;
//! - `Down` — crashed / partitioned; the node is silent and its stages
//!   cannot run.
//!
//! A [`FailurePlan`] is a time-sorted schedule of condition changes.
//! Constructors cover one-shot crashes, crash + recovery, intermittent
//! flaps, gray-failure windows, and random schedules (per-node crash
//! probability with an optional MTTR, or a full MTBF/MTTR renewal
//! process), and plans compose with [`FailurePlan::merge`].
//!
//! *Detection* of these conditions lives in [`crate::health`]: a
//! simulated heartbeat channel feeds a [`crate::health::HealthDetector`],
//! which — unlike the ground truth here — can be wrong in both
//! directions (late detections and false positives). The legacy
//! [`Detector`] below is the oracle model (exact detection one heartbeat
//! quantum plus a timeout after a crash) kept for seed-compatible runs.

use crate::util::rng::Rng;

/// Ground-truth node condition.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NodeCondition {
    /// Serving normally.
    Up,
    /// Gray failure: alive but running this many times slower (> 1).
    Degraded(f64),
    /// Crashed or partitioned; silent, cannot serve.
    Down,
}

impl NodeCondition {
    /// Whether the node can serve at all (possibly slowly).
    pub fn is_up(&self) -> bool {
        !matches!(self, NodeCondition::Down)
    }

    /// Service-time stretch factor (1.0 when healthy; infinite when down).
    pub fn slowdown(&self) -> f64 {
        match self {
            NodeCondition::Up => 1.0,
            NodeCondition::Degraded(s) => *s,
            NodeCondition::Down => f64::INFINITY,
        }
    }
}

/// A scheduled condition change.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureEvent {
    /// Simulation time, milliseconds.
    pub at_ms: f64,
    pub node: usize,
    pub condition: NodeCondition,
}

/// Failure schedule generator: a time-sorted list of condition changes.
#[derive(Debug, Clone, Default)]
pub struct FailurePlan {
    pub events: Vec<FailureEvent>,
}

impl FailurePlan {
    /// No failures at all.
    pub fn none() -> FailurePlan {
        FailurePlan { events: Vec::new() }
    }

    /// A single crash of `node` at `at_ms` (never recovers).
    pub fn crash(node: usize, at_ms: f64) -> FailurePlan {
        FailurePlan {
            events: vec![FailureEvent {
                at_ms,
                node,
                condition: NodeCondition::Down,
            }],
        }
    }

    /// A crash at `at_ms` followed by recovery `down_ms` later.
    pub fn crash_recover(node: usize, at_ms: f64, down_ms: f64) -> FailurePlan {
        FailurePlan {
            events: vec![
                FailureEvent {
                    at_ms,
                    node,
                    condition: NodeCondition::Down,
                },
                FailureEvent {
                    at_ms: at_ms + down_ms,
                    node,
                    condition: NodeCondition::Up,
                },
            ],
        }
    }

    /// A gray-failure window: `node` runs `slowdown`× slower during
    /// `[at_ms, at_ms + duration_ms)`, then returns to normal.
    pub fn degraded(node: usize, at_ms: f64, slowdown: f64, duration_ms: f64) -> FailurePlan {
        assert!(slowdown > 1.0, "degraded slowdown must be > 1");
        FailurePlan {
            events: vec![
                FailureEvent {
                    at_ms,
                    node,
                    condition: NodeCondition::Degraded(slowdown),
                },
                FailureEvent {
                    at_ms: at_ms + duration_ms,
                    node,
                    condition: NodeCondition::Up,
                },
            ],
        }
    }

    /// Intermittent connectivity: `node` flaps down/up `cycles` times.
    pub fn intermittent(node: usize, start_ms: f64, down_ms: f64, up_ms: f64, cycles: usize) -> FailurePlan {
        let mut events = Vec::new();
        let mut t = start_ms;
        for _ in 0..cycles {
            events.push(FailureEvent {
                at_ms: t,
                node,
                condition: NodeCondition::Down,
            });
            t += down_ms;
            events.push(FailureEvent {
                at_ms: t,
                node,
                condition: NodeCondition::Up,
            });
            t += up_ms;
        }
        FailurePlan { events }
    }

    /// Random crashes over a horizon: each eligible node crashes at most
    /// once, with probability `p_crash`, at a uniform time. With
    /// `mttr_ms = Some(m)` each crash recovers after an Exp(1/m) repair,
    /// so random plans exercise the recovery path too; `None` reproduces
    /// crash-and-stay-down.
    pub fn random(
        eligible: &[usize],
        horizon_ms: f64,
        p_crash: f64,
        mttr_ms: Option<f64>,
        rng: &mut Rng,
    ) -> FailurePlan {
        let mut events = Vec::new();
        for &node in eligible {
            if rng.bool(p_crash) {
                let at_ms = rng.range(0.0, horizon_ms);
                events.push(FailureEvent {
                    at_ms,
                    node,
                    condition: NodeCondition::Down,
                });
                if let Some(m) = mttr_ms {
                    events.push(FailureEvent {
                        at_ms: at_ms + rng.exp(1.0 / m.max(1e-9)),
                        node,
                        condition: NodeCondition::Up,
                    });
                }
            }
        }
        events.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms));
        FailurePlan { events }
    }

    /// A full renewal process per node: time-to-failure ~ Exp(1/mtbf),
    /// time-to-repair ~ Exp(1/mttr), repeating until `horizon_ms`. Every
    /// crash inside the horizon gets its recovery event (possibly past
    /// the horizon), so the plan always closes its outages.
    pub fn random_mtbf(
        eligible: &[usize],
        horizon_ms: f64,
        mtbf_ms: f64,
        mttr_ms: f64,
        rng: &mut Rng,
    ) -> FailurePlan {
        assert!(mtbf_ms > 0.0 && mttr_ms > 0.0, "mtbf/mttr must be positive");
        let mut events = Vec::new();
        for &node in eligible {
            let mut t = rng.exp(1.0 / mtbf_ms);
            while t < horizon_ms {
                events.push(FailureEvent {
                    at_ms: t,
                    node,
                    condition: NodeCondition::Down,
                });
                let up = t + rng.exp(1.0 / mttr_ms);
                events.push(FailureEvent {
                    at_ms: up,
                    node,
                    condition: NodeCondition::Up,
                });
                t = up + rng.exp(1.0 / mtbf_ms);
            }
        }
        events.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms));
        FailurePlan { events }
    }

    /// Combine several plans into one time-sorted schedule.
    pub fn merge<I: IntoIterator<Item = FailurePlan>>(plans: I) -> FailurePlan {
        let mut events: Vec<FailureEvent> = plans.into_iter().flat_map(|p| p.events).collect();
        events.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms));
        FailurePlan { events }
    }

    /// Events due at or before `now_ms` that haven't been applied yet
    /// (callers track the cursor).
    pub fn due(&self, cursor: usize, now_ms: f64) -> &[FailureEvent] {
        let mut end = cursor;
        while end < self.events.len() && self.events[end].at_ms <= now_ms {
            end += 1;
        }
        &self.events[cursor..end]
    }

    /// Time of the last scheduled event (0 when empty).
    pub fn last_event_ms(&self) -> f64 {
        self.events.last().map(|e| e.at_ms).unwrap_or(0.0)
    }
}

/// Oracle failure-detector model: a crash at time t is *detected* at the
/// next heartbeat boundary plus a timeout — exact, never wrong, used by
/// seed-compatible runs. The imperfect detectors (late, and wrong in both
/// directions) live in [`crate::health`].
#[derive(Debug, Clone)]
pub struct Detector {
    pub heartbeat_ms: f64,
    pub timeout_ms: f64,
}

impl Default for Detector {
    fn default() -> Self {
        Detector {
            heartbeat_ms: 10.0,
            timeout_ms: 5.0,
        }
    }
}

impl Detector {
    /// Time at which a failure occurring at `t_ms` is detected.
    pub fn detection_time(&self, t_ms: f64) -> f64 {
        let next_beat = (t_ms / self.heartbeat_ms).ceil() * self.heartbeat_ms;
        next_beat + self.timeout_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crash_plan() {
        let p = FailurePlan::crash(3, 100.0);
        assert_eq!(p.events.len(), 1);
        assert_eq!(p.events[0].node, 3);
        assert_eq!(p.events[0].condition, NodeCondition::Down);
    }

    #[test]
    fn crash_recover_closes_outage() {
        let p = FailurePlan::crash_recover(2, 50.0, 30.0);
        assert_eq!(p.events.len(), 2);
        assert_eq!(p.events[1].condition, NodeCondition::Up);
        assert!((p.events[1].at_ms - 80.0).abs() < 1e-9);
    }

    #[test]
    fn degraded_window() {
        let p = FailurePlan::degraded(4, 10.0, 3.0, 100.0);
        assert_eq!(p.events[0].condition, NodeCondition::Degraded(3.0));
        assert!(p.events[0].condition.is_up());
        assert!((p.events[0].condition.slowdown() - 3.0).abs() < 1e-12);
        assert_eq!(p.events[1].condition, NodeCondition::Up);
        assert!((p.events[1].at_ms - 110.0).abs() < 1e-9);
    }

    #[test]
    fn intermittent_alternates() {
        let p = FailurePlan::intermittent(2, 10.0, 5.0, 20.0, 3);
        assert_eq!(p.events.len(), 6);
        assert_eq!(p.events[0].condition, NodeCondition::Down);
        assert_eq!(p.events[1].condition, NodeCondition::Up);
        assert!((p.events[1].at_ms - 15.0).abs() < 1e-9);
        // strictly increasing times
        for w in p.events.windows(2) {
            assert!(w[0].at_ms < w[1].at_ms);
        }
    }

    #[test]
    fn random_is_sorted_and_bounded() {
        let mut rng = Rng::new(4);
        let p = FailurePlan::random(&[2, 3, 4, 5, 6], 1000.0, 0.8, None, &mut rng);
        for w in p.events.windows(2) {
            assert!(w[0].at_ms <= w[1].at_ms);
        }
        for e in &p.events {
            assert!((0.0..=1000.0).contains(&e.at_ms));
        }
    }

    #[test]
    fn random_with_mttr_recovers_every_crash() {
        let mut rng = Rng::new(9);
        let p = FailurePlan::random(&[1, 2, 3, 4, 5], 1000.0, 1.0, Some(50.0), &mut rng);
        let downs = p.events.iter().filter(|e| e.condition == NodeCondition::Down).count();
        let ups = p.events.iter().filter(|e| e.condition == NodeCondition::Up).count();
        assert_eq!(downs, 5);
        assert_eq!(ups, 5, "every crash must schedule its recovery");
    }

    #[test]
    fn mtbf_plan_alternates_per_node() {
        let mut rng = Rng::new(7);
        let p = FailurePlan::random_mtbf(&[1, 2, 3], 5000.0, 400.0, 60.0, &mut rng);
        assert!(!p.events.is_empty(), "5000 ms at mtbf 400 must produce crashes");
        for node in 1..=3 {
            let seq: Vec<NodeCondition> = p
                .events
                .iter()
                .filter(|e| e.node == node)
                .map(|e| e.condition)
                .collect();
            // per node: Down, Up, Down, Up, ... and balanced
            for (i, c) in seq.iter().enumerate() {
                let want = if i % 2 == 0 { NodeCondition::Down } else { NodeCondition::Up };
                assert_eq!(*c, want, "node {node} event {i}");
            }
            assert_eq!(seq.len() % 2, 0, "node {node}: outages must close");
        }
    }

    #[test]
    fn merge_sorts_across_plans() {
        let p = FailurePlan::merge([
            FailurePlan::crash(2, 100.0),
            FailurePlan::degraded(3, 20.0, 2.0, 30.0),
            FailurePlan::none(),
        ]);
        assert_eq!(p.events.len(), 3);
        for w in p.events.windows(2) {
            assert!(w[0].at_ms <= w[1].at_ms);
        }
        assert!((p.last_event_ms() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn due_cursor() {
        let p = FailurePlan::intermittent(1, 0.0, 10.0, 10.0, 2);
        let due = p.due(0, 10.0);
        assert_eq!(due.len(), 2);
        let due2 = p.due(2, 25.0);
        assert_eq!(due2.len(), 1);
    }

    #[test]
    fn detector_quantises() {
        let d = Detector {
            heartbeat_ms: 10.0,
            timeout_ms: 5.0,
        };
        assert!((d.detection_time(12.0) - 25.0).abs() < 1e-9);
        assert!((d.detection_time(20.0) - 25.0).abs() < 1e-9);
    }
}
