//! Failure injection and detection for the simulated edge cluster.
//!
//! The injector produces a schedule of crash / recovery events (one-shot
//! crashes, intermittent flaps); the detector models heartbeat-based
//! detection latency, which contributes to the measured downtime of a
//! failover (the paper's downtime metric starts at detection).

use crate::util::rng::Rng;

/// Node liveness.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeStatus {
    Up,
    Down,
}

/// A scheduled failure event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureEvent {
    /// Simulation time, milliseconds.
    pub at_ms: f64,
    pub node: usize,
    pub status: NodeStatus,
}

/// Failure schedule generator.
#[derive(Debug, Clone)]
pub struct FailurePlan {
    pub events: Vec<FailureEvent>,
}

impl FailurePlan {
    /// A single crash of `node` at `at_ms` (never recovers).
    pub fn crash(node: usize, at_ms: f64) -> FailurePlan {
        FailurePlan {
            events: vec![FailureEvent {
                at_ms,
                node,
                status: NodeStatus::Down,
            }],
        }
    }

    /// Intermittent connectivity: `node` flaps down/up `cycles` times.
    pub fn intermittent(node: usize, start_ms: f64, down_ms: f64, up_ms: f64, cycles: usize) -> FailurePlan {
        let mut events = Vec::new();
        let mut t = start_ms;
        for _ in 0..cycles {
            events.push(FailureEvent {
                at_ms: t,
                node,
                status: NodeStatus::Down,
            });
            t += down_ms;
            events.push(FailureEvent {
                at_ms: t,
                node,
                status: NodeStatus::Up,
            });
            t += up_ms;
        }
        FailurePlan { events }
    }

    /// Random crashes over a horizon: each eligible node crashes at most
    /// once, with probability `p_crash`, at a uniform time.
    pub fn random(
        eligible: &[usize],
        horizon_ms: f64,
        p_crash: f64,
        rng: &mut Rng,
    ) -> FailurePlan {
        let mut events = Vec::new();
        for &node in eligible {
            if rng.bool(p_crash) {
                events.push(FailureEvent {
                    at_ms: rng.range(0.0, horizon_ms),
                    node,
                    status: NodeStatus::Down,
                });
            }
        }
        events.sort_by(|a, b| a.at_ms.partial_cmp(&b.at_ms).unwrap());
        FailurePlan { events }
    }

    /// Events due at or before `now_ms` that haven't been applied yet
    /// (callers track the cursor).
    pub fn due(&self, cursor: usize, now_ms: f64) -> &[FailureEvent] {
        let mut end = cursor;
        while end < self.events.len() && self.events[end].at_ms <= now_ms {
            end += 1;
        }
        &self.events[cursor..end]
    }
}

/// Heartbeat-based failure detector model: a crash at time t is *detected*
/// at the next heartbeat boundary plus a timeout.
#[derive(Debug, Clone)]
pub struct Detector {
    pub heartbeat_ms: f64,
    pub timeout_ms: f64,
}

impl Default for Detector {
    fn default() -> Self {
        Detector {
            heartbeat_ms: 10.0,
            timeout_ms: 5.0,
        }
    }
}

impl Detector {
    /// Time at which a failure occurring at `t_ms` is detected.
    pub fn detection_time(&self, t_ms: f64) -> f64 {
        let next_beat = (t_ms / self.heartbeat_ms).ceil() * self.heartbeat_ms;
        next_beat + self.timeout_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crash_plan() {
        let p = FailurePlan::crash(3, 100.0);
        assert_eq!(p.events.len(), 1);
        assert_eq!(p.events[0].node, 3);
        assert_eq!(p.events[0].status, NodeStatus::Down);
    }

    #[test]
    fn intermittent_alternates() {
        let p = FailurePlan::intermittent(2, 10.0, 5.0, 20.0, 3);
        assert_eq!(p.events.len(), 6);
        assert_eq!(p.events[0].status, NodeStatus::Down);
        assert_eq!(p.events[1].status, NodeStatus::Up);
        assert!((p.events[1].at_ms - 15.0).abs() < 1e-9);
        // strictly increasing times
        for w in p.events.windows(2) {
            assert!(w[0].at_ms < w[1].at_ms);
        }
    }

    #[test]
    fn random_is_sorted_and_bounded() {
        let mut rng = Rng::new(4);
        let p = FailurePlan::random(&[2, 3, 4, 5, 6], 1000.0, 0.8, &mut rng);
        for w in p.events.windows(2) {
            assert!(w[0].at_ms <= w[1].at_ms);
        }
        for e in &p.events {
            assert!((0.0..=1000.0).contains(&e.at_ms));
        }
    }

    #[test]
    fn due_cursor() {
        let p = FailurePlan::intermittent(1, 0.0, 10.0, 10.0, 2);
        let due = p.due(0, 10.0);
        assert_eq!(due.len(), 2);
        let due2 = p.due(2, 25.0);
        assert_eq!(due2.len(), 1);
    }

    #[test]
    fn detector_quantises() {
        let d = Detector {
            heartbeat_ms: 10.0,
            timeout_ms: 5.0,
        };
        assert!((d.detection_time(12.0) - 25.0).abs() < 1e-9);
        assert!((d.detection_time(20.0) - 25.0).abs() < 1e-9);
    }
}
