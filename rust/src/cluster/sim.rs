//! The simulated edge cluster: N nodes, each hosting its block of the
//! distributed DNN as a compiled PJRT executable. Block compute is *real*
//! (executed and wall-clock timed); inter-node links use the LinkModel;
//! failure injection flips per-node [`NodeCondition`]s — a `Degraded`
//! node stretches its measured stage time by its slowdown factor, a
//! `Down` node cannot run stages at all.
//!
//! A technique's execution is a sequence of [`Step`]s: which *unit* (block
//! or exit head) runs and which physical *host* runs it. Repartitioning
//! keeps every block but re-hosts the failed node's block on a surviving
//! neighbour, so its link hop disappears — exactly the paper's "constant
//! latency" repartition behaviour with one fewer boundary.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::LinkConfig;
use crate::dnn::model::{ExitMeta, ModelMeta, NodeMeta};
use crate::dnn::variants::Technique;
use crate::runtime::{ArtifactStore, Engine, HostTensor, UnitKind};
use crate::util::rng::Rng;

use super::failure::NodeCondition;
use super::link::LinkModel;

/// One pipeline step: a unit executed on a physical host node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Step {
    pub unit: UnitKind,
    pub host: usize,
}

/// Timing breakdown of one pipeline execution.
#[derive(Debug, Clone, Default)]
pub struct PathTiming {
    /// Real compute wall-time per executed unit, ms.
    pub compute_ms: Vec<(UnitKind, f64)>,
    /// Modeled network time, ms.
    pub network_ms: f64,
}

impl PathTiming {
    pub fn total_compute_ms(&self) -> f64 {
        self.compute_ms.iter().map(|(_, t)| t).sum()
    }

    pub fn total_ms(&self) -> f64 {
        self.total_compute_ms() + self.network_ms
    }
}

/// Build the step sequence of a technique on an `num_nodes`-long chain.
///
/// Hosting depends only on the (1-based, contiguous) node indices, so this
/// needs no model metadata — the synthetic serving backend shares it with
/// the real cluster. `failed`: the failed node (None = healthy pipeline).
/// Units are hosted on their own node except under repartitioning, where
/// the failed node's block is re-hosted on its predecessor (successor for
/// node 1) — the deterministic merge plan of `coordinator::deployment`.
pub fn steps_for_chain(num_nodes: usize, tech: Technique, failed: Option<usize>) -> Vec<Step> {
    match tech {
        Technique::Repartition => (1..=num_nodes)
            .map(|i| {
                let host = match failed {
                    Some(f) if i == f => {
                        if f == 1 {
                            2
                        } else {
                            f - 1
                        }
                    }
                    _ => i,
                };
                Step {
                    unit: UnitKind::Node(i),
                    host,
                }
            })
            .collect(),
        Technique::EarlyExit(e) => (1..=num_nodes.min(e))
            .map(|i| Step {
                unit: UnitKind::Node(i),
                host: i,
            })
            .chain(std::iter::once(Step {
                unit: UnitKind::Exit(e),
                host: e,
            }))
            .collect(),
        Technique::SkipConnection(k) => (1..=num_nodes)
            .filter(|&i| i != k)
            .map(|i| Step {
                unit: UnitKind::Node(i),
                host: i,
            })
            .collect(),
    }
}

/// Build the step sequence of a technique for a deployed model.
pub fn steps_for(meta: &ModelMeta, tech: Technique, failed: Option<usize>) -> Vec<Step> {
    steps_for_chain(meta.num_nodes, tech, failed)
}

/// Convenience: healthy full pipeline.
pub fn healthy_path(meta: &ModelMeta) -> Vec<Step> {
    steps_for(meta, Technique::Repartition, None)
}

/// The simulated cluster for one deployed model.
pub struct EdgeCluster<'a> {
    engine: &'a Engine,
    store: &'a ArtifactStore,
    pub meta: &'a ModelMeta,
    link: LinkModel,
    conditions: Vec<NodeCondition>, // index 0 unused; 1-based node ids
    units: RefCell<HashMap<(UnitKind, usize), Rc<crate::runtime::UnitExecutable>>>,
    rng: RefCell<Rng>,
}

impl<'a> EdgeCluster<'a> {
    pub fn new(
        engine: &'a Engine,
        store: &'a ArtifactStore,
        meta: &'a ModelMeta,
        link_cfg: LinkConfig,
        seed: u64,
    ) -> EdgeCluster<'a> {
        EdgeCluster {
            engine,
            store,
            meta,
            link: LinkModel::new(link_cfg),
            conditions: vec![NodeCondition::Up; meta.num_nodes + 1],
            units: RefCell::new(HashMap::new()),
            rng: RefCell::new(Rng::new(seed)),
        }
    }

    pub fn link(&self) -> &LinkModel {
        &self.link
    }

    // ----- liveness -------------------------------------------------------

    pub fn fail(&mut self, node: usize) {
        self.conditions[node] = NodeCondition::Down;
    }

    pub fn restore(&mut self, node: usize) {
        self.conditions[node] = NodeCondition::Up;
    }

    /// Gray failure: `node` keeps serving but `slowdown`× slower.
    pub fn degrade(&mut self, node: usize, slowdown: f64) {
        self.conditions[node] = NodeCondition::Degraded(slowdown);
    }

    pub fn set_condition(&mut self, node: usize, condition: NodeCondition) {
        self.conditions[node] = condition;
    }

    pub fn condition(&self, node: usize) -> NodeCondition {
        self.conditions[node]
    }

    pub fn is_up(&self, node: usize) -> bool {
        self.conditions[node].is_up()
    }

    pub fn alive_nodes(&self) -> Vec<usize> {
        (1..=self.meta.num_nodes).filter(|&n| self.is_up(n)).collect()
    }

    pub fn failed_nodes(&self) -> Vec<usize> {
        (1..=self.meta.num_nodes).filter(|&n| !self.is_up(n)).collect()
    }

    // ----- unit loading (lazy, cached) -------------------------------------

    pub fn unit(&self, kind: UnitKind, batch: usize) -> Result<Rc<crate::runtime::UnitExecutable>> {
        if let Some(u) = self.units.borrow().get(&(kind, batch)) {
            return Ok(u.clone());
        }
        let u = Rc::new(
            self.store
                .load_unit(self.engine, &self.meta.name, kind, batch)?,
        );
        self.units.borrow_mut().insert((kind, batch), u.clone());
        Ok(u)
    }

    /// Pre-compile every node block (and exit heads) at a batch size.
    pub fn preload(&self, batch: usize, with_exits: bool) -> Result<()> {
        for n in &self.meta.nodes {
            self.unit(UnitKind::Node(n.index), batch)?;
        }
        if with_exits {
            for e in &self.meta.exits {
                self.unit(UnitKind::Exit(e.after_node), batch)?;
            }
        }
        Ok(())
    }

    pub fn loaded_units(&self) -> usize {
        self.units.borrow().len()
    }

    // ----- execution --------------------------------------------------------

    /// Execute one step's unit on a batch (liveness-checked), returning
    /// the output activation and the occupancy time, ms: the *measured*
    /// wall-clock compute stretched by the host's condition slowdown (1×
    /// when healthy). This is the engine's per-stage primitive: the
    /// serving engine schedules stage occupancy around it instead of
    /// executing whole paths.
    pub fn execute_stage(&self, step: Step, x: &HostTensor) -> Result<(HostTensor, f64)> {
        if !self.is_up(step.host) {
            bail!("step {:?} hosted on failed node {}", step.unit, step.host);
        }
        let slowdown = self.conditions[step.host].slowdown();
        let unit = self.unit(step.unit, x.shape[0])?;
        let t0 = Instant::now();
        let y = unit.run(self.engine, x)?;
        Ok((y, t0.elapsed().as_secs_f64() * 1e3 * slowdown))
    }

    /// Serialized weight payload of a unit, bytes — what a repartition
    /// deployment moves when the unit is re-hosted. Units the manifest
    /// does not know cost nothing (they cannot be scheduled anyway).
    pub fn unit_weight_bytes(&self, unit: UnitKind) -> usize {
        match unit {
            UnitKind::Node(n) => self.meta.node(n).map(NodeMeta::weight_bytes).unwrap_or(0),
            UnitKind::Exit(e) => self.meta.exit(e).map(ExitMeta::weight_bytes).unwrap_or(0),
        }
    }

    /// Modeled time to push `bytes` of weights onto a node during a
    /// repartition deployment. Deterministic ([`LinkModel::deploy_ms`]):
    /// the engine schedules cut-over instants from it, so it must not
    /// consume RNG state the way [`Self::stage_transfer_ms`] does.
    pub fn deploy_transfer_ms(&self, bytes: usize) -> f64 {
        self.link.deploy_ms(bytes)
    }

    /// Modeled transfer time of `bytes` moving from host `from` to host
    /// `to`, ms. Zero when the hosts coincide; a non-adjacent forward hop
    /// (a skip reroute) pays one extra base latency.
    pub fn stage_transfer_ms(&self, from: usize, to: usize, bytes: usize) -> f64 {
        if from == to {
            return 0.0;
        }
        let mut ms = self.link.sample_ms(bytes, &mut self.rng.borrow_mut());
        if to > from + 1 {
            ms += self.link.skip_extra_ms();
        }
        ms
    }

    /// Execute a step sequence on an input batch, checking host liveness
    /// (stage-by-stage over [`Self::execute_stage`]).
    pub fn execute_steps(
        &self,
        steps: &[Step],
        x: &HostTensor,
    ) -> Result<(HostTensor, PathTiming)> {
        if steps.is_empty() {
            bail!("empty path");
        }
        let mut timing = PathTiming::default();
        let mut act = x.clone();
        let mut prev_host: Option<usize> = None;
        for step in steps {
            if let Some(p) = prev_host {
                timing.network_ms += self.stage_transfer_ms(p, step.host, act.bytes());
            }
            let (y, ms) = self.execute_stage(*step, &act)?;
            act = y;
            timing.compute_ms.push((step.unit, ms));
            prev_host = Some(step.host);
        }
        Ok((act, timing))
    }

    /// Execute a technique's path under an optional failure.
    pub fn execute_technique(
        &self,
        tech: Technique,
        failed: Option<usize>,
        x: &HostTensor,
    ) -> Result<(HostTensor, PathTiming)> {
        self.execute_steps(&steps_for(self.meta, tech, failed), x)
    }

    /// Measured accuracy of a technique over (images, labels), running the
    /// real pipeline in batches.
    pub fn measure_accuracy(
        &self,
        tech: Technique,
        failed: Option<usize>,
        images: &HostTensor,
        labels: &[i32],
        batch: usize,
    ) -> Result<f64> {
        let n = images.shape[0];
        if n != labels.len() {
            bail!("images/labels length mismatch");
        }
        let steps = steps_for(self.meta, tech, failed);
        let mut correct = 0usize;
        let mut done = 0usize;
        while done + batch <= n {
            let xb = images.slice0(done, done + batch)?;
            let (logits, _) = self.execute_steps(&steps, &xb)?;
            for (pred, &y) in logits
                .argmax_rows()
                .iter()
                .zip(&labels[done..done + batch])
            {
                if *pred as i32 == y {
                    correct += 1;
                }
            }
            done += batch;
        }
        if done == 0 {
            bail!("eval set smaller than batch size");
        }
        Ok(correct as f64 / done as f64)
    }

    /// Measured end-to-end latency (mean over `reps`) of a technique at
    /// batch 1, ms (real compute + modeled network).
    pub fn measure_latency(
        &self,
        tech: Technique,
        failed: Option<usize>,
        sample: &HostTensor,
        reps: usize,
    ) -> Result<f64> {
        let steps = steps_for(self.meta, tech, failed);
        self.execute_steps(&steps, sample)?; // warmup: compile + cache
        let mut total = 0.0;
        for _ in 0..reps {
            let (_, timing) = self.execute_steps(&steps, sample)?;
            total += timing.total_ms();
        }
        Ok(total / reps.max(1) as f64)
    }

    /// Like [`measure_latency`], but returns (compute_ms, network_ms)
    /// separately — the platform-2 transform scales only compute.
    pub fn measure_latency_split(
        &self,
        tech: Technique,
        failed: Option<usize>,
        sample: &HostTensor,
        reps: usize,
    ) -> Result<(f64, f64)> {
        let steps = steps_for(self.meta, tech, failed);
        self.execute_steps(&steps, sample)?; // warmup
        let (mut comp, mut net) = (0.0, 0.0);
        for _ in 0..reps {
            let (_, timing) = self.execute_steps(&steps, sample)?;
            comp += timing.total_compute_ms();
            net += timing.network_ms;
        }
        let r = reps.max(1) as f64;
        Ok((comp / r, net / r))
    }

    /// Analytic (jitter-free) network time of a step sequence — the value
    /// the latency *predictor* adds for transfers.
    pub fn expected_network_ms(&self, steps: &[Step]) -> f64 {
        expected_network_ms(self.meta, &self.link, steps)
    }
}

/// Analytic network time of a step sequence under a link model.
pub fn expected_network_ms(meta: &ModelMeta, link: &LinkModel, steps: &[Step]) -> f64 {
    let mut total = 0.0;
    let mut prev: Option<(usize, usize)> = None; // (host, out_bytes of last node unit)
    let mut last_bytes = 0usize;
    for step in steps {
        if let Some((p, _)) = prev {
            if step.host != p {
                total += link.expected_ms(last_bytes);
                if step.host > p + 1 {
                    total += link.skip_extra_ms();
                }
            }
        }
        if let UnitKind::Node(n) = step.unit {
            last_bytes = meta.node(n).map(|m| m.out_bytes()).unwrap_or(0);
        }
        prev = Some((step.host, last_bytes));
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::model::test_fixtures::tiny_model;

    #[test]
    fn steps_healthy() {
        let m = tiny_model();
        let p = healthy_path(&m);
        assert_eq!(p.len(), 5);
        assert!(p.iter().enumerate().all(|(i, s)| s.host == i + 1));
    }

    #[test]
    fn steps_repartition_rehosts_failed_block() {
        let m = tiny_model();
        let p = steps_for(&m, Technique::Repartition, Some(3));
        assert_eq!(p.len(), 5, "all blocks still execute");
        let s3 = p.iter().find(|s| s.unit == UnitKind::Node(3)).unwrap();
        assert_eq!(s3.host, 2, "failed block re-hosted on predecessor");
        // node-1 failure re-hosts forward
        let p1 = steps_for(&m, Technique::Repartition, Some(1));
        assert_eq!(
            p1.iter().find(|s| s.unit == UnitKind::Node(1)).unwrap().host,
            2
        );
    }

    #[test]
    fn steps_exit_and_skip() {
        let m = tiny_model();
        let p = steps_for(&m, Technique::EarlyExit(2), Some(3));
        assert_eq!(p.len(), 3);
        assert_eq!(p.last().unwrap().unit, UnitKind::Exit(2));
        assert_eq!(p.last().unwrap().host, 2);
        let p = steps_for(&m, Technique::SkipConnection(3), Some(3));
        assert_eq!(p.len(), 4);
        assert!(!p.iter().any(|s| s.host == 3));
    }

    #[test]
    fn prop_steps_never_touch_failed_host() {
        use crate::util::proptest::{check, prop_assert};
        let m = tiny_model();
        check(100, 42, |g| {
            let f = g.usize(2, 4);
            let techniques = [
                Technique::EarlyExit(f - 1),
                Technique::SkipConnection(f),
                Technique::Repartition,
            ];
            for t in techniques {
                let steps = steps_for(&m, t, Some(f));
                match t {
                    Technique::Repartition => {}
                    _ => prop_assert(
                        steps.iter().all(|s| s.host != f),
                        "exit/skip paths must avoid the failed node",
                    )?,
                }
                // repartition never hosts anything on the failed node
                if let Technique::Repartition = t {
                    prop_assert(
                        steps.iter().all(|s| s.host != f),
                        "repartition must re-host off the failed node",
                    )?;
                }
            }
            Ok(())
        });
    }
}
