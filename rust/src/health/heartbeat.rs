//! Simulated heartbeat channel: when do a node's heartbeats *arrive* at
//! the monitor, given the node's ground-truth condition timeline?
//!
//! The channel is imperfect on purpose — arrival jitter, independent
//! per-beat loss, and an optional blackout window (a partition of the
//! monitoring path while the node itself keeps serving) are exactly the
//! mechanisms that make detectors fire false positives. A `Degraded`
//! node emits beats stretched by its slowdown factor, which is how the
//! monitor can *estimate* gray failures it cannot observe directly; a
//! `Down` node is silent until it recovers. Everything is seeded via
//! [`crate::util::rng::Rng`], so a (plan, config, seed) triple always
//! produces the same arrival sequence.

use crate::cluster::failure::{FailurePlan, NodeCondition};
use crate::util::rng::Rng;

/// Heartbeat channel parameters.
#[derive(Debug, Clone)]
pub struct HeartbeatConfig {
    /// Nominal emission interval of a healthy node, ms.
    pub interval_ms: f64,
    /// Arrival jitter: each beat lands uniformly in `[0, jitter_ms)` late.
    pub jitter_ms: f64,
    /// Independent probability that a beat is lost in transit.
    pub loss_prob: f64,
    /// Optional monitoring-path blackout `[start_ms, end_ms)`: every beat
    /// arriving inside it is dropped while the node keeps serving — the
    /// canonical false-positive generator.
    pub blackout: Option<(f64, f64)>,
}

impl Default for HeartbeatConfig {
    fn default() -> Self {
        HeartbeatConfig {
            interval_ms: 10.0,
            jitter_ms: 1.0,
            loss_prob: 0.0,
            blackout: None,
        }
    }
}

/// One node's ground-truth condition over time (starts `Up` at t = 0).
#[derive(Debug, Clone)]
pub struct ConditionTimeline {
    /// Time-sorted condition changes.
    changes: Vec<(f64, NodeCondition)>,
}

impl ConditionTimeline {
    /// Extract `node`'s timeline from a failure plan.
    pub fn from_plan(plan: &FailurePlan, node: usize) -> ConditionTimeline {
        let mut changes: Vec<(f64, NodeCondition)> = plan
            .events
            .iter()
            .filter(|e| e.node == node)
            .map(|e| (e.at_ms, e.condition))
            .collect();
        changes.sort_by(|a, b| a.0.total_cmp(&b.0));
        ConditionTimeline { changes }
    }

    /// Condition in effect at `t_ms`.
    pub fn at(&self, t_ms: f64) -> NodeCondition {
        let mut cond = NodeCondition::Up;
        for (at, c) in &self.changes {
            if *at <= t_ms {
                cond = *c;
            } else {
                break;
            }
        }
        cond
    }

    /// Earliest change time strictly after `t_ms` at which the node can
    /// serve (and thus heartbeat) again, if any.
    pub fn next_serving_after(&self, t_ms: f64) -> Option<f64> {
        self.changes
            .iter()
            .find(|(at, c)| *at > t_ms && c.is_up())
            .map(|(at, _)| *at)
    }
}

/// Simulate the arrival times (at the monitor) of one node's heartbeats
/// over `[0, horizon_ms)`. The node is assumed to have announced itself
/// at t = 0, so the first beat is due one (condition-stretched) interval
/// in.
pub fn arrivals(
    cfg: &HeartbeatConfig,
    timeline: &ConditionTimeline,
    horizon_ms: f64,
    rng: &mut Rng,
) -> Vec<f64> {
    assert!(cfg.interval_ms > 0.0, "heartbeat interval must be positive");
    let mut out = Vec::new();
    let mut t = 0.0;
    while t < horizon_ms {
        let cond = timeline.at(t);
        if !cond.is_up() {
            // Silent while down; resume after the next recovery.
            match timeline.next_serving_after(t) {
                Some(r) => {
                    t = r;
                    continue;
                }
                None => break,
            }
        }
        t += cfg.interval_ms * cond.slowdown();
        if t >= horizon_ms {
            break;
        }
        if !timeline.at(t).is_up() {
            // Crashed before this beat was due; the loop top jumps ahead.
            continue;
        }
        let lost = rng.bool(cfg.loss_prob);
        let jitter = if cfg.jitter_ms > 0.0 {
            rng.range(0.0, cfg.jitter_ms)
        } else {
            0.0
        };
        let arrive = t + jitter;
        let blacked = cfg
            .blackout
            .is_some_and(|(s, e)| arrive >= s && arrive < e);
        if !lost && !blacked {
            out.push(arrive);
        }
    }
    // Jitter larger than the interval can reorder adjacent beats; the
    // detectors assume monotone observation times.
    out.sort_by(|a, b| a.total_cmp(b));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(jitter: f64, loss: f64) -> HeartbeatConfig {
        HeartbeatConfig {
            interval_ms: 10.0,
            jitter_ms: jitter,
            loss_prob: loss,
            blackout: None,
        }
    }

    #[test]
    fn healthy_node_beats_every_interval() {
        let tl = ConditionTimeline::from_plan(&FailurePlan::none(), 1);
        let mut rng = Rng::new(1);
        let beats = arrivals(&cfg(0.0, 0.0), &tl, 100.0, &mut rng);
        assert_eq!(beats.len(), 9, "beats at 10..=90");
        for (i, b) in beats.iter().enumerate() {
            assert!((b - 10.0 * (i + 1) as f64).abs() < 1e-9);
        }
    }

    #[test]
    fn down_node_is_silent_until_recovery() {
        let plan = FailurePlan::crash_recover(2, 35.0, 40.0);
        let tl = ConditionTimeline::from_plan(&plan, 2);
        let mut rng = Rng::new(2);
        let beats = arrivals(&cfg(0.0, 0.0), &tl, 120.0, &mut rng);
        // beats at 10, 20, 30, then silence until recovery at 75 →
        // beats resume at 85, ..., 115.
        assert!(beats.iter().all(|&b| b < 35.0 || b >= 85.0), "{beats:?}");
        assert!(beats.contains(&30.0));
        assert!(beats.contains(&85.0));
    }

    #[test]
    fn crashed_forever_stops_beating() {
        let tl = ConditionTimeline::from_plan(&FailurePlan::crash(1, 25.0), 1);
        let mut rng = Rng::new(3);
        let beats = arrivals(&cfg(0.0, 0.0), &tl, 1000.0, &mut rng);
        assert_eq!(beats, vec![10.0, 20.0]);
    }

    #[test]
    fn degraded_node_stretches_intervals() {
        let plan = FailurePlan::degraded(1, 0.0, 3.0, 1e9);
        let tl = ConditionTimeline::from_plan(&plan, 1);
        let mut rng = Rng::new(4);
        let beats = arrivals(&cfg(0.0, 0.0), &tl, 100.0, &mut rng);
        assert_eq!(beats, vec![30.0, 60.0, 90.0]);
    }

    #[test]
    fn loss_drops_beats_deterministically() {
        let tl = ConditionTimeline::from_plan(&FailurePlan::none(), 1);
        let a = arrivals(&cfg(0.0, 0.4), &tl, 2000.0, &mut Rng::new(7));
        let b = arrivals(&cfg(0.0, 0.4), &tl, 2000.0, &mut Rng::new(7));
        assert_eq!(a, b, "same seed, same losses");
        let full = arrivals(&cfg(0.0, 0.0), &tl, 2000.0, &mut Rng::new(7));
        assert!(a.len() < full.len(), "40% loss must drop something");
        assert!(!a.is_empty(), "and keep something");
    }

    #[test]
    fn blackout_swallows_a_window() {
        let tl = ConditionTimeline::from_plan(&FailurePlan::none(), 1);
        let mut c = cfg(0.0, 0.0);
        c.blackout = Some((35.0, 65.0));
        let beats = arrivals(&c, &tl, 100.0, &mut Rng::new(5));
        assert!(beats.iter().all(|&b| !(35.0..65.0).contains(&b)), "{beats:?}");
        assert!(beats.contains(&30.0));
        assert!(beats.contains(&70.0));
    }

    #[test]
    fn jittered_arrivals_are_sorted() {
        let tl = ConditionTimeline::from_plan(&FailurePlan::none(), 1);
        let beats = arrivals(&cfg(25.0, 0.0), &tl, 500.0, &mut Rng::new(6));
        assert!(beats.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn timeline_lookup() {
        let plan = FailurePlan::merge([
            FailurePlan::degraded(3, 10.0, 2.0, 20.0),
            FailurePlan::crash_recover(3, 50.0, 25.0),
        ]);
        let tl = ConditionTimeline::from_plan(&plan, 3);
        assert_eq!(tl.at(0.0), NodeCondition::Up);
        assert_eq!(tl.at(15.0), NodeCondition::Degraded(2.0));
        assert_eq!(tl.at(40.0), NodeCondition::Up);
        assert_eq!(tl.at(60.0), NodeCondition::Down);
        assert_eq!(tl.at(80.0), NodeCondition::Up);
        assert_eq!(tl.next_serving_after(55.0), Some(75.0));
        assert_eq!(tl.next_serving_after(100.0), None);
    }
}
