//! Node-health subsystem: imperfect failure detection for the serving
//! engine.
//!
//! The paper's downtime metric starts at *detection*, but a perfect
//! oracle detector hides the hard part of edge resilience: real
//! monitors watch a lossy heartbeat channel and must trade detection
//! latency against false failovers, gray failures degrade a node
//! without killing it, and a recovered node is only worth
//! repartitioning back onto once it stops flapping. This module models
//! that whole loop:
//!
//! - [`heartbeat`] — the simulated channel: per-node beat emission
//!   driven by the ground-truth [`crate::cluster::NodeCondition`]
//!   timeline, with seeded jitter, loss and optional blackout windows.
//! - [`detector`] — the [`HealthDetector`] trait with the classic
//!   fixed-timeout detector and a phi-accrual detector whose suspicion
//!   adapts to the observed inter-arrival history.
//! - [`reintegrate`] — the quarantine hysteresis gate: one failover per
//!   suspicion episode, one reintegration per sustained stability
//!   window, flaps reset the clock silently.
//! - [`monitor`] — ties them together per node and emits the
//!   [`HealthEvent`] stream (failovers, false positives included, and
//!   quarantine-gated recoveries) that
//!   [`crate::coordinator::engine::serve`] consumes in
//!   [`crate::coordinator::engine::HealthMode::Monitored`] runs.
//!
//! Everything is virtual-time and seeded; no wall clocks, no threads —
//! a (plan, config) pair always produces the same event stream.

pub mod detector;
pub mod heartbeat;
pub mod monitor;
pub mod reintegrate;

pub use detector::{DetectorKind, FixedTimeoutDetector, HealthDetector, PhiAccrualDetector};
pub use heartbeat::{arrivals, ConditionTimeline, HeartbeatConfig};
pub use monitor::{simulate, HealthConfig, HealthEvent, HealthEventKind};
pub use reintegrate::{ReAction, ReintegrationController};
