//! The health monitor: per-node heartbeat observation, suspicion
//! checks, gray-failure estimation and quarantine gating, producing the
//! stream of [`HealthEvent`]s the serving engine reacts to.
//!
//! The monitor never sees ground truth. It sees heartbeat *arrivals*
//! (jittered, lossy, possibly blacked out) and periodically asks its
//! [`HealthDetector`](super::HealthDetector) how suspicious the silence
//! is, so its output can be late, can miss short flaps between checks,
//! and — crucially — can be wrong: a loss burst or a monitoring-path
//! blackout produces a [`HealthEventKind::Failover`] for a perfectly
//! healthy node (`false_positive = true`), which the engine later rolls
//! back when the [`ReintegrationController`] clears it.
//!
//! Gray failures are *estimated*, not observed: a degraded node's beats
//! stretch by its slowdown, so the monitor compares the mean of its
//! recent inter-arrival window to the nominal interval and fails over
//! once the estimate crosses [`HealthConfig::failover_slowdown`]. Below
//! the threshold the node is left in the path, slowing its stage in
//! place — failing over a mildly degraded node would trade a small
//! latency stretch for a full downtime window.
//!
//! Everything is virtual-time and seeded, so a (plan, config) pair
//! always yields the same event stream — the serving experiments stay
//! reproducible down to the byte.

use std::collections::VecDeque;

use crate::cluster::failure::{FailurePlan, NodeCondition};
use crate::util::rng::Rng;

use super::detector::DetectorKind;
use super::heartbeat::{arrivals, ConditionTimeline, HeartbeatConfig};
use super::reintegrate::{ReAction, ReintegrationController};

/// Monitored-health configuration.
#[derive(Debug, Clone)]
pub struct HealthConfig {
    pub heartbeat: HeartbeatConfig,
    pub detector: DetectorKind,
    /// Estimated slowdown at or above which a degraded node is failed
    /// over (`f64::INFINITY` never fails over on degradation alone).
    pub failover_slowdown: f64,
    /// How long a cleared node must stay clean before reintegration.
    pub quarantine_ms: f64,
    /// Sliding window (beats) for the slowdown estimate.
    pub slowdown_window: usize,
    /// Seed of the heartbeat channel randomness (jitter/loss draws).
    pub seed: u64,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            heartbeat: HeartbeatConfig::default(),
            detector: DetectorKind::PhiAccrual {
                threshold: 8.0,
                window: 64,
                min_std_ms: 0.5,
            },
            failover_slowdown: 3.0,
            quarantine_ms: 100.0,
            slowdown_window: 8,
            seed: 0x4845_414c,
        }
    }
}

impl HealthConfig {
    /// A fixed-timeout configuration (the classic detector, but now over
    /// the imperfect channel).
    pub fn fixed_timeout(timeout_ms: f64) -> HealthConfig {
        HealthConfig {
            detector: DetectorKind::FixedTimeout { timeout_ms },
            ..HealthConfig::default()
        }
    }

    /// How far the monitor must simulate so that everything scheduled in
    /// `plan` (plus a trailing detection + quarantine) is observed.
    pub fn horizon_for(&self, plan: &FailurePlan, last_arrival_ms: f64) -> f64 {
        let blackout_end = self.heartbeat.blackout.map(|(_, e)| e).unwrap_or(0.0);
        plan.last_event_ms()
            .max(last_arrival_ms)
            .max(blackout_end)
            + self.quarantine_ms
            + 50.0 * self.heartbeat.interval_ms
    }
}

/// What the monitor concluded.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum HealthEventKind {
    /// The node should be failed over away from. `false_positive` is the
    /// ground-truth verdict (node was `Up` at detection time), recorded
    /// for evaluation — the controller of course cannot see it.
    Failover { false_positive: bool },
    /// The node was stable through quarantine: repartition back onto it
    /// (for a false positive this is the rollback).
    Recovery,
}

/// One monitor conclusion about one node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HealthEvent {
    pub at_ms: f64,
    pub node: usize,
    pub kind: HealthEventKind,
}

/// Simulate the monitor over `[0, horizon_ms)` for nodes `1..=num_nodes`
/// of one replica, returning the time-sorted health events.
pub fn simulate(
    cfg: &HealthConfig,
    plan: &FailurePlan,
    num_nodes: usize,
    horizon_ms: f64,
) -> Vec<HealthEvent> {
    let mut root = Rng::new(cfg.seed);
    let mut events = Vec::new();
    let interval = cfg.heartbeat.interval_ms;
    for node in 1..=num_nodes {
        let mut rng = root.fork(node as u64);
        let timeline = ConditionTimeline::from_plan(plan, node);
        let beats = arrivals(&cfg.heartbeat, &timeline, horizon_ms, &mut rng);
        let mut detector = cfg.detector.build(interval);
        let mut gate = ReintegrationController::new(cfg.quarantine_ms);
        let mut recent: VecDeque<f64> = VecDeque::with_capacity(cfg.slowdown_window + 1);
        let mut last_beat = 0.0;
        let mut next = 0usize;
        let mut det_suspected = false;
        // Check suspicion on the heartbeat grid (the natural cadence of a
        // monitor that wakes per expected beat).
        let mut t = interval;
        while t <= horizon_ms {
            while next < beats.len() && beats[next] <= t {
                let b = beats[next];
                detector.observe(b);
                // A gap spanning detector-flagged silence measures the
                // outage, not the node's serving cadence — feeding it
                // into the slowdown estimate would make a freshly
                // recovered node look degraded and stall its quarantine
                // clock. Gray-failure stretches are NOT detector-flagged
                // at push time (beats keep flowing), so they still
                // accumulate here.
                if det_suspected {
                    det_suspected = false;
                } else {
                    recent.push_back(b - last_beat);
                    while recent.len() > cfg.slowdown_window {
                        recent.pop_front();
                    }
                }
                last_beat = b;
                next += 1;
            }
            let est_slowdown = if recent.len() >= 3 {
                recent.iter().sum::<f64>() / recent.len() as f64 / interval
            } else {
                1.0
            };
            let det_suspect = detector.is_suspect(t);
            det_suspected = det_suspected || det_suspect;
            let suspect = det_suspect || est_slowdown >= cfg.failover_slowdown;
            match gate.observe(t, suspect) {
                ReAction::Failover => events.push(HealthEvent {
                    at_ms: t,
                    node,
                    kind: HealthEventKind::Failover {
                        false_positive: timeline.at(t) == NodeCondition::Up,
                    },
                }),
                ReAction::Reintegrate => events.push(HealthEvent {
                    at_ms: t,
                    node,
                    kind: HealthEventKind::Recovery,
                }),
                ReAction::None => {}
            }
            t += interval;
        }
    }
    events.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms).then(a.node.cmp(&b.node)));
    events
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic channel: no jitter, no loss.
    fn clean(detector: DetectorKind, quarantine_ms: f64) -> HealthConfig {
        HealthConfig {
            heartbeat: HeartbeatConfig {
                interval_ms: 10.0,
                jitter_ms: 0.0,
                loss_prob: 0.0,
                blackout: None,
            },
            detector,
            failover_slowdown: 3.0,
            quarantine_ms,
            slowdown_window: 8,
            seed: 1,
        }
    }

    fn fixed(timeout_ms: f64, quarantine_ms: f64) -> HealthConfig {
        clean(DetectorKind::FixedTimeout { timeout_ms }, quarantine_ms)
    }

    #[test]
    fn healthy_cluster_is_quiet() {
        let ev = simulate(&fixed(25.0, 50.0), &FailurePlan::none(), 4, 1000.0);
        assert!(ev.is_empty(), "{ev:?}");
    }

    #[test]
    fn crash_is_detected_then_reintegrated_after_quarantine() {
        // Down @50, up @130. Beats 10..40, then 140, 150, ...
        let plan = FailurePlan::crash_recover(3, 50.0, 80.0);
        let ev = simulate(&fixed(25.0, 100.0), &plan, 4, 1000.0);
        assert_eq!(ev.len(), 2, "{ev:?}");
        assert_eq!(ev[0].node, 3);
        assert_eq!(ev[0].kind, HealthEventKind::Failover { false_positive: false });
        // last beat 40, timeout 25 → first suspect check at 70.
        assert!((ev[0].at_ms - 70.0).abs() < 1e-9, "{ev:?}");
        // beats resume at 140 → cleared at the 140 check → quarantine
        // until 240.
        assert_eq!(ev[1].kind, HealthEventKind::Recovery);
        assert!((ev[1].at_ms - 240.0).abs() < 1e-9, "{ev:?}");
    }

    #[test]
    fn blackout_produces_false_positive_and_rollback() {
        let mut cfg = fixed(25.0, 40.0);
        cfg.heartbeat.blackout = Some((100.0, 160.0));
        let ev = simulate(&cfg, &FailurePlan::none(), 2, 1000.0);
        // Both nodes: FP failover at 120 (last beat 90), recovery at
        // 160-beat check + 40 ms quarantine = 200.
        assert_eq!(ev.len(), 4, "{ev:?}");
        for e in &ev[..2] {
            assert_eq!(e.kind, HealthEventKind::Failover { false_positive: true });
            assert!((e.at_ms - 120.0).abs() < 1e-9, "{ev:?}");
        }
        for e in &ev[2..] {
            assert_eq!(e.kind, HealthEventKind::Recovery);
            assert!((e.at_ms - 200.0).abs() < 1e-9, "{ev:?}");
        }
    }

    #[test]
    fn flapping_node_stays_quarantined_until_stable() {
        // Down 50–90, up 90–190, down 190–230, up from 230 on.
        let plan = FailurePlan::intermittent(3, 50.0, 40.0, 100.0, 2);
        let ev = simulate(&fixed(25.0, 150.0), &plan, 4, 1000.0);
        let node3: Vec<&HealthEvent> = ev.iter().filter(|e| e.node == 3).collect();
        // One failover (the second outage lands inside quarantine and
        // resets it silently), one reintegration once genuinely stable.
        assert_eq!(node3.len(), 2, "{ev:?}");
        assert_eq!(node3[0].kind, HealthEventKind::Failover { false_positive: false });
        assert!((node3[0].at_ms - 70.0).abs() < 1e-9);
        assert_eq!(node3[1].kind, HealthEventKind::Recovery);
        // Beats resume at 240 after the second outage; stable 150 ms → 390.
        assert!((node3[1].at_ms - 390.0).abs() < 1e-9, "{ev:?}");
    }

    #[test]
    fn heavy_degradation_crosses_the_failover_threshold() {
        // 5× slowdown: beats every 50 ms, est slowdown → 5 ≥ 3.
        let plan = FailurePlan::degraded(2, 100.0, 5.0, 600.0);
        let ev = simulate(&fixed(1e6, 50.0), &plan, 4, 2000.0);
        let node2: Vec<&HealthEvent> = ev.iter().filter(|e| e.node == 2).collect();
        assert!(!node2.is_empty(), "5x degradation must fail over: {ev:?}");
        assert_eq!(
            node2[0].kind,
            HealthEventKind::Failover { false_positive: false },
            "degraded ground truth is not a false positive"
        );
        assert!(node2[0].at_ms > 100.0);
        // After the window ends (t = 700) the estimate drains and the
        // node reintegrates.
        assert!(
            node2.iter().any(|e| e.kind == HealthEventKind::Recovery && e.at_ms > 700.0),
            "{ev:?}"
        );
    }

    #[test]
    fn mild_degradation_stays_in_the_path() {
        // 1.5× slowdown: beats every 15 ms < timeout 35, est 1.5 < 3.
        let plan = FailurePlan::degraded(2, 100.0, 1.5, 600.0);
        let ev = simulate(&fixed(35.0, 50.0), &plan, 4, 2000.0);
        assert!(ev.is_empty(), "mild degradation must not fail over: {ev:?}");
    }

    #[test]
    fn phi_detects_crash_and_lower_threshold_is_no_slower() {
        let plan = FailurePlan::crash(3, 200.0);
        let slow = clean(
            DetectorKind::PhiAccrual { threshold: 8.0, window: 32, min_std_ms: 0.5 },
            50.0,
        );
        let fast = clean(
            DetectorKind::PhiAccrual { threshold: 1.0, window: 32, min_std_ms: 0.5 },
            50.0,
        );
        let ev_slow = simulate(&slow, &plan, 4, 2000.0);
        let ev_fast = simulate(&fast, &plan, 4, 2000.0);
        assert_eq!(ev_slow.len(), 1, "{ev_slow:?}");
        assert_eq!(ev_fast.len(), 1, "{ev_fast:?}");
        assert!(ev_slow[0].at_ms > 200.0);
        assert!(ev_fast[0].at_ms <= ev_slow[0].at_ms);
    }

    #[test]
    fn same_seed_same_events_under_noise() {
        let mut cfg = fixed(25.0, 50.0);
        cfg.heartbeat.jitter_ms = 3.0;
        cfg.heartbeat.loss_prob = 0.15;
        cfg.seed = 99;
        let plan = FailurePlan::crash_recover(2, 300.0, 200.0);
        let a = simulate(&cfg, &plan, 4, 3000.0);
        let b = simulate(&cfg, &plan, 4, 3000.0);
        assert_eq!(a, b);
    }

    #[test]
    fn horizon_covers_plan_and_quarantine() {
        let cfg = fixed(25.0, 100.0);
        let plan = FailurePlan::crash_recover(1, 400.0, 50.0);
        let h = cfg.horizon_for(&plan, 600.0);
        assert!(h >= 600.0 + 100.0, "h = {h}");
        let ev = simulate(&cfg, &plan, 2, h);
        assert!(
            ev.iter().any(|e| e.kind == HealthEventKind::Recovery),
            "recovery must land inside the default horizon: {ev:?}"
        );
    }
}
