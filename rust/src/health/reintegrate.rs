//! Stability-aware reintegration: when is a recovered node safe to serve
//! on again?
//!
//! Repartitioning back onto a node is itself a downtime event, so doing
//! it eagerly on the first clean heartbeat is exactly wrong for flapping
//! nodes — every flap would pay a failover *and* a reintegration. The
//! [`ReintegrationController`] is a per-node hysteresis state machine:
//!
//! ```text
//!   Trusted --suspect--> Suspected --clear--> Quarantine --stable for
//!      ^                     ^                    |        quarantine_ms
//!      |                     +-----suspect--------+            |
//!      +------------------- reintegrate <---------------------+
//! ```
//!
//! The `Trusted → Suspected` edge is the (single) failover trigger; the
//! `Quarantine → Trusted` edge is the (single) reintegration trigger. A
//! flap during quarantine silently resets the clock — the node is
//! already failed over, so there is nothing new to react to.

/// Reintegration state of one monitored node.
#[derive(Debug, Clone, Copy, PartialEq)]
enum ReState {
    /// In the serving path.
    Trusted,
    /// Failed over away from; suspicion still active.
    Suspected,
    /// Suspicion cleared at `since_ms`; waiting out the stability window.
    Quarantine { since_ms: f64 },
}

/// What the controller wants done after an observation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReAction {
    /// Nothing changed.
    None,
    /// Node newly suspected: fail over away from it.
    Failover,
    /// Node stable for the full quarantine window: repartition back on.
    Reintegrate,
}

/// Per-node hysteresis gate between the detector and the failover
/// controller.
#[derive(Debug, Clone)]
pub struct ReintegrationController {
    quarantine_ms: f64,
    state: ReState,
}

impl ReintegrationController {
    pub fn new(quarantine_ms: f64) -> ReintegrationController {
        ReintegrationController {
            quarantine_ms: quarantine_ms.max(0.0),
            state: ReState::Trusted,
        }
    }

    /// Whether the node is currently in the serving path.
    pub fn is_trusted(&self) -> bool {
        self.state == ReState::Trusted
    }

    /// Feed one suspicion observation at `now_ms` (monotone times).
    pub fn observe(&mut self, now_ms: f64, suspect: bool) -> ReAction {
        match (self.state, suspect) {
            (ReState::Trusted, true) => {
                self.state = ReState::Suspected;
                ReAction::Failover
            }
            (ReState::Suspected, false) => {
                self.state = ReState::Quarantine { since_ms: now_ms };
                // quarantine_ms == 0 means "reintegrate on first clear".
                if self.quarantine_ms <= 0.0 {
                    self.state = ReState::Trusted;
                    ReAction::Reintegrate
                } else {
                    ReAction::None
                }
            }
            (ReState::Quarantine { .. }, true) => {
                // Flap: stay failed over, restart the stability clock on
                // the next clear observation.
                self.state = ReState::Suspected;
                ReAction::None
            }
            (ReState::Quarantine { since_ms }, false) => {
                if now_ms - since_ms >= self.quarantine_ms {
                    self.state = ReState::Trusted;
                    ReAction::Reintegrate
                } else {
                    ReAction::None
                }
            }
            _ => ReAction::None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failover_once_then_reintegrate_after_stability() {
        let mut c = ReintegrationController::new(50.0);
        assert!(c.is_trusted());
        assert_eq!(c.observe(10.0, true), ReAction::Failover);
        assert_eq!(c.observe(20.0, true), ReAction::None, "no duplicate failover");
        assert!(!c.is_trusted());
        assert_eq!(c.observe(30.0, false), ReAction::None, "quarantine starts");
        assert_eq!(c.observe(60.0, false), ReAction::None, "30 ms stable < 50");
        assert_eq!(c.observe(80.0, false), ReAction::Reintegrate, "50 ms stable");
        assert!(c.is_trusted());
    }

    #[test]
    fn flap_resets_the_stability_clock() {
        let mut c = ReintegrationController::new(50.0);
        assert_eq!(c.observe(0.0, true), ReAction::Failover);
        assert_eq!(c.observe(10.0, false), ReAction::None); // quarantine @10
        assert_eq!(c.observe(40.0, true), ReAction::None); // flap, no 2nd failover
        assert_eq!(c.observe(70.0, false), ReAction::None); // quarantine @70
        assert_eq!(
            c.observe(110.0, false),
            ReAction::None,
            "old window must not count: only 40 ms since the flap cleared"
        );
        assert_eq!(c.observe(120.0, false), ReAction::Reintegrate);
    }

    #[test]
    fn zero_quarantine_reintegrates_immediately() {
        let mut c = ReintegrationController::new(0.0);
        assert_eq!(c.observe(5.0, true), ReAction::Failover);
        assert_eq!(c.observe(6.0, false), ReAction::Reintegrate);
        assert!(c.is_trusted());
    }

    #[test]
    fn trusted_stays_quiet_while_healthy() {
        let mut c = ReintegrationController::new(50.0);
        for t in 0..100 {
            assert_eq!(c.observe(t as f64, false), ReAction::None);
        }
        assert!(c.is_trusted());
    }

    #[test]
    fn can_fail_over_again_after_reintegration() {
        let mut c = ReintegrationController::new(10.0);
        assert_eq!(c.observe(0.0, true), ReAction::Failover);
        assert_eq!(c.observe(5.0, false), ReAction::None);
        assert_eq!(c.observe(15.0, false), ReAction::Reintegrate);
        assert_eq!(c.observe(20.0, true), ReAction::Failover, "second cycle");
    }
}
