//! Failure detectors over a heartbeat arrival stream.
//!
//! A [`HealthDetector`] turns "when did I last hear from this node" into
//! a scalar *suspicion level*; the node is suspected once the level
//! crosses the detector's threshold. Two implementations:
//!
//! - [`FixedTimeoutDetector`] — the classic model the seed shipped:
//!   suspicion is elapsed-since-last-beat over a fixed timeout. Cheap and
//!   predictable, but one timeout must fit both a jittery WAN link and a
//!   quiet LAN.
//! - [`PhiAccrualDetector`] — the phi-accrual detector (Hayashibara et
//!   al.): suspicion is `phi = -log10(P(a beat would arrive this late))`
//!   under a normal model fitted to the recent inter-arrival history, so
//!   the threshold adapts to the observed channel. Implemented with the
//!   standard logistic approximation of the normal tail, no `erf` needed.
//!
//! Detectors are *per node* and fed by the monitor; they never see ground
//! truth, which is precisely why they can be late or flat-out wrong
//! (false positives under loss/jitter bursts).

use std::collections::VecDeque;

/// Suspicion source for one monitored node.
pub trait HealthDetector {
    /// A heartbeat from the node arrived at `at_ms` (monotone times).
    fn observe(&mut self, at_ms: f64);
    /// Suspicion level at `now_ms` (unitless; compare to `threshold`).
    fn suspicion(&self, now_ms: f64) -> f64;
    /// Level at or above which the node is suspected failed.
    fn threshold(&self) -> f64;
    /// Whether the node is suspected at `now_ms`.
    fn is_suspect(&self, now_ms: f64) -> bool {
        self.suspicion(now_ms) >= self.threshold()
    }
}

/// Detector choice + parameters (config-level, buildable per node).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DetectorKind {
    /// Suspect after `timeout_ms` of silence.
    FixedTimeout { timeout_ms: f64 },
    /// Suspect once phi (see [`PhiAccrualDetector`]) reaches `threshold`,
    /// estimated over a sliding `window` of inter-arrival samples with a
    /// `min_std_ms` floor on the fitted deviation.
    PhiAccrual {
        threshold: f64,
        window: usize,
        min_std_ms: f64,
    },
}

impl DetectorKind {
    /// Instantiate one detector for a node; `nominal_interval_ms` seeds
    /// the phi detector's bootstrap estimate before history accumulates.
    pub fn build(&self, nominal_interval_ms: f64) -> Box<dyn HealthDetector> {
        match *self {
            DetectorKind::FixedTimeout { timeout_ms } => {
                Box::new(FixedTimeoutDetector::new(timeout_ms))
            }
            DetectorKind::PhiAccrual {
                threshold,
                window,
                min_std_ms,
            } => Box::new(PhiAccrualDetector::new(
                threshold,
                window,
                min_std_ms,
                nominal_interval_ms,
            )),
        }
    }
}

/// Suspicion = elapsed / timeout; threshold 1.
#[derive(Debug, Clone)]
pub struct FixedTimeoutDetector {
    timeout_ms: f64,
    last_ms: f64,
}

impl FixedTimeoutDetector {
    /// The node is assumed to have announced itself at t = 0.
    pub fn new(timeout_ms: f64) -> FixedTimeoutDetector {
        assert!(timeout_ms > 0.0, "timeout must be positive");
        FixedTimeoutDetector {
            timeout_ms,
            last_ms: 0.0,
        }
    }
}

impl HealthDetector for FixedTimeoutDetector {
    fn observe(&mut self, at_ms: f64) {
        self.last_ms = self.last_ms.max(at_ms);
    }

    fn suspicion(&self, now_ms: f64) -> f64 {
        (now_ms - self.last_ms).max(0.0) / self.timeout_ms
    }

    fn threshold(&self) -> f64 {
        1.0
    }
}

/// Phi-accrual detector over a sliding inter-arrival window.
#[derive(Debug, Clone)]
pub struct PhiAccrualDetector {
    threshold: f64,
    window: usize,
    min_std_ms: f64,
    /// Prior mean used until two real samples exist.
    bootstrap_ms: f64,
    intervals: VecDeque<f64>,
    last_ms: f64,
}

impl PhiAccrualDetector {
    pub fn new(
        threshold: f64,
        window: usize,
        min_std_ms: f64,
        bootstrap_ms: f64,
    ) -> PhiAccrualDetector {
        assert!(window >= 2, "phi window must hold >= 2 samples");
        PhiAccrualDetector {
            threshold,
            window,
            min_std_ms: min_std_ms.max(1e-6),
            bootstrap_ms,
            intervals: VecDeque::with_capacity(window),
            last_ms: 0.0,
        }
    }

    /// Fitted (mean, std) of the inter-arrival distribution.
    fn fit(&self) -> (f64, f64) {
        if self.intervals.len() < 2 {
            return (self.bootstrap_ms, (self.bootstrap_ms / 4.0).max(self.min_std_ms));
        }
        let n = self.intervals.len() as f64;
        let mean = self.intervals.iter().sum::<f64>() / n;
        let var = self
            .intervals
            .iter()
            .map(|x| (x - mean) * (x - mean))
            .sum::<f64>()
            / n;
        (mean, var.sqrt().max(self.min_std_ms))
    }
}

impl HealthDetector for PhiAccrualDetector {
    fn observe(&mut self, at_ms: f64) {
        let dt = at_ms - self.last_ms;
        if dt > 0.0 {
            self.intervals.push_back(dt);
            while self.intervals.len() > self.window {
                self.intervals.pop_front();
            }
            self.last_ms = at_ms;
        }
    }

    fn suspicion(&self, now_ms: f64) -> f64 {
        let elapsed = (now_ms - self.last_ms).max(0.0);
        let (mean, std) = self.fit();
        // P(beat arrives later than `elapsed`) under N(mean, std), via the
        // logistic approximation of the normal tail (as in Akka's
        // PhiAccrualFailureDetector); phi = -log10 of that.
        let y = (elapsed - mean) / std;
        let e = (-y * (1.5976 + 0.070566 * y * y)).exp();
        let p_later = (e / (1.0 + e)).max(1e-300);
        -p_later.log10()
    }

    fn threshold(&self) -> f64 {
        self.threshold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed(det: &mut dyn HealthDetector, interval: f64, n: usize) -> f64 {
        let mut t = 0.0;
        for _ in 0..n {
            t += interval;
            det.observe(t);
        }
        t
    }

    /// First suspicion time after silence begins at `from`, probing on a
    /// fine grid (None if never within the probe horizon).
    fn detection_time(det: &dyn HealthDetector, from: f64, horizon: f64) -> Option<f64> {
        let mut t = from;
        while t < from + horizon {
            if det.is_suspect(t) {
                return Some(t);
            }
            t += 0.5;
        }
        None
    }

    #[test]
    fn fixed_timeout_trips_exactly() {
        let mut d = FixedTimeoutDetector::new(25.0);
        let last = feed(&mut d, 10.0, 5);
        assert!(!d.is_suspect(last + 24.0));
        assert!(d.is_suspect(last + 25.0));
        assert!(d.suspicion(last + 50.0) > d.suspicion(last + 30.0), "monotone");
    }

    #[test]
    fn fixed_timeout_recovers_on_beat() {
        let mut d = FixedTimeoutDetector::new(20.0);
        feed(&mut d, 10.0, 3);
        assert!(d.is_suspect(70.0));
        d.observe(71.0);
        assert!(!d.is_suspect(72.0));
    }

    #[test]
    fn phi_grows_with_silence() {
        // A generous std floor keeps phi in a comparable range over the
        // probed silences (a tiny floor saturates the tail to the same
        // clamped value for every long elapsed time).
        let mut d = PhiAccrualDetector::new(3.0, 32, 5.0, 10.0);
        let last = feed(&mut d, 10.0, 20);
        let p1 = d.suspicion(last + 10.0);
        let p2 = d.suspicion(last + 20.0);
        let p3 = d.suspicion(last + 35.0);
        assert!(p1 < p2 && p2 < p3, "phi monotone in silence: {p1} {p2} {p3}");
        assert!(d.is_suspect(last + 200.0), "long silence must cross any sane threshold");
    }

    #[test]
    fn phi_on_time_beat_is_not_suspect() {
        let mut d = PhiAccrualDetector::new(2.0, 32, 0.5, 10.0);
        let last = feed(&mut d, 10.0, 20);
        // Right around the expected next beat, phi ~ 0.3 (p ~ 0.5).
        assert!(d.suspicion(last + 10.0) < 1.0);
        assert!(!d.is_suspect(last + 10.0));
    }

    #[test]
    fn lower_threshold_detects_no_later() {
        let mut fast = PhiAccrualDetector::new(1.0, 32, 0.5, 10.0);
        let mut slow = PhiAccrualDetector::new(8.0, 32, 0.5, 10.0);
        let last_f = feed(&mut fast, 10.0, 20);
        let last_s = feed(&mut slow, 10.0, 20);
        let t_fast = detection_time(&fast, last_f, 10_000.0).unwrap();
        let t_slow = detection_time(&slow, last_s, 10_000.0).unwrap();
        assert!(
            t_fast <= t_slow,
            "aggressive threshold must not detect later ({t_fast} vs {t_slow})"
        );
    }

    #[test]
    fn phi_adapts_to_slow_channels() {
        // Same silence, but one detector learned a 30 ms cadence: at
        // t_last + 35 the 10 ms-cadence detector is far more suspicious.
        let mut d10 = PhiAccrualDetector::new(3.0, 32, 0.5, 10.0);
        let mut d30 = PhiAccrualDetector::new(3.0, 32, 0.5, 10.0);
        let l10 = feed(&mut d10, 10.0, 20);
        let l30 = feed(&mut d30, 30.0, 20);
        assert!(d10.suspicion(l10 + 35.0) > d30.suspicion(l30 + 35.0));
    }

    #[test]
    fn bootstrap_before_history() {
        let d = PhiAccrualDetector::new(3.0, 32, 0.5, 10.0);
        // No beats yet: near the nominal interval nothing is suspect.
        assert!(!d.is_suspect(10.0));
        // An hour of silence is, even with only the bootstrap estimate.
        assert!(d.is_suspect(3_600_000.0));
    }

    #[test]
    fn kind_builds_both() {
        let f = DetectorKind::FixedTimeout { timeout_ms: 25.0 }.build(10.0);
        assert!((f.threshold() - 1.0).abs() < 1e-12);
        let p = DetectorKind::PhiAccrual {
            threshold: 8.0,
            window: 16,
            min_std_ms: 0.5,
        }
        .build(10.0);
        assert!((p.threshold() - 8.0).abs() < 1e-12);
        assert!(!p.is_suspect(5.0));
    }
}
