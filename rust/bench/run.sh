#!/usr/bin/env bash
# Reproducible perf sweep for the serving engine.
#
# Runs the engine-scale bench (replica axis, sequential vs sharded
# workers axis, saturation sweep) and leaves the machine-readable
# artifacts in rust/:
#
#   BENCH_engine_scale.json   replica + workers axes, saturation knee
#   BENCH_serving.json        pipelining-depth hot-path bench
#   BENCH_health.json         monitored-health serving bench
#
# Usage:
#   bench/run.sh                 # full sweep, 1M requests
#   REQUESTS=100000 bench/run.sh # smaller scale
#   WORKERS=8 bench/run.sh       # pin the sharded worker count
#   QUICK=1 bench/run.sh         # ~20k-request smoke (CI-sized)
#   SKEW=1 bench/run.sh          # add the heterogeneous-fleet skew axis
#                                # (JSQ vs weighted JSQ vs + stealing)
set -euo pipefail
cd "$(dirname "$0")/.."

REQUESTS="${REQUESTS:-1000000}"
ARGS=()
if [[ -n "${QUICK:-}" ]]; then
  ARGS+=(--quick)
else
  ARGS+=(--requests "$REQUESTS")
fi
if [[ -n "${WORKERS:-}" ]]; then
  ARGS+=(--workers "$WORKERS")
fi
if [[ -n "${SKEW:-}" ]]; then
  ARGS+=(--skew)
fi

cargo bench --bench engine_scale -- "${ARGS[@]}"
cargo bench --bench pipeline
cargo bench --bench health

echo
echo "artifacts:"
for f in BENCH_engine_scale.json BENCH_serving.json BENCH_health.json; do
  [[ -s $f ]] && echo "  $f"
done
