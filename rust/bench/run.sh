#!/usr/bin/env bash
# Reproducible perf sweep for the serving engine.
#
# Runs the engine-scale bench (replica axis, sequential vs sharded
# workers axis, saturation sweep, heap-vs-calendar queue axis) and
# leaves the machine-readable artifacts in rust/:
#
#   BENCH_engine_scale.json   replica + workers + queue axes, saturation knee
#   BENCH_serving.json        pipelining-depth hot-path bench
#   BENCH_health.json         monitored-health serving bench
#
# BENCH_engine_scale.json is also copied to the repo root so the perf
# trajectory is tracked across PRs.
#
# Usage:
#   bench/run.sh                 # full sweep, 1M requests
#   REQUESTS=100000 bench/run.sh # smaller scale
#   WORKERS=8 bench/run.sh       # pin the sharded worker count
#   QUICK=1 bench/run.sh         # ~20k-request smoke (CI-sized)
#   SKEW=1 bench/run.sh          # add the heterogeneous-fleet skew axis
#                                # (JSQ vs weighted JSQ vs + stealing)
#   QUEUE=heap bench/run.sh      # pin the event queue (heap|calendar);
#                                # unset runs calendar + a heap reference arm
set -euo pipefail
cd "$(dirname "$0")/.."

REQUESTS="${REQUESTS:-1000000}"
ARGS=()
if [[ -n "${QUICK:-}" ]]; then
  ARGS+=(--quick)
else
  ARGS+=(--requests "$REQUESTS")
fi
if [[ -n "${WORKERS:-}" ]]; then
  ARGS+=(--workers "$WORKERS")
fi
if [[ -n "${SKEW:-}" ]]; then
  ARGS+=(--skew)
fi
if [[ -n "${QUEUE:-}" ]]; then
  ARGS+=(--queue "$QUEUE")
fi

cargo bench --bench engine_scale -- "${ARGS[@]}"
cargo bench --bench pipeline
cargo bench --bench health

# Track the engine-scale trajectory at the repo root across PRs.
cp BENCH_engine_scale.json ../BENCH_engine_scale.json

echo
echo "artifacts:"
for f in BENCH_engine_scale.json BENCH_serving.json BENCH_health.json; do
  [[ -s $f ]] && echo "  $f"
done
echo "  ../BENCH_engine_scale.json (repo-root trajectory copy)"
