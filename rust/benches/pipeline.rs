//! Bench: the serving hot paths.
//!
//! Part 1 (always runs, no artifacts needed): serving throughput of the
//! event-driven engine on a deterministic synthetic 4-node pipeline under
//! saturating Poisson load — requests/sec for replica counts 1/2/4, each
//! with pipelining off (`depth 1`, the seed's one-batch-in-flight regime)
//! and on (`depth 4`). Emits machine-readable `BENCH_serving.json` for the
//! perf trajectory — including the allocations-per-event proxy (batches
//! dispatched vs step plans actually allocated, which stays at the
//! distinct-plan count thanks to the engine's PlanCache); the acceptance
//! floor is pipelined >= 2x sequential on the same single-replica
//! workload.
//!
//! Part 2 (needs `make artifacts`): end-to-end pipeline execution per
//! technique over the real PJRT block executables (regenerates the latency
//! regime behind Fig 7 / Table V).

use continuer::cluster::failure::Detector;
use continuer::cluster::sim::EdgeCluster;
use continuer::config::{Config, Objectives};
use continuer::coordinator::batcher::BatcherConfig;
use continuer::coordinator::engine::{serve, EngineConfig, Execution, HealthMode, SyntheticBackend};
use continuer::coordinator::estimator::MetricsSource;
use continuer::coordinator::router::RoutePolicy;
use continuer::coordinator::scheduler::CandidateMetrics;
use continuer::coordinator::Failover;
use continuer::dnn::variants::Technique;
use continuer::exper::{default_artifacts_dir, require_artifacts};
use continuer::runtime::{ArtifactStore, Engine, HostTensor};
use continuer::util::bench::{f, Table};
use continuer::util::json::{obj, Json};
use continuer::workload::{generate, Arrival};

/// Stub predictions: the synthetic bench has no fitted models.
struct StubMetrics;

impl MetricsSource for StubMetrics {
    fn candidate_metrics(&self, failed: usize) -> anyhow::Result<Vec<CandidateMetrics>> {
        Ok(vec![CandidateMetrics {
            technique: Technique::SkipConnection(failed),
            accuracy: 85.0,
            latency_ms: 25.0,
            downtime_ms: 3.0,
        }])
    }

    fn reinstate_ms(&self) -> f64 {
        1.0
    }
}

struct ServingCase {
    throughput_rps: f64,
    max_in_flight: usize,
    events_processed: usize,
    batches_dispatched: usize,
    plans_allocated: usize,
    plan_cache_hits: usize,
}

fn serving_case(replicas: usize, depth: usize) -> ServingCase {
    const NODES: usize = 4;
    const STAGE_MS: f64 = 5.0;
    const HOP_MS: f64 = 1.0;
    let mut backends: Vec<SyntheticBackend> = (0..replicas)
        .map(|_| SyntheticBackend::uniform(NODES, STAGE_MS, HOP_MS))
        .collect();
    let mut failovers: Vec<Failover> = (0..replicas)
        .map(|_| Failover::new(Objectives::default()))
        .collect();
    let cfg = EngineConfig {
        batcher: BatcherConfig::new(vec![1], 2.0, 1),
        health: HealthMode::Oracle(Detector::default()),
        deadline_ms: None,
        pipeline_depth: depth,
        route: RoutePolicy::JoinShortestQueue,
        decision_ms_override: Some(1.5),
        record_completions: false,
        speed_factors: Vec::new(),
        steal: false,
        event_queue: Default::default(),
        execution: Execution::Sequential,
        deployment: Default::default(),
    };
    // Saturating Poisson load: ~1 ms inter-arrival against a 23 ms path.
    let requests = generate(400, Arrival::Poisson { rate_rps: 1000.0 }, 16, 42);
    let inputs = HostTensor::zeros(vec![16, 4]);
    let report = serve(
        &mut backends,
        &StubMetrics,
        &mut failovers,
        &cfg,
        &requests,
        &inputs,
        &[],
    )
    .unwrap();
    assert_eq!(report.completed_count, 400, "bench must serve everything");
    ServingCase {
        throughput_rps: report.throughput_rps,
        max_in_flight: report.max_in_flight,
        events_processed: report.events_processed,
        batches_dispatched: report.batches_dispatched,
        plans_allocated: report.plan_cache_misses,
        plan_cache_hits: report.plan_cache_hits,
    }
}

fn serving_bench() {
    let mut t = Table::new(
        "bench: serving throughput — synthetic 4-node pipeline, saturating poisson",
        &[
            "replicas",
            "depth",
            "throughput rps",
            "peak in flight",
            "batches",
            "plans alloc'd",
        ],
    );
    let mut cases = Vec::new();
    let mut seed_equivalent_rps = 0.0;
    let mut pipelined_1r_rps = 0.0;
    for replicas in [1usize, 2, 4] {
        for depth in [1usize, 4] {
            let c = serving_case(replicas, depth);
            if replicas == 1 && depth == 1 {
                seed_equivalent_rps = c.throughput_rps;
            }
            if replicas == 1 && depth == 4 {
                pipelined_1r_rps = c.throughput_rps;
            }
            t.row(&[
                replicas.to_string(),
                depth.to_string(),
                f(c.throughput_rps, 1),
                c.max_in_flight.to_string(),
                c.batches_dispatched.to_string(),
                c.plans_allocated.to_string(),
            ]);
            // batches_dispatched vs plans_allocated is the allocations-
            // per-event proxy: plans allocated stays at the distinct-plan
            // count (1 per replica here) however many batches dispatch.
            cases.push(obj(&[
                ("replicas", replicas.into()),
                ("pipeline_depth", depth.into()),
                ("throughput_rps", c.throughput_rps.into()),
                ("max_in_flight", c.max_in_flight.into()),
                ("events_processed", c.events_processed.into()),
                ("batches_dispatched", c.batches_dispatched.into()),
                ("plans_allocated", c.plans_allocated.into()),
                ("plan_cache_hits", c.plan_cache_hits.into()),
                (
                    "plan_allocs_per_batch",
                    (c.plans_allocated as f64 / c.batches_dispatched.max(1) as f64).into(),
                ),
            ]));
        }
    }
    t.print();

    let speedup = pipelined_1r_rps / seed_equivalent_rps.max(1e-9);
    println!(
        "pipelined (1 replica, depth 4) vs seed one-batch-in-flight: {:.2}x\n",
        speedup
    );
    let out = obj(&[
        ("bench", "serving".into()),
        ("nodes", 4usize.into()),
        ("stage_ms", 5.0.into()),
        ("hop_ms", 1.0.into()),
        ("requests", 400usize.into()),
        ("arrival", "poisson 1000 rps".into()),
        ("cases", Json::Arr(cases)),
        ("seed_equivalent_rps", seed_equivalent_rps.into()),
        ("pipelined_speedup_vs_seed", speedup.into()),
    ]);
    let path = "BENCH_serving.json";
    std::fs::write(path, out.to_string()).unwrap();
    println!("wrote {path}");
}

fn real_pipeline_bench(cfg: &Config) {
    let engine = Engine::cpu().unwrap();
    let store = ArtifactStore::open(&cfg.artifacts_dir).unwrap();

    for name in ["resnet32", "mobilenetv2"] {
        let Ok(meta) = store.model(name) else { continue };
        let cluster = EdgeCluster::new(&engine, &store, meta, cfg.link.clone(), 0);
        let (images, _) = store.test_set().unwrap();
        let x1 = images.slice0(0, 1).unwrap();

        let mid_exit = meta.exit_nodes[meta.exit_nodes.len() / 2];
        let mid_skip = meta.skippable_nodes[meta.skippable_nodes.len() / 2];
        let cases = [
            ("full pipeline", Technique::Repartition, None),
            ("repartition (n3 down)", Technique::Repartition, Some(3)),
            ("early-exit (mid)", Technique::EarlyExit(mid_exit), Some(mid_exit + 1)),
            ("skip (mid)", Technique::SkipConnection(mid_skip), Some(mid_skip)),
        ];
        let mut t = Table::new(
            &format!("bench: pipeline latency, batch 1 — {name}"),
            &["path", "compute ms", "network ms", "total ms"],
        );
        for (label, tech, failed) in cases {
            let (c, n) = cluster
                .measure_latency_split(tech, failed, &x1, 10)
                .unwrap();
            t.row(&[label.to_string(), f(c, 2), f(n, 2), f(c + n, 2)]);
        }
        t.print();

        // Batched throughput (batch 32): requests/sec through the full
        // pipeline — the dynamic batcher's payoff.
        let x32 = images.slice0(0, 32).unwrap();
        let steps =
            continuer::cluster::sim::steps_for(meta, Technique::Repartition, None);
        cluster.execute_steps(&steps, &x32).unwrap(); // warmup/compile
        let t0 = std::time::Instant::now();
        let reps = 5;
        for _ in 0..reps {
            cluster.execute_steps(&steps, &x32).unwrap();
        }
        let secs = t0.elapsed().as_secs_f64();
        let (c1, n1) = cluster
            .measure_latency_split(Technique::Repartition, None, &x1, 10)
            .unwrap();
        println!(
            "{name}: batch-32 throughput {:.1} img/s vs batch-1 {:.1} img/s\n",
            (reps * 32) as f64 / secs,
            1e3 / (c1 + n1)
        );
    }
}

fn main() {
    serving_bench();

    let mut cfg = Config::default();
    cfg.artifacts_dir = default_artifacts_dir();
    if require_artifacts(&cfg.artifacts_dir).is_err() {
        eprintln!("skipping real-pipeline bench: run `make artifacts` first");
        return;
    }
    real_pipeline_bench(&cfg);
}
