//! Bench: the serving hot path — end-to-end pipeline execution per
//! technique over the real PJRT block executables (regenerates the latency
//! regime behind Fig 7 / Table V). Needs `make artifacts`; exits with a
//! message otherwise.

use continuer::cluster::sim::EdgeCluster;
use continuer::config::Config;
use continuer::dnn::variants::Technique;
use continuer::exper::{default_artifacts_dir, require_artifacts};
use continuer::runtime::{ArtifactStore, Engine};
use continuer::util::bench::{f, Table};

fn main() {
    let mut cfg = Config::default();
    cfg.artifacts_dir = default_artifacts_dir();
    if require_artifacts(&cfg.artifacts_dir).is_err() {
        eprintln!("skipping pipeline bench: run `make artifacts` first");
        return;
    }
    let engine = Engine::cpu().unwrap();
    let store = ArtifactStore::open(&cfg.artifacts_dir).unwrap();

    for name in ["resnet32", "mobilenetv2"] {
        let Ok(meta) = store.model(name) else { continue };
        let cluster = EdgeCluster::new(&engine, &store, meta, cfg.link.clone(), 0);
        let (images, _) = store.test_set().unwrap();
        let x1 = images.slice0(0, 1).unwrap();

        let mid_exit = meta.exit_nodes[meta.exit_nodes.len() / 2];
        let mid_skip = meta.skippable_nodes[meta.skippable_nodes.len() / 2];
        let cases = [
            ("full pipeline", Technique::Repartition, None),
            ("repartition (n3 down)", Technique::Repartition, Some(3)),
            ("early-exit (mid)", Technique::EarlyExit(mid_exit), Some(mid_exit + 1)),
            ("skip (mid)", Technique::SkipConnection(mid_skip), Some(mid_skip)),
        ];
        let mut t = Table::new(
            &format!("bench: pipeline latency, batch 1 — {name}"),
            &["path", "compute ms", "network ms", "total ms"],
        );
        for (label, tech, failed) in cases {
            let (c, n) = cluster
                .measure_latency_split(tech, failed, &x1, 10)
                .unwrap();
            t.row(&[label.to_string(), f(c, 2), f(n, 2), f(c + n, 2)]);
        }
        t.print();

        // Batched throughput (batch 32): requests/sec through the full
        // pipeline — the dynamic batcher's payoff.
        let x32 = images.slice0(0, 32).unwrap();
        let steps =
            continuer::cluster::sim::steps_for(meta, Technique::Repartition, None);
        cluster.execute_steps(&steps, &x32).unwrap(); // warmup/compile
        let t0 = std::time::Instant::now();
        let reps = 5;
        for _ in 0..reps {
            cluster.execute_steps(&steps, &x32).unwrap();
        }
        let secs = t0.elapsed().as_secs_f64();
        let (c1, n1) = cluster
            .measure_latency_split(Technique::Repartition, None, &x1, 10)
            .unwrap();
        println!(
            "{name}: batch-32 throughput {:.1} img/s vs batch-1 {:.1} img/s\n",
            (reps * 32) as f64 / secs,
            1e3 / (c1 + n1)
        );
    }
}
