//! Bench: downtime, both modeled and paid.
//!
//! Part 1 (synthetic, no artifacts — always runs, smoke-run in CI): the
//! repartition deployment axis. The same 4-node pipeline, crash and
//! request stream served under the three deployment modes —
//! `Instantaneous` (the legacy free swap), `BreakBeforeMake` (the
//! modeled transfer + warm-up span is paid as a dispatch stall) and
//! `MakeBeforeBreak` (the span is hidden behind a repartition-free
//! fallback; zero stall) — reporting the downtime split and the engine
//! wall time per run for each. Emits `BENCH_downtime.json`.
//!
//! Part 2 (needs `make artifacts`): the failover decision path
//! end-to-end (predictor queries + scheduler selection) — the measured
//! basis of Table VIII.

use continuer::baselines::AlwaysRepartition;
use continuer::cluster::failure::{Detector, FailurePlan};
use continuer::cluster::link::LinkModel;
use continuer::config::Config;
use continuer::coordinator::batcher::BatcherConfig;
use continuer::coordinator::engine::{
    serve, DeploymentConfig, EngineConfig, Execution, HealthMode, SyntheticBackend,
};
use continuer::coordinator::estimator::{Estimator, StaticMetrics};
use continuer::coordinator::failover::Failover;
use continuer::coordinator::profiler::DowntimeTable;
use continuer::coordinator::router::RoutePolicy;
use continuer::coordinator::service::DeployMode;
use continuer::exper::{default_artifacts_dir, require_artifacts};
use continuer::predict::{AccuracyModel, GbdtParams, LatencyModel, LayerSample};
use continuer::runtime::{ArtifactStore, HostTensor};
use continuer::util::bench::{bench, f, Table};
use continuer::util::json::{obj, Json};
use continuer::workload::{generate, Arrival};

fn deploy_case(mode: DeployMode) -> (f64, f64, f64, f64) {
    let cfg = EngineConfig {
        batcher: BatcherConfig::new(vec![1], 2.0, 1),
        health: HealthMode::Oracle(Detector::default()),
        deadline_ms: None,
        pipeline_depth: 2,
        route: RoutePolicy::RoundRobin,
        decision_ms_override: Some(2.0),
        record_completions: false,
        speed_factors: Vec::new(),
        steal: false,
        event_queue: Default::default(),
        execution: Execution::Sequential,
        deployment: DeploymentConfig { mode, warmup_ms: 10.0 },
    };
    // 2 MB per block over 50 kB/ms: a 40 ms transfer + 10 ms warm-up
    // when the crash re-hosts one block.
    let backend = || {
        SyntheticBackend::uniform(4, 5.0, 1.0).with_deployment(vec![2_000_000; 5], 50_000.0)
    };
    let mut backends = vec![backend()];
    let mut failovers = vec![Failover::with_policy(Box::new(AlwaysRepartition))];
    let requests = generate(500, Arrival::Poisson { rate_rps: 150.0 }, 16, 42);
    let inputs = HostTensor::zeros(vec![16, 4]);
    let plans = [FailurePlan::crash(3, 200.0)];
    let report = serve(
        &mut backends,
        &StaticMetrics,
        &mut failovers,
        &cfg,
        &requests,
        &inputs,
        &plans,
    )
    .unwrap();
    assert_eq!(
        report.completed_count + report.dropped.len(),
        500,
        "bench must conserve requests"
    );
    let s = bench(2, 10, || {
        let mut backends = vec![backend()];
        let mut failovers = vec![Failover::with_policy(Box::new(AlwaysRepartition))];
        std::hint::black_box(
            serve(
                &mut backends,
                &StaticMetrics,
                &mut failovers,
                &cfg,
                &requests,
                &inputs,
                &plans,
            )
            .unwrap(),
        );
    });
    (
        report.total_downtime_ms(),
        report.deploy_stall_ms(),
        report.throughput_rps,
        s.mean,
    )
}

/// The deployment-mode axis: no artifacts needed, always runs.
fn deploy_bench() -> Vec<Json> {
    let mut t = Table::new(
        "bench: repartition deployment modes — 4-node pipeline, crash @200ms, 40ms transfer + 10ms warm-up",
        &["mode", "decision ms", "stall ms", "total ms", "rps", "run us"],
    );
    let mut out = Vec::new();
    for mode in [
        DeployMode::Instantaneous,
        DeployMode::BreakBeforeMake,
        DeployMode::MakeBeforeBreak,
    ] {
        let (decision_ms, stall_ms, rps, run_us) = deploy_case(mode);
        t.row(&[
            mode.as_str().to_string(),
            f(decision_ms, 2),
            f(stall_ms, 2),
            f(decision_ms + stall_ms, 2),
            f(rps, 1),
            f(run_us, 1),
        ]);
        out.push(obj(&[
            ("mode", mode.as_str().into()),
            ("decision_downtime_ms", decision_ms.into()),
            ("deploy_stall_ms", stall_ms.into()),
            ("total_downtime_ms", (decision_ms + stall_ms).into()),
            ("throughput_rps", rps.into()),
            ("run_us", run_us.into()),
        ]));
    }
    t.print();
    out
}

fn main() {
    let deploy = deploy_bench();
    let out = obj(&[
        ("bench", "downtime".into()),
        ("deploy_modes", Json::Arr(deploy)),
    ]);
    let path = "BENCH_downtime.json";
    std::fs::write(path, out.to_string()).unwrap();
    println!("wrote {path}");

    let mut cfg = Config::default();
    cfg.artifacts_dir = default_artifacts_dir();
    if require_artifacts(&cfg.artifacts_dir).is_err() {
        eprintln!("skipping decision-path bench: run `make artifacts` first");
        return;
    }
    let store = ArtifactStore::open(&cfg.artifacts_dir).unwrap();
    let params = GbdtParams::default();
    // Analytic latency samples are fine here: we time the *query* path.
    let metas: Vec<_> = store.models.values().collect();
    let samples: Vec<LayerSample> = metas[0]
        .all_layers()
        .iter()
        .map(|l| LayerSample {
            spec: (*l).clone(),
            latency_ms: 1e-6 * l.flops() as f64 + 0.02,
        })
        .collect();
    let (lat_model, _) = LatencyModel::fit(&samples, &params, 0).unwrap();
    let (acc_model, _) = AccuracyModel::fit(&metas, &params, 0).unwrap();
    let link = LinkModel::new(cfg.link.clone());
    let downtime = DowntimeTable::new();

    for name in ["resnet32", "mobilenetv2"] {
        let Ok(meta) = store.model(name) else { continue };
        let est = Estimator::new(
            meta,
            &lat_model,
            &acc_model,
            &link,
            &downtime,
            cfg.reinstate_ms,
        );
        let mut t = Table::new(
            &format!("bench: failover decision path — {name}"),
            &["failed node", "mean ms", "p95 ms", "p99 ms"],
        );
        for failed in [2usize, meta.num_nodes / 2, meta.num_nodes] {
            let s = bench(5, 100, || {
                let mut fo = Failover::new(cfg.objectives.clone());
                let _ = fo.on_failure(&est, failed).unwrap();
            });
            t.row(&[
                format!("n{failed}"),
                f(s.mean / 1000.0, 3),
                f(s.p95 / 1000.0, 3),
                f(s.p99 / 1000.0, 3),
            ]);
        }
        t.print();
    }
}
