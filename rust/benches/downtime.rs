//! Bench: the failover decision path end-to-end (predictor queries +
//! scheduler selection) — the measured basis of Table VIII. Needs
//! `make artifacts`.

use continuer::cluster::link::LinkModel;
use continuer::config::Config;
use continuer::coordinator::estimator::Estimator;
use continuer::coordinator::failover::Failover;
use continuer::coordinator::profiler::DowntimeTable;
use continuer::exper::{default_artifacts_dir, require_artifacts};
use continuer::predict::{AccuracyModel, GbdtParams, LatencyModel, LayerSample};
use continuer::runtime::ArtifactStore;
use continuer::util::bench::{bench, f, Table};

fn main() {
    let mut cfg = Config::default();
    cfg.artifacts_dir = default_artifacts_dir();
    if require_artifacts(&cfg.artifacts_dir).is_err() {
        eprintln!("skipping downtime bench: run `make artifacts` first");
        return;
    }
    let store = ArtifactStore::open(&cfg.artifacts_dir).unwrap();
    let params = GbdtParams::default();
    // Analytic latency samples are fine here: we time the *query* path.
    let metas: Vec<_> = store.models.values().collect();
    let samples: Vec<LayerSample> = metas[0]
        .all_layers()
        .iter()
        .map(|l| LayerSample {
            spec: (*l).clone(),
            latency_ms: 1e-6 * l.flops() as f64 + 0.02,
        })
        .collect();
    let (lat_model, _) = LatencyModel::fit(&samples, &params, 0).unwrap();
    let (acc_model, _) = AccuracyModel::fit(&metas, &params, 0).unwrap();
    let link = LinkModel::new(cfg.link.clone());
    let downtime = DowntimeTable::new();

    for name in ["resnet32", "mobilenetv2"] {
        let Ok(meta) = store.model(name) else { continue };
        let est = Estimator::new(
        meta,
        &lat_model,
        &acc_model,
        &link,
        &downtime,
        cfg.reinstate_ms,
    );
        let mut t = Table::new(
            &format!("bench: failover decision path — {name}"),
            &["failed node", "mean ms", "p95 ms", "p99 ms"],
        );
        for failed in [2usize, meta.num_nodes / 2, meta.num_nodes] {
            let s = bench(5, 100, || {
                let mut fo = Failover::new(cfg.objectives.clone());
                let _ = fo.on_failure(&est, failed).unwrap();
            });
            t.row(&[
                format!("n{failed}"),
                f(s.mean / 1000.0, 3),
                f(s.p95 / 1000.0, 3),
                f(s.p99 / 1000.0, 3),
            ]);
        }
        t.print();
    }
}
