//! Bench: the two prediction models — fit cost (offline profiler phase)
//! and query cost (on the failover path, so it bounds downtime /
//! Table VIII).

use continuer::dnn::layers::{LayerKind, LayerSpec};
use continuer::predict::{Dataset, Gbdt, GbdtParams, LatencyModel, LayerSample};
use continuer::util::bench::{bench, f, Table};
use continuer::util::rng::Rng;

fn synth_dataset(n: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut data = Dataset::new((0..d).map(|i| format!("x{i}")).collect());
    for _ in 0..n {
        let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
        let y = x.iter().enumerate().map(|(i, v)| v * (i + 1) as f64).sum::<f64>()
            + rng.normal() * 0.01;
        data.push(x, y);
    }
    data
}

fn synth_samples(n: usize, seed: u64) -> Vec<LayerSample> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let h = [4usize, 8, 16, 32][rng.below(4)];
            let c = [8usize, 16, 32, 64][rng.below(4)];
            let spec = LayerSpec {
                kind: LayerKind::Conv,
                input_h: h,
                input_w: h,
                input_c: c,
                kernel: 3,
                stride: 1,
                filters: c,
            };
            let l = 1e-6 * spec.flops() as f64 * (1.0 + 0.05 * rng.normal());
            LayerSample {
                spec,
                latency_ms: l.max(1e-4),
            }
        })
        .collect()
}

fn main() {
    let mut t = Table::new(
        "bench: GBDT fit (offline)",
        &["rows x feats x trees", "mean ms"],
    );
    for (n, d, trees) in [(200usize, 9usize, 50usize), (500, 9, 100), (500, 25, 200)] {
        let data = synth_dataset(n, d, 1);
        let params = GbdtParams {
            n_estimators: trees,
            early_stop: 0,
            ..Default::default()
        };
        let s = bench(1, 5, || {
            let _ = Gbdt::fit(&data, &params);
        });
        t.row(&[format!("{n} x {d} x {trees}"), f(s.mean / 1000.0, 1)]);
    }
    t.print();

    // Query path (hot): single-row prediction.
    let data = synth_dataset(500, 9, 2);
    let model = Gbdt::fit(&data, &GbdtParams::default());
    let row = vec![0.5; 9];
    let s = bench(1000, 20000, || {
        let _ = model.predict_one(&row);
    });
    println!("gbdt predict_one: mean {:.3} us p99 {:.3} us", s.mean, s.p99);

    // Latency-model path prediction over a ResNet-block-like layer list.
    let samples = synth_samples(300, 3);
    let (lat, _) = LatencyModel::fit(&samples, &GbdtParams::default(), 0).unwrap();
    let layers: Vec<LayerSpec> = samples.iter().take(40).map(|s| s.spec.clone()).collect();
    let s = bench(100, 2000, || {
        let _ = lat.predict_path(layers.iter());
    });
    println!(
        "latency model: 40-layer path prediction mean {:.1} us p99 {:.1} us\n",
        s.mean, s.p99
    );
}
