//! Bench: the health-monitoring hot paths (no artifacts needed).
//!
//! Part 1 — monitor simulation cost: wall time to simulate the heartbeat
//! channel + detector + quarantine gate for an 8-node replica over a
//! 60-second horizon under a churning MTBF/MTTR plan, for the fixed-
//! timeout and phi-accrual detectors. This is the per-replica setup cost
//! every monitored serving run pays.
//!
//! Part 2 — serving under monitored health: engine throughput on the
//! synthetic 4-node pipeline with a mid-run crash + recovery, comparing
//! oracle detection against monitored fixed-timeout and phi-accrual
//! detection over a noisy channel (1 ms jitter, 5% loss).
//!
//! Emits machine-readable `BENCH_health.json` for the perf trajectory.

use continuer::cluster::failure::{Detector, FailurePlan};
use continuer::config::Objectives;
use continuer::coordinator::batcher::BatcherConfig;
use continuer::coordinator::engine::{serve, EngineConfig, Execution, HealthMode, SyntheticBackend};
use continuer::coordinator::estimator::StaticMetrics;
use continuer::coordinator::router::RoutePolicy;
use continuer::coordinator::Failover;
use continuer::health::{simulate, DetectorKind, HealthConfig, HeartbeatConfig};
use continuer::runtime::HostTensor;
use continuer::util::bench::{bench, f, Table};
use continuer::util::json::{obj, Json};
use continuer::util::rng::Rng;
use continuer::workload::{generate, Arrival};

fn health_cfg(detector: DetectorKind) -> HealthConfig {
    HealthConfig {
        heartbeat: HeartbeatConfig {
            interval_ms: 10.0,
            jitter_ms: 1.0,
            loss_prob: 0.05,
            blackout: None,
        },
        detector,
        failover_slowdown: 3.0,
        quarantine_ms: 100.0,
        slowdown_window: 8,
        seed: 42,
    }
}

fn monitor_bench() -> Vec<Json> {
    const NODES: usize = 8;
    const HORIZON_MS: f64 = 60_000.0;
    let mut rng = Rng::new(17);
    let eligible: Vec<usize> = (1..=NODES).collect();
    let plan = FailurePlan::random_mtbf(&eligible, HORIZON_MS, 5_000.0, 500.0, &mut rng);

    let mut t = Table::new(
        "bench: monitor simulation — 8 nodes, 60 s horizon, mtbf 5 s / mttr 0.5 s",
        &["detector", "mean us", "p95 us", "events"],
    );
    let mut out = Vec::new();
    let cases = [
        ("fixed/25ms", DetectorKind::FixedTimeout { timeout_ms: 25.0 }),
        (
            "phi/8",
            DetectorKind::PhiAccrual { threshold: 8.0, window: 64, min_std_ms: 0.5 },
        ),
    ];
    for (label, kind) in cases {
        let cfg = health_cfg(kind);
        let events = simulate(&cfg, &plan, NODES, HORIZON_MS).len();
        let s = bench(2, 10, || {
            std::hint::black_box(simulate(&cfg, &plan, NODES, HORIZON_MS));
        });
        t.row(&[
            label.to_string(),
            f(s.mean, 1),
            f(s.p95, 1),
            events.to_string(),
        ]);
        out.push(obj(&[
            ("detector", label.into()),
            ("mean_us", s.mean.into()),
            ("p95_us", s.p95.into()),
            ("events", events.into()),
        ]));
    }
    t.print();
    out
}

fn serving_case(health: HealthMode) -> (f64, usize, usize) {
    let mut backends = vec![SyntheticBackend::uniform(4, 5.0, 1.0)];
    let mut failovers = vec![Failover::new(Objectives::default())];
    let cfg = EngineConfig {
        batcher: BatcherConfig::new(vec![1], 2.0, 1),
        health,
        deadline_ms: None,
        pipeline_depth: 4,
        route: RoutePolicy::RoundRobin,
        decision_ms_override: Some(1.5),
        record_completions: false,
        speed_factors: Vec::new(),
        steal: false,
        event_queue: Default::default(),
        execution: Execution::Sequential,
        deployment: Default::default(),
    };
    let requests = generate(400, Arrival::Poisson { rate_rps: 500.0 }, 16, 42);
    let inputs = HostTensor::zeros(vec![16, 4]);
    let report = serve(
        &mut backends,
        &StaticMetrics,
        &mut failovers,
        &cfg,
        &requests,
        &inputs,
        &[FailurePlan::crash_recover(3, 200.0, 300.0)],
    )
    .unwrap();
    assert_eq!(
        report.completed_count + report.dropped.len(),
        400,
        "bench must conserve requests"
    );
    (
        report.throughput_rps,
        report.failovers.len(),
        report.false_failovers(),
    )
}

fn serving_bench() -> Vec<Json> {
    let mut t = Table::new(
        "bench: serving under monitored health — 4-node pipeline, crash @200ms + recovery",
        &["health mode", "throughput rps", "failovers", "false fo"],
    );
    let cases: Vec<(&str, HealthMode)> = vec![
        ("oracle", HealthMode::Oracle(Detector::default())),
        (
            "monitored fixed/25ms",
            HealthMode::Monitored(health_cfg(DetectorKind::FixedTimeout { timeout_ms: 25.0 })),
        ),
        (
            "monitored phi/8",
            HealthMode::Monitored(health_cfg(DetectorKind::PhiAccrual {
                threshold: 8.0,
                window: 64,
                min_std_ms: 0.5,
            })),
        ),
    ];
    let mut out = Vec::new();
    for (label, health) in cases {
        let (rps, fo, false_fo) = serving_case(health);
        t.row(&[
            label.to_string(),
            f(rps, 1),
            fo.to_string(),
            false_fo.to_string(),
        ]);
        out.push(obj(&[
            ("mode", label.into()),
            ("throughput_rps", rps.into()),
            ("failovers", fo.into()),
            ("false_failovers", false_fo.into()),
        ]));
    }
    t.print();
    out
}

fn main() {
    let monitor = monitor_bench();
    let serving = serving_bench();
    let out = obj(&[
        ("bench", "health".into()),
        ("monitor_sim", Json::Arr(monitor)),
        ("serving", Json::Arr(serving)),
    ]);
    let path = "BENCH_health.json";
    std::fs::write(path, out.to_string()).unwrap();
    println!("wrote {path}");
}
