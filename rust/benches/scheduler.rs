//! Bench: scheduler hot path — the additive-weighting selection that runs
//! during a failover (L3 hot path; paper Table VIII regime). Criterion is
//! unavailable offline; `continuer::util::bench` provides warmup + robust
//! summaries.

use continuer::config::Objectives;
use continuer::coordinator::scheduler::{select, weight_sweep, CandidateMetrics};
use continuer::dnn::variants::Technique;
use continuer::util::bench::{bench, bench_throughput, f, Table};
use continuer::util::rng::Rng;

fn candidates(n: usize, rng: &mut Rng) -> Vec<CandidateMetrics> {
    (0..n)
        .map(|i| CandidateMetrics {
            technique: match i % 3 {
                0 => Technique::Repartition,
                1 => Technique::EarlyExit(i + 1),
                _ => Technique::SkipConnection(i + 1),
            },
            accuracy: rng.range(50.0, 100.0),
            latency_ms: rng.range(1.0, 60.0),
            downtime_ms: rng.range(0.5, 20.0),
        })
        .collect()
}

fn main() {
    let mut rng = Rng::new(0xBE);
    let w = Objectives::default();
    let mut t = Table::new(
        "bench: scheduler selection",
        &["candidates", "mean us", "p95 us", "p99 us"],
    );
    for n in [2usize, 3, 8, 32] {
        let cands = candidates(n, &mut rng);
        let s = bench(200, 2000, || {
            let _ = select(&cands, &w).unwrap();
        });
        t.row(&[n.to_string(), f(s.mean, 3), f(s.p95, 3), f(s.p99, 3)]);
    }
    t.print();

    // Table VII style sweep throughput: 729 weight combos x selection.
    let cands = candidates(3, &mut rng);
    let weights = weight_sweep(0.1, 0.9, 0.1);
    let (per_item_us, per_sec) = bench_throughput(3, 50, || {
        let mut n = 0;
        for w in &weights {
            let _ = select(&cands, w).unwrap();
            n += 1;
        }
        n
    });
    println!(
        "weight sweep: {:.3} us/selection, {:.0} selections/sec\n",
        per_item_us, per_sec
    );
}
