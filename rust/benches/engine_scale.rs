//! Bench: the serving engine at million-request scale (synthetic, no
//! artifacts needed).
//!
//! Drives 1M requests (default; `--quick` runs ~20k for CI smoke,
//! `--requests N` picks any scale) through the event-driven engine on a
//! 4-node synthetic pipeline for replica counts 1/2/4 at depth 4, with a
//! mid-run crash + recovery per replica so failover and requeue sit on
//! the measured path. Streaming metrics are on (no per-request records),
//! so the run demonstrates — and asserts — the zero-allocation steady
//! state: completion memory is O(1) in request count and step plans are
//! allocated once per distinct (technique, failure) pair, not per batch.
//!
//! Two further axes cover the sharded engine:
//!
//! - **Workers sweep** (`--workers N` pins a single count; default
//!   1/2/4): the 4-replica case run `Execution::Sharded(N)` with
//!   round-robin pre-split arrivals, against the same round-robin case
//!   run sequentially — the speedup column is real-thread scaling on
//!   identical per-shard work.
//! - **Saturation sweep**: offered load ramps across the bottleneck
//!   capacity until p99 exceeds the 50 ms SLO; the knee (highest offered
//!   rate still inside the SLO) lands in the JSON.
//! - **Skew axis** (`--skew`): a heterogeneous fleet (speed factors
//!   [1.0, 0.6, 1.4, 1.0]) with one replica suffering a 3× node
//!   degradation mid-run, served three ways — plain JSQ, speed-weighted
//!   JSQ, and weighted JSQ + cross-replica work stealing — reporting
//!   virtual throughput and p99 per arm (the fleet-aware routing win,
//!   recorded under the JSON's `skew` key).
//! - **Queue axis** (`--queue heap|calendar` pins every case to one
//!   event-queue implementation; unset runs the default calendar and
//!   adds a heap reference arm): the 4-replica sequential round-robin
//!   case and the saturation sweep re-run on the `BinaryHeap` reference
//!   queue, with the calendar/heap events-per-sec ratio and knee shift
//!   recorded under the JSON's `queue_axis` key. Same seed, so the two
//!   arms process byte-identical event streams — the ratio is pure
//!   queue mechanics.
//!
//! A **tracing axis** guards the observability layer: the 4-replica
//! round-robin workload run with the default `NoopSink` (must hold the
//! baseline within 1% — the sink generic monomorphizes to nothing) and
//! with a recording `EventBuffer` (reported, not asserted). Both land
//! under the JSON's `tracing` key.
//!
//! Emits machine-readable `BENCH_engine_scale.json`: per case, wall-clock
//! events/sec through the event loop, virtual-time throughput, peak
//! batches in flight, plan allocations vs batches dispatched, and the
//! time to render the report's JSON record (`report_build_ms` — the
//! post-run summary readout; the in-engine report construction itself is
//! part of `wall_s`). `rust/bench/run.sh` scripts the full sweep.

use std::time::Instant;

use continuer::cluster::failure::{Detector, FailurePlan};
use continuer::config::Objectives;
use continuer::coordinator::batcher::BatcherConfig;
use continuer::coordinator::engine::{
    serve, serve_with_sink, EngineConfig, Execution, HealthMode, SyntheticBackend,
};
use continuer::coordinator::estimator::MetricsSource;
use continuer::coordinator::router::RoutePolicy;
use continuer::coordinator::scheduler::CandidateMetrics;
use continuer::coordinator::Failover;
use continuer::dnn::variants::Technique;
use continuer::obs::EventBuffer;
use continuer::runtime::HostTensor;
use continuer::util::bench::{f, Table};
use continuer::util::cli::Args;
use continuer::util::eventq::QueueKind;
use continuer::util::json::{obj, Json};
use continuer::workload::{generate, Arrival};

const NODES: usize = 4;
const STAGE_MS: f64 = 5.0;
const HOP_MS: f64 = 1.0;
const DEPTH: usize = 4;
/// What the batch-16 bottleneck stage admits per replica, roughly.
const CAPACITY_RPS_PER_REPLICA: f64 = 3200.0;
/// The saturation sweep's latency objective.
const SLO_P99_MS: f64 = 50.0;

/// Stub predictions: the synthetic bench has no fitted models.
struct StubMetrics;

impl MetricsSource for StubMetrics {
    fn candidate_metrics(&self, failed: usize) -> anyhow::Result<Vec<CandidateMetrics>> {
        Ok(vec![CandidateMetrics {
            technique: Technique::SkipConnection(failed),
            accuracy: 85.0,
            latency_ms: 25.0,
            downtime_ms: 3.0,
        }])
    }

    fn reinstate_ms(&self) -> f64 {
        1.0
    }
}

struct ScaleCase {
    label: String,
    wall_s: f64,
    events_per_sec: f64,
    report_build_ms: f64,
    json: Json,
}

fn scale_case(
    replicas: usize,
    n_requests: usize,
    route: RoutePolicy,
    execution: Execution,
    queue: QueueKind,
) -> ScaleCase {
    // Near-saturating arrivals: the batch-16 bottleneck stage admits
    // ~3200 rps per replica; offer ~2500 per replica so queues stay
    // bounded and every request completes.
    let rate_rps = 2500.0 * replicas as f64;
    let span_est_ms = n_requests as f64 / (rate_rps / 1e3);

    let mut backends: Vec<SyntheticBackend> = (0..replicas)
        .map(|_| SyntheticBackend::uniform(NODES, STAGE_MS, HOP_MS))
        .collect();
    let mut failovers: Vec<Failover> = (0..replicas)
        .map(|_| Failover::new(Objectives::default()))
        .collect();
    // Every replica loses a node mid-run and gets it back, so failover,
    // requeue and reintegration all sit on the measured hot path.
    let plans: Vec<FailurePlan> = (0..replicas)
        .map(|r| {
            let node = 2 + (r % (NODES - 1));
            FailurePlan::crash_recover(node, 0.25 * span_est_ms, 0.1 * span_est_ms)
        })
        .collect();
    let cfg = EngineConfig {
        batcher: BatcherConfig::new(vec![1, 2, 4, 8, 16], 2.0, 16),
        health: HealthMode::Oracle(Detector::default()),
        deadline_ms: None,
        pipeline_depth: DEPTH,
        route,
        decision_ms_override: Some(1.5),
        // The point of the bench: no per-request records at 1M scale.
        record_completions: false,
        speed_factors: Vec::new(),
        steal: false,
        execution,
        deployment: Default::default(),
        event_queue: queue,
    };
    let requests = generate(n_requests, Arrival::Poisson { rate_rps }, 16, 42);
    let inputs = HostTensor::zeros(vec![16, 4]);

    let t0 = Instant::now();
    let report = serve(
        &mut backends,
        &StubMetrics,
        &mut failovers,
        &cfg,
        &requests,
        &inputs,
        &plans,
    )
    .unwrap();
    let wall_s = t0.elapsed().as_secs_f64();

    // The zero-allocation steady state, asserted at scale.
    assert_eq!(
        report.completed_count + report.dropped.len(),
        n_requests,
        "bench must conserve requests"
    );
    assert!(
        report.completed.is_empty(),
        "streaming metrics must keep no per-request records"
    );
    assert!(
        report.plan_cache_misses <= 3 * replicas,
        "plans must be allocated per distinct failure, not per batch \
         ({} misses over {} batches)",
        report.plan_cache_misses,
        report.batches_dispatched
    );

    let (exec_label, workers) = match execution {
        Execution::Sequential => ("sequential".to_string(), 1usize),
        Execution::Sharded(w) => (format!("sharded({w})"), w),
    };
    let route_label = match route {
        RoutePolicy::RoundRobin => "round_robin",
        RoutePolicy::JoinShortestQueue => "jsq",
        RoutePolicy::WeightedRoundRobin => "weighted_round_robin",
        RoutePolicy::WeightedJoinShortestQueue => "weighted_jsq",
    };
    let label = format!("{replicas}r/{exec_label}");
    let events_per_sec = report.events_processed as f64 / wall_s.max(1e-9);
    let t1 = Instant::now();
    let mut json = obj(&[
        ("replicas", replicas.into()),
        ("execution", exec_label.as_str().into()),
        ("workers", workers.into()),
        ("event_queue", queue.label().into()),
        ("route", route_label.into()),
        ("pipeline_depth", DEPTH.into()),
        ("requests", n_requests.into()),
        ("arrival_rate_rps", rate_rps.into()),
        ("completed", report.completed_count.into()),
        ("dropped", report.dropped.len().into()),
        ("failovers", report.failovers.len().into()),
        ("events_processed", report.events_processed.into()),
        ("events_per_sec", events_per_sec.into()),
        ("wall_s", wall_s.into()),
        ("virtual_throughput_rps", report.throughput_rps.into()),
        ("peak_in_flight", report.max_in_flight.into()),
        ("batches_dispatched", report.batches_dispatched.into()),
        ("plans_allocated", report.plan_cache_misses.into()),
        ("plan_cache_hits", report.plan_cache_hits.into()),
        ("latency_mean_ms", report.latency.mean.into()),
        ("latency_p50_ms", report.latency.p50.into()),
        ("latency_p95_ms", report.latency.p95.into()),
        ("latency_p99_ms", report.latency.p99.into()),
    ]);
    let report_build_ms = t1.elapsed().as_secs_f64() * 1e3;
    if let Json::Obj(m) = &mut json {
        m.insert("report_build_ms".to_string(), report_build_ms.into());
    }
    ScaleCase {
        label,
        wall_s,
        events_per_sec,
        report_build_ms,
        json,
    }
}

/// One arm of the tracing axis: the 4-replica round-robin sequential
/// workload run with the default `NoopSink` (via `serve`) or with a
/// recording `EventBuffer` (via `serve_with_sink`). Returns wall-clock
/// events/sec and the number of observability events captured.
fn tracing_arm(n_requests: usize, record: bool, queue: QueueKind) -> (f64, usize) {
    let replicas = 4usize;
    let rate_rps = 2500.0 * replicas as f64;
    let span_est_ms = n_requests as f64 / (rate_rps / 1e3);
    let mut backends: Vec<SyntheticBackend> = (0..replicas)
        .map(|_| SyntheticBackend::uniform(NODES, STAGE_MS, HOP_MS))
        .collect();
    let mut failovers: Vec<Failover> = (0..replicas)
        .map(|_| Failover::new(Objectives::default()))
        .collect();
    let plans: Vec<FailurePlan> = (0..replicas)
        .map(|r| {
            let node = 2 + (r % (NODES - 1));
            FailurePlan::crash_recover(node, 0.25 * span_est_ms, 0.1 * span_est_ms)
        })
        .collect();
    let cfg = EngineConfig {
        batcher: BatcherConfig::new(vec![1, 2, 4, 8, 16], 2.0, 16),
        health: HealthMode::Oracle(Detector::default()),
        deadline_ms: None,
        pipeline_depth: DEPTH,
        route: RoutePolicy::RoundRobin,
        decision_ms_override: Some(1.5),
        record_completions: false,
        speed_factors: Vec::new(),
        steal: false,
        execution: Execution::Sequential,
        deployment: Default::default(),
        event_queue: queue,
    };
    let requests = generate(n_requests, Arrival::Poisson { rate_rps }, 16, 42);
    let inputs = HostTensor::zeros(vec![16, 4]);
    let mut sink = EventBuffer::default();
    let t0 = Instant::now();
    let report = if record {
        serve_with_sink(
            &mut backends,
            &StubMetrics,
            &mut failovers,
            &cfg,
            &requests,
            &inputs,
            &plans,
            &mut sink,
        )
        .unwrap()
    } else {
        serve(
            &mut backends,
            &StubMetrics,
            &mut failovers,
            &cfg,
            &requests,
            &inputs,
            &plans,
        )
        .unwrap()
    };
    let wall_s = t0.elapsed().as_secs_f64();
    (
        report.events_processed as f64 / wall_s.max(1e-9),
        sink.events.len(),
    )
}

/// One rung of the saturation sweep: 4 replicas, round-robin shards, no
/// failures — pure offered load against the pipeline's capacity.
/// Returns the rung's JSON record and whether p99 met the SLO.
fn saturation_rung(
    rate_rps: f64,
    n_requests: usize,
    workers: usize,
    queue: QueueKind,
) -> (Json, bool) {
    let replicas = 4usize;
    let mut backends: Vec<SyntheticBackend> = (0..replicas)
        .map(|_| SyntheticBackend::uniform(NODES, STAGE_MS, HOP_MS))
        .collect();
    let mut failovers: Vec<Failover> = (0..replicas)
        .map(|_| Failover::new(Objectives::default()))
        .collect();
    let cfg = EngineConfig {
        batcher: BatcherConfig::new(vec![1, 2, 4, 8, 16], 2.0, 16),
        health: HealthMode::Oracle(Detector::default()),
        deadline_ms: None,
        pipeline_depth: DEPTH,
        route: RoutePolicy::RoundRobin,
        decision_ms_override: Some(1.5),
        record_completions: false,
        speed_factors: Vec::new(),
        steal: false,
        execution: Execution::Sharded(workers),
        deployment: Default::default(),
        event_queue: queue,
    };
    let requests = generate(n_requests, Arrival::Poisson { rate_rps }, 16, 42);
    let inputs = HostTensor::zeros(vec![16, 4]);
    let report = serve(
        &mut backends,
        &StubMetrics,
        &mut failovers,
        &cfg,
        &requests,
        &inputs,
        &[],
    )
    .unwrap();
    let within_slo = report.latency.p99 <= SLO_P99_MS;
    let rung = obj(&[
        ("offered_rps", rate_rps.into()),
        ("requests", n_requests.into()),
        ("completed", report.completed_count.into()),
        ("p50_ms", report.latency.p50.into()),
        ("p99_ms", report.latency.p99.into()),
        ("within_slo", within_slo.into()),
    ]);
    (rung, within_slo)
}

/// Ramp offered load across the bottleneck capacity and report the knee:
/// the highest offered rate whose p99 still meets the SLO.
fn saturation_sweep(n_requests: usize, workers: usize, queue: QueueKind) -> (Json, f64) {
    let mut rungs = Vec::new();
    let mut knee_rps = 0.0f64;
    for mult in [0.5, 0.7, 0.85, 1.0, 1.1, 1.25, 1.5] {
        let rate_rps = mult * CAPACITY_RPS_PER_REPLICA * 4.0;
        let (rung, within_slo) = saturation_rung(rate_rps, n_requests, workers, queue);
        if within_slo && rate_rps > knee_rps {
            knee_rps = rate_rps;
        }
        rungs.push(rung);
    }
    let sweep = obj(&[
        ("slo_p99_ms", SLO_P99_MS.into()),
        ("workers", workers.into()),
        ("event_queue", queue.label().into()),
        ("knee_rps", knee_rps.into()),
        ("rungs", Json::Arr(rungs)),
    ]);
    (sweep, knee_rps)
}

/// Per-replica static speed factors for the skew axis: a heterogeneous
/// fleet with one slow edge box and one fast server.
const SKEW_SPEEDS: [f64; 4] = [1.0, 0.6, 1.4, 1.0];
/// The skew axis degrades one node of replica 0 by this factor mid-run.
const SKEW_SLOWDOWN: f64 = 3.0;

/// One arm of the skew axis: the heterogeneous fleet ([`SKEW_SPEEDS`])
/// with replica 0 suffering a [`SKEW_SLOWDOWN`]× node degradation
/// through the middle of the stream, served sharded under the given
/// routing policy with stealing on or off. Oracle health never fails
/// over on `Degraded`, so the whole effect lands on routing and
/// stealing — exactly the surface this axis measures. Returns the arm's
/// JSON record plus its p99 latency and virtual throughput.
fn skew_arm(
    label: &str,
    n_requests: usize,
    workers: usize,
    route: RoutePolicy,
    steal: bool,
    queue: QueueKind,
) -> (Json, f64, f64) {
    let replicas = SKEW_SPEEDS.len();
    // ~65% of the fleet's healthy weighted capacity: enough headroom
    // that the weighted arms stay comfortable, tight enough that plain
    // count-balanced JSQ piles a deep queue onto the degraded replica.
    let speed_total: f64 = SKEW_SPEEDS.iter().sum();
    let rate_rps = 0.65 * CAPACITY_RPS_PER_REPLICA * speed_total;
    let span_est_ms = n_requests as f64 / (rate_rps / 1e3);

    let mut backends: Vec<SyntheticBackend> = (0..replicas)
        .map(|_| SyntheticBackend::uniform(NODES, STAGE_MS, HOP_MS))
        .collect();
    let mut failovers: Vec<Failover> = (0..replicas)
        .map(|_| Failover::new(Objectives::default()))
        .collect();
    // Replica 0 runs one node at 3x stage times across the middle 40%
    // of the stream; the rest of the fleet stays healthy.
    let mut plans = vec![FailurePlan::none(); replicas];
    plans[0] = FailurePlan::degraded(2, 0.25 * span_est_ms, SKEW_SLOWDOWN, 0.4 * span_est_ms);
    let cfg = EngineConfig {
        batcher: BatcherConfig::new(vec![1, 2, 4, 8, 16], 2.0, 16),
        health: HealthMode::Oracle(Detector::default()),
        deadline_ms: None,
        pipeline_depth: DEPTH,
        route,
        decision_ms_override: Some(1.5),
        record_completions: false,
        speed_factors: SKEW_SPEEDS.to_vec(),
        steal,
        execution: Execution::Sharded(workers),
        deployment: Default::default(),
        event_queue: queue,
    };
    let requests = generate(n_requests, Arrival::Poisson { rate_rps }, 16, 42);
    let inputs = HostTensor::zeros(vec![16, 4]);
    let t0 = Instant::now();
    let report = serve(
        &mut backends,
        &StubMetrics,
        &mut failovers,
        &cfg,
        &requests,
        &inputs,
        &plans,
    )
    .unwrap();
    let wall_s = t0.elapsed().as_secs_f64();
    assert_eq!(
        report.completed_count + report.dropped.len(),
        n_requests,
        "skew arm must conserve requests"
    );
    let json = obj(&[
        ("arm", label.into()),
        ("steal", steal.into()),
        ("requests", n_requests.into()),
        ("offered_rps", rate_rps.into()),
        ("completed", report.completed_count.into()),
        ("dropped", report.dropped.len().into()),
        ("virtual_throughput_rps", report.throughput_rps.into()),
        ("latency_p50_ms", report.latency.p50.into()),
        ("latency_p95_ms", report.latency.p95.into()),
        ("latency_p99_ms", report.latency.p99.into()),
        ("wall_s", wall_s.into()),
    ]);
    (json, report.latency.p99, report.throughput_rps)
}

/// The skew axis: the same heterogeneous, partially degraded fleet
/// served three ways — plain JSQ (count-balanced), speed-weighted JSQ
/// (drain-time-balanced), and weighted JSQ plus cross-replica work
/// stealing. Weighted routing should cut p99 (the degraded replica
/// holds a third of the backlog it holds under plain JSQ) and stealing
/// should cut the end-of-stream drain, lifting virtual throughput.
fn skew_axis(n_requests: usize, workers: usize, queue: QueueKind) -> Json {
    let arms = [
        ("jsq", RoutePolicy::JoinShortestQueue, false),
        ("weighted_jsq", RoutePolicy::WeightedJoinShortestQueue, false),
        (
            "weighted_jsq_steal",
            RoutePolicy::WeightedJoinShortestQueue,
            true,
        ),
    ];
    let mut records = Vec::new();
    let mut stats = Vec::new();
    for (label, route, steal) in arms {
        let (json, p99, tput) = skew_arm(label, n_requests, workers, route, steal, queue);
        println!("skew {label}: {tput:.0} rps virtual throughput, p99 {p99:.1} ms");
        records.push(json);
        stats.push((p99, tput));
    }
    let (jsq_p99, jsq_tput) = stats[0];
    let (steal_p99, steal_tput) = stats[2];
    let beats = steal_tput > jsq_tput && steal_p99 < jsq_p99;
    println!(
        "skew: weighted JSQ + stealing vs plain JSQ — throughput {:.2}x, p99 {:.2}x{}",
        steal_tput / jsq_tput.max(1e-9),
        steal_p99 / jsq_p99.max(1e-9),
        if beats {
            ""
        } else {
            "  (WARNING: expected a win on both axes)"
        }
    );
    obj(&[
        (
            "speed_factors",
            Json::Arr(SKEW_SPEEDS.iter().map(|&s| s.into()).collect()),
        ),
        ("degraded_replica", 0.into()),
        ("degraded_slowdown", SKEW_SLOWDOWN.into()),
        ("workers", workers.into()),
        ("steal_beats_jsq", beats.into()),
        ("arms", Json::Arr(records)),
    ])
}

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let n_requests = if quick {
        20_000
    } else {
        args.get_usize("requests", 1_000_000)
            .expect("--requests expects an integer")
    };
    // 0 = sweep the default axis; `--workers N` pins a single count.
    let pinned_workers = args
        .get_usize("workers", 0)
        .expect("--workers expects an integer");
    let workers_axis: Vec<usize> = if pinned_workers == 0 {
        vec![1, 2, 4]
    } else {
        vec![pinned_workers]
    };
    // `--queue heap|calendar` pins every case to one event-queue
    // implementation (CI runs both smokes this way); unset runs the
    // default calendar everywhere and adds the heap reference arm.
    let pinned_queue = args.get("queue").map(|s| {
        QueueKind::parse(s)
            .unwrap_or_else(|| panic!("--queue expects 'heap' or 'calendar', got '{s}'"))
    });
    let queue = pinned_queue.unwrap_or_default();

    let mut t = Table::new(
        &format!("bench: engine scale — {n_requests} requests, 4-node synthetic, depth 4"),
        &["case", "wall s", "events/sec", "report build ms"],
    );
    let mut cases = Vec::new();
    let mut push_case = |t: &mut Table, c: ScaleCase| -> f64 {
        t.row(&[
            c.label,
            f(c.wall_s, 2),
            f(c.events_per_sec, 0),
            f(c.report_build_ms, 3),
        ]);
        cases.push(c.json);
        c.events_per_sec
    };

    // Replica axis, sequential reference (JSQ, as served in production).
    for replicas in [1usize, 2, 4] {
        let c = scale_case(
            replicas,
            n_requests,
            RoutePolicy::JoinShortestQueue,
            Execution::Sequential,
            queue,
        );
        push_case(&mut t, c);
    }

    // Workers axis: 4 replicas on real threads vs the same work run
    // sequentially — round-robin pre-split so both do identical work.
    let seq_eps = {
        let c = scale_case(
            4,
            n_requests,
            RoutePolicy::RoundRobin,
            Execution::Sequential,
            queue,
        );
        push_case(&mut t, c)
    };
    let mut speedups = Vec::new();
    let mut speedup_lines = Vec::new();
    for &w in &workers_axis {
        let c = scale_case(
            4,
            n_requests,
            RoutePolicy::RoundRobin,
            Execution::Sharded(w),
            queue,
        );
        let eps = push_case(&mut t, c);
        let speedup = eps / seq_eps.max(1e-9);
        speedup_lines.push(format!(
            "workers={w}: {speedup:.2}x events/sec vs sequential round-robin"
        ));
        speedups.push(obj(&[
            ("workers", w.into()),
            ("events_per_sec", eps.into()),
            ("speedup_vs_sequential", speedup.into()),
        ]));
    }
    t.print();
    for line in &speedup_lines {
        println!("{line}");
    }

    // Tracing axis: the engine is generic over its event sink, so the
    // default NoopSink must cost nothing — guard that the `serve` hot
    // path (Noop) holds the sequential round-robin baseline measured
    // above, and report what a recording sink pays. Interleaved
    // best-of-2 to damp scheduler noise.
    let (mut noop_eps, mut recording_eps, mut events_recorded) = (0.0f64, 0.0f64, 0usize);
    for _ in 0..2 {
        let (eps, _) = tracing_arm(n_requests, false, queue);
        noop_eps = noop_eps.max(eps);
        let (eps, n) = tracing_arm(n_requests, true, queue);
        recording_eps = recording_eps.max(eps);
        events_recorded = n;
    }
    let noop_vs_baseline = noop_eps / seq_eps.max(1e-9);
    let recording_overhead_pct = 100.0 * (1.0 - recording_eps / noop_eps.max(1e-9));
    println!(
        "tracing: noop {noop_eps:.0} events/sec ({:.2}x baseline), recording {recording_eps:.0} \
         events/sec ({recording_overhead_pct:.1}% overhead, {events_recorded} events captured)",
        noop_vs_baseline
    );
    assert!(
        noop_vs_baseline >= 0.99,
        "NoopSink must keep the zero-cost hot path: best-of-2 {noop_eps:.0} events/sec \
         vs baseline {seq_eps:.0} ({noop_vs_baseline:.3}x < 0.99x)"
    );
    let tracing = obj(&[
        ("noop_events_per_sec", noop_eps.into()),
        ("recording_events_per_sec", recording_eps.into()),
        ("noop_vs_baseline", noop_vs_baseline.into()),
        ("recording_overhead_pct", recording_overhead_pct.into()),
        ("events_recorded", events_recorded.into()),
    ]);

    // Saturation knee, on the widest sharded configuration benchmarked.
    let sat_workers = *workers_axis.iter().max().unwrap();
    let sat_requests = (n_requests / 10).max(5_000);
    let (saturation, knee_rps) = saturation_sweep(sat_requests, sat_workers, queue);
    println!(
        "saturation knee ({sat_workers} workers): {knee_rps:.0} rps offered within p99 <= {SLO_P99_MS} ms"
    );

    // Queue axis: when no `--queue` is pinned, re-run the 4-replica
    // sequential round-robin case and the saturation sweep on the
    // BinaryHeap reference. Same seed as the calendar runs above, so
    // both arms walk byte-identical event streams — events/sec ratio
    // and knee shift are pure queue mechanics. CI diffs the ratio
    // (warn-only) so a calendar win that evaporates gets flagged.
    let queue_axis = if pinned_queue.is_none() {
        let heap = scale_case(
            4,
            n_requests,
            RoutePolicy::RoundRobin,
            Execution::Sequential,
            QueueKind::Heap,
        );
        let (_, heap_knee_rps) = saturation_sweep(sat_requests, sat_workers, QueueKind::Heap);
        let ratio = seq_eps / heap.events_per_sec.max(1e-9);
        println!(
            "queue axis: calendar {seq_eps:.0} events/sec vs heap {:.0} ({ratio:.2}x); \
             knee {knee_rps:.0} rps vs {heap_knee_rps:.0}{}",
            heap.events_per_sec,
            if ratio >= 1.0 {
                ""
            } else {
                "  (WARNING: calendar slower than the heap reference)"
            }
        );
        obj(&[
            ("case", "4r/sequential round_robin".into()),
            ("heap_events_per_sec", heap.events_per_sec.into()),
            ("calendar_events_per_sec", seq_eps.into()),
            ("calendar_vs_heap", ratio.into()),
            ("heap_knee_rps", heap_knee_rps.into()),
            ("calendar_knee_rps", knee_rps.into()),
        ])
    } else {
        Json::Null
    };

    // Skew axis (opt-in: `--skew`): heterogeneous speeds + one degraded
    // replica, plain JSQ vs weighted JSQ vs weighted JSQ + stealing.
    let skew = if args.flag("skew") {
        skew_axis(sat_requests, sat_workers, queue)
    } else {
        Json::Null
    };

    let out = obj(&[
        ("bench", "engine_scale".into()),
        ("requests", n_requests.into()),
        ("quick", quick.into()),
        ("nodes", NODES.into()),
        ("stage_ms", STAGE_MS.into()),
        ("hop_ms", HOP_MS.into()),
        (
            "workers_axis",
            Json::Arr(workers_axis.iter().map(|&w| w.into()).collect()),
        ),
        ("event_queue", queue.label().into()),
        ("sequential_rr_events_per_sec", seq_eps.into()),
        ("worker_scaling", Json::Arr(speedups)),
        ("tracing", tracing),
        ("saturation", saturation),
        ("queue_axis", queue_axis),
        ("skew", skew),
        ("cases", Json::Arr(cases)),
    ]);
    let path = "BENCH_engine_scale.json";
    std::fs::write(path, out.to_string()).unwrap();
    println!("wrote {path}");
}
