//! Bench: the serving engine at million-request scale (synthetic, no
//! artifacts needed).
//!
//! Drives 1M requests (default; `--quick` runs ~20k for CI smoke,
//! `--requests N` picks any scale) through the event-driven engine on a
//! 4-node synthetic pipeline for replica counts 1/2/4 at depth 4, with a
//! mid-run crash + recovery per replica so failover and requeue sit on
//! the measured path. Streaming metrics are on (no per-request records),
//! so the run demonstrates — and asserts — the zero-allocation steady
//! state: completion memory is O(1) in request count and step plans are
//! allocated once per distinct (technique, failure) pair, not per batch.
//!
//! Emits machine-readable `BENCH_engine_scale.json`: per case, wall-clock
//! events/sec through the event loop, virtual-time throughput, peak
//! batches in flight, plan allocations vs batches dispatched, and the
//! time to render the report's JSON record (`report_build_ms` — the
//! post-run summary readout; the in-engine report construction itself is
//! part of `wall_s`).

use std::time::Instant;

use continuer::cluster::failure::{Detector, FailurePlan};
use continuer::config::Objectives;
use continuer::coordinator::batcher::BatcherConfig;
use continuer::coordinator::engine::{serve, EngineConfig, HealthMode, SyntheticBackend};
use continuer::coordinator::estimator::MetricsSource;
use continuer::coordinator::router::RoutePolicy;
use continuer::coordinator::scheduler::CandidateMetrics;
use continuer::coordinator::Failover;
use continuer::dnn::variants::Technique;
use continuer::runtime::HostTensor;
use continuer::util::bench::{f, Table};
use continuer::util::cli::Args;
use continuer::util::json::{obj, Json};
use continuer::workload::{generate, Arrival};

/// Stub predictions: the synthetic bench has no fitted models.
struct StubMetrics;

impl MetricsSource for StubMetrics {
    fn candidate_metrics(&self, failed: usize) -> anyhow::Result<Vec<CandidateMetrics>> {
        Ok(vec![CandidateMetrics {
            technique: Technique::SkipConnection(failed),
            accuracy: 85.0,
            latency_ms: 25.0,
            downtime_ms: 3.0,
        }])
    }

    fn reinstate_ms(&self) -> f64 {
        1.0
    }
}

struct ScaleCase {
    replicas: usize,
    wall_s: f64,
    events_per_sec: f64,
    report_build_ms: f64,
    json: Json,
}

fn scale_case(replicas: usize, n_requests: usize) -> ScaleCase {
    const NODES: usize = 4;
    const STAGE_MS: f64 = 5.0;
    const HOP_MS: f64 = 1.0;
    const DEPTH: usize = 4;
    // Near-saturating arrivals: the batch-16 bottleneck stage admits
    // ~3200 rps per replica; offer ~2500 per replica so queues stay
    // bounded and every request completes.
    let rate_rps = 2500.0 * replicas as f64;
    let span_est_ms = n_requests as f64 / (rate_rps / 1e3);

    let mut backends: Vec<SyntheticBackend> = (0..replicas)
        .map(|_| SyntheticBackend::uniform(NODES, STAGE_MS, HOP_MS))
        .collect();
    let mut failovers: Vec<Failover> = (0..replicas)
        .map(|_| Failover::new(Objectives::default()))
        .collect();
    // Every replica loses a node mid-run and gets it back, so failover,
    // requeue and reintegration all sit on the measured hot path.
    let plans: Vec<FailurePlan> = (0..replicas)
        .map(|r| {
            let node = 2 + (r % (NODES - 1));
            FailurePlan::crash_recover(node, 0.25 * span_est_ms, 0.1 * span_est_ms)
        })
        .collect();
    let cfg = EngineConfig {
        batcher: BatcherConfig::new(vec![1, 2, 4, 8, 16], 2.0, 16),
        health: HealthMode::Oracle(Detector::default()),
        deadline_ms: None,
        pipeline_depth: DEPTH,
        route: RoutePolicy::JoinShortestQueue,
        decision_ms_override: Some(1.5),
        // The point of the bench: no per-request records at 1M scale.
        record_completions: false,
    };
    let requests = generate(n_requests, Arrival::Poisson { rate_rps }, 16, 42);
    let inputs = HostTensor::zeros(vec![16, 4]);

    let t0 = Instant::now();
    let report = serve(
        &mut backends,
        &StubMetrics,
        &mut failovers,
        &cfg,
        &requests,
        &inputs,
        &plans,
    )
    .unwrap();
    let wall_s = t0.elapsed().as_secs_f64();

    // The zero-allocation steady state, asserted at scale.
    assert_eq!(
        report.completed_count + report.dropped.len(),
        n_requests,
        "bench must conserve requests"
    );
    assert!(
        report.completed.is_empty(),
        "streaming metrics must keep no per-request records"
    );
    assert!(
        report.plan_cache_misses <= 3 * replicas,
        "plans must be allocated per distinct failure, not per batch \
         ({} misses over {} batches)",
        report.plan_cache_misses,
        report.batches_dispatched
    );

    let events_per_sec = report.events_processed as f64 / wall_s.max(1e-9);
    let t1 = Instant::now();
    let json = obj(&[
        ("replicas", replicas.into()),
        ("pipeline_depth", DEPTH.into()),
        ("requests", n_requests.into()),
        ("arrival_rate_rps", rate_rps.into()),
        ("completed", report.completed_count.into()),
        ("dropped", report.dropped.len().into()),
        ("failovers", report.failovers.len().into()),
        ("events_processed", report.events_processed.into()),
        ("events_per_sec", events_per_sec.into()),
        ("wall_s", wall_s.into()),
        ("virtual_throughput_rps", report.throughput_rps.into()),
        ("peak_in_flight", report.max_in_flight.into()),
        ("batches_dispatched", report.batches_dispatched.into()),
        ("plans_allocated", report.plan_cache_misses.into()),
        ("plan_cache_hits", report.plan_cache_hits.into()),
        ("latency_mean_ms", report.latency.mean.into()),
        ("latency_p50_ms", report.latency.p50.into()),
        ("latency_p95_ms", report.latency.p95.into()),
        ("latency_p99_ms", report.latency.p99.into()),
    ]);
    let report_build_ms = t1.elapsed().as_secs_f64() * 1e3;
    ScaleCase {
        replicas,
        wall_s,
        events_per_sec,
        report_build_ms,
        json,
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).collect());
    let quick = args.flag("quick");
    let n_requests = if quick {
        20_000
    } else {
        args.get_usize("requests", 1_000_000)
            .expect("--requests expects an integer")
    };

    let mut t = Table::new(
        &format!("bench: engine scale — {n_requests} requests, 4-node synthetic, depth 4"),
        &["replicas", "wall s", "events/sec", "report build ms"],
    );
    let mut cases = Vec::new();
    for replicas in [1usize, 2, 4] {
        let c = scale_case(replicas, n_requests);
        t.row(&[
            c.replicas.to_string(),
            f(c.wall_s, 2),
            f(c.events_per_sec, 0),
            f(c.report_build_ms, 3),
        ]);
        let mut case = c.json;
        if let Json::Obj(m) = &mut case {
            m.insert("report_build_ms".into(), c.report_build_ms.into());
        }
        cases.push(case);
    }
    t.print();

    let out = obj(&[
        ("bench", "engine_scale".into()),
        ("requests", n_requests.into()),
        ("quick", quick.into()),
        ("nodes", 4usize.into()),
        ("stage_ms", 5.0.into()),
        ("hop_ms", 1.0.into()),
        ("cases", Json::Arr(cases)),
    ]);
    let path = "BENCH_engine_scale.json";
    std::fs::write(path, out.to_string()).unwrap();
    println!("wrote {path}");
}
