//! Property: for ANY seeded heartbeat/failure schedule — crashes with
//! recovery, gray failures, lossy/jittery monitoring channels, false
//! positives and all — the serving engine conserves requests
//! (completed + dropped == offered, with no duplicates) and terminates.
//!
//! This is the safety net under the whole health subsystem: however
//! wrong the monitor is about the world, no request may vanish or be
//! served twice, and the event loop must drain.

use continuer::cluster::failure::FailurePlan;
use continuer::config::Objectives;
use continuer::coordinator::batcher::BatcherConfig;
use continuer::coordinator::engine::{serve, EngineConfig, Execution, HealthMode, SyntheticBackend};
use continuer::coordinator::estimator::StaticMetrics;
use continuer::coordinator::router::RoutePolicy;
use continuer::coordinator::Failover;
use continuer::health::{DetectorKind, HealthConfig, HeartbeatConfig};
use continuer::runtime::HostTensor;
use continuer::util::proptest::{check, prop_assert, prop_assert_eq, Gen};
use continuer::workload::{generate, Arrival};

fn random_health(g: &mut Gen) -> HealthConfig {
    let detector = if g.bool() {
        DetectorKind::FixedTimeout {
            timeout_ms: g.f64(12.0, 120.0),
        }
    } else {
        DetectorKind::PhiAccrual {
            threshold: g.f64(0.5, 12.0),
            window: g.usize(4, 64),
            min_std_ms: g.f64(0.1, 2.0),
        }
    };
    HealthConfig {
        heartbeat: HeartbeatConfig {
            interval_ms: g.f64(5.0, 20.0),
            jitter_ms: g.f64(0.0, 4.0),
            loss_prob: g.f64(0.0, 0.3),
            blackout: if g.bool() {
                let start = g.f64(50.0, 400.0);
                Some((start, start + g.f64(20.0, 150.0)))
            } else {
                None
            },
        },
        detector,
        failover_slowdown: g.f64(1.5, 6.0),
        quarantine_ms: g.f64(0.0, 200.0),
        slowdown_window: g.usize(3, 12),
        seed: g.rng().next_u64(),
    }
}

fn random_plan(g: &mut Gen, nodes: usize, horizon_ms: f64) -> FailurePlan {
    let eligible: Vec<usize> = (1..=nodes).collect();
    let mut parts = Vec::new();
    // A churning crash/recovery renewal process...
    parts.push(FailurePlan::random_mtbf(
        &eligible,
        horizon_ms,
        g.f64(200.0, 2000.0),
        g.f64(30.0, 300.0),
        g.rng(),
    ));
    // ...plus an optional gray-failure window on a random node.
    if g.bool() {
        parts.push(FailurePlan::degraded(
            g.usize(1, nodes),
            g.f64(0.0, horizon_ms / 2.0),
            g.f64(1.2, 6.0),
            g.f64(20.0, horizon_ms / 2.0),
        ));
    }
    FailurePlan::merge(parts)
}

#[test]
fn engine_conserves_requests_under_arbitrary_health_schedules() {
    check(60, 0xC0A5E7, |g| {
        let replicas = g.usize(1, 2);
        let nodes = g.usize(3, 5);
        let n_requests = g.usize(5, 40);
        let horizon_ms = 600.0;

        let mut backends: Vec<SyntheticBackend> = (0..replicas)
            .map(|_| SyntheticBackend::uniform(nodes, g.f64(1.0, 8.0), 1.0))
            .collect();
        let mut failovers: Vec<Failover> = (0..replicas)
            .map(|_| Failover::new(Objectives::default()))
            .collect();
        let plans: Vec<FailurePlan> = (0..replicas)
            .map(|_| random_plan(g, nodes, horizon_ms))
            .collect();
        let cfg = EngineConfig {
            batcher: BatcherConfig::new(vec![1], 2.0, 1),
            health: HealthMode::Monitored(random_health(g)),
            deadline_ms: if g.bool() { Some(g.f64(20.0, 300.0)) } else { None },
            pipeline_depth: g.usize(1, 3),
            route: if g.bool() {
                RoutePolicy::RoundRobin
            } else {
                RoutePolicy::JoinShortestQueue
            },
            decision_ms_override: Some(1.5),
            // The property inspects per-request ids below.
            record_completions: true,
            speed_factors: Vec::new(),
            steal: false,
            event_queue: Default::default(),
            execution: Execution::Sequential,
            deployment: Default::default(),
        };
        let requests = generate(
            n_requests,
            Arrival::Poisson {
                rate_rps: g.f64(50.0, 600.0),
            },
            8,
            g.rng().next_u64(),
        );
        let inputs = HostTensor::zeros(vec![8, 4]);

        let report = serve(
            &mut backends,
            &StaticMetrics,
            &mut failovers,
            &cfg,
            &requests,
            &inputs,
            &plans,
        )
        .map_err(|e| format!("engine errored: {e}"))?;

        // Conservation: every offered request is either completed or
        // dropped, exactly once.
        prop_assert_eq(
            report.completed.len() + report.dropped.len(),
            n_requests,
        )?;
        prop_assert_eq(report.completed_count, report.completed.len())?;
        let mut ids: Vec<usize> = report
            .completed
            .iter()
            .map(|c| c.id)
            .chain(report.dropped.iter().map(|d| d.id))
            .collect();
        ids.sort_unstable();
        ids.dedup();
        prop_assert(ids.len() == n_requests, "duplicate or missing request ids")?;

        // Sanity: windows are well-formed and latencies are finite.
        for w in &report.failovers {
            prop_assert(w.end_ms >= w.start_ms, "negative downtime window")?;
        }
        prop_assert(
            report.completed.iter().all(|c| c.latency_ms.is_finite() && c.latency_ms >= 0.0),
            "non-finite completion latency",
        )?;
        Ok(())
    });
}

/// The oracle path must satisfy the same conservation law (regression
/// guard for the seed-compatible configuration).
#[test]
fn oracle_mode_conserves_requests_too() {
    use continuer::cluster::Detector;
    check(30, 0x0AC1E, |g| {
        let nodes = g.usize(3, 5);
        let n_requests = g.usize(5, 30);
        let mut backends = vec![SyntheticBackend::uniform(nodes, g.f64(1.0, 8.0), 1.0)];
        let mut failovers = vec![Failover::new(Objectives::default())];
        let plan = random_plan(g, nodes, 600.0);
        let cfg = EngineConfig {
            batcher: BatcherConfig::new(vec![1], 2.0, 1),
            health: HealthMode::Oracle(Detector::default()),
            deadline_ms: if g.bool() { Some(g.f64(20.0, 300.0)) } else { None },
            pipeline_depth: g.usize(1, 3),
            route: RoutePolicy::RoundRobin,
            decision_ms_override: Some(1.5),
            record_completions: true,
            speed_factors: Vec::new(),
            steal: false,
            event_queue: Default::default(),
            execution: Execution::Sequential,
            deployment: Default::default(),
        };
        let requests = generate(
            n_requests,
            Arrival::Poisson { rate_rps: g.f64(50.0, 600.0) },
            8,
            g.rng().next_u64(),
        );
        let inputs = HostTensor::zeros(vec![8, 4]);
        let report = serve(
            &mut backends,
            &StaticMetrics,
            &mut failovers,
            &cfg,
            &requests,
            &inputs,
            std::slice::from_ref(&plan),
        )
        .map_err(|e| format!("engine errored: {e}"))?;
        prop_assert_eq(report.completed.len() + report.dropped.len(), n_requests)?;
        Ok(())
    });
}
