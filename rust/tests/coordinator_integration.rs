//! Integration: profiler-phase models + estimator + scheduler over the
//! real artifacts (skipped when artifacts/ is absent).

use std::path::PathBuf;

use continuer::cluster::link::LinkModel;
use continuer::config::{Config, Objectives};
use continuer::coordinator::estimator::Estimator;
use continuer::coordinator::failover::{Failover, Mode};
use continuer::coordinator::profiler::DowntimeTable;
use continuer::coordinator::scheduler::select;
use continuer::dnn::variants::{candidates, Technique};
use continuer::predict::{AccuracyModel, GbdtParams, LatencyModel, LayerSample};
use continuer::runtime::ArtifactStore;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts/ (run `make artifacts`)");
        None
    }
}

struct Fixture {
    store: ArtifactStore,
    lat: LatencyModel,
    acc: AccuracyModel,
    link: LinkModel,
    cfg: Config,
}

fn fixture() -> Option<Fixture> {
    let dir = artifacts_dir()?;
    let store = ArtifactStore::open(&dir).unwrap();
    let params = GbdtParams::default();
    let metas: Vec<_> = store.models.values().collect();
    // Analytic flops-based samples: deterministic, fast, monotone.
    let samples: Vec<LayerSample> = metas
        .iter()
        .flat_map(|m| m.all_layers())
        .map(|l| LayerSample {
            spec: l.clone(),
            latency_ms: 1e-6 * l.flops() as f64 + 0.02,
        })
        .collect();
    let (lat, _) = LatencyModel::fit(&samples, &params, 0).unwrap();
    let (acc, quality) = AccuracyModel::fit(&metas, &params, 0).unwrap();
    assert!(
        quality.r2 > 0.5,
        "accuracy model should fit the history (r2 = {})",
        quality.r2
    );
    let cfg = Config::default();
    let link = LinkModel::new(cfg.link.clone());
    Some(Fixture {
        store,
        lat,
        acc,
        link,
        cfg,
    })
}

fn estimator<'a>(fx: &'a Fixture, model: &str, downtime: &'a DowntimeTable) -> Estimator<'a> {
    Estimator::new(
        fx.store.model(model).unwrap(),
        &fx.lat,
        &fx.acc,
        &fx.link,
        downtime,
        fx.cfg.reinstate_ms,
    )
}

#[test]
fn estimates_have_papers_shape() {
    let Some(fx) = fixture() else { return };
    let downtime = DowntimeTable::new();
    let est = estimator(&fx, "resnet32", &downtime);
    let meta = fx.store.model("resnet32").unwrap();

    // Repartition latency must be ~constant across failed nodes (paper
    // Fig. 7) while early-exit latency grows with the failed node index.
    let rep: Vec<f64> = (2..=meta.num_nodes)
        .map(|f| est.predict_latency_ms(Technique::Repartition, Some(f)))
        .collect();
    let spread = (continuer::util::stats::max(&rep) - continuer::util::stats::min(&rep))
        / continuer::util::stats::mean(&rep);
    assert!(spread < 0.15, "repartition latency spread {spread}");

    let exit_early = est.predict_latency_ms(Technique::EarlyExit(2), Some(3));
    let exit_late = est.predict_latency_ms(Technique::EarlyExit(12), Some(13));
    assert!(
        exit_late > exit_early * 2.0,
        "late exit {exit_late} should far exceed early exit {exit_early}"
    );

    // Skip should be cheaper than repartition (one block less + no extra
    // transfer beyond the reroute).
    let skip = est.predict_latency_ms(Technique::SkipConnection(3), Some(3));
    assert!(skip < rep[1] * 1.05, "skip {skip} vs repartition {}", rep[1]);

    // Accuracy ordering: repartition >= early exit at node 1 (ResNet's
    // first exit is its weakest classifier).
    let full_acc = est.predict_accuracy(Technique::Repartition).unwrap();
    let e1_acc = est.predict_accuracy(Technique::EarlyExit(1)).unwrap();
    assert!(
        full_acc > e1_acc,
        "full {full_acc}% should beat exit-1 {e1_acc}%"
    );
}

#[test]
fn failover_selects_and_switches_mode() {
    let Some(fx) = fixture() else { return };
    let downtime = DowntimeTable::new();
    for model in ["resnet32", "mobilenetv2"] {
        let est = estimator(&fx, model, &downtime);
        let meta = fx.store.model(model).unwrap();
        let failed = meta.skippable_nodes[0];
        let mut fo = Failover::new(Objectives::default());
        let report = fo.on_failure(&est, failed).unwrap();
        assert_eq!(report.candidates.len(), 3, "{model}: all three feasible");
        assert!(matches!(fo.mode, Mode::Degraded { .. }));
        assert!(report.downtime_ms() < 100.0, "{model}: downtime {} ms", report.downtime_ms());
        fo.on_recovery(failed);
        assert_eq!(fo.mode, Mode::Healthy);
    }
}

#[test]
fn objective_weights_flip_the_choice() {
    let Some(fx) = fixture() else { return };
    let downtime = DowntimeTable::new();
    let est = estimator(&fx, "resnet32", &downtime);
    let meta = fx.store.model("resnet32").unwrap();
    // Find a failure where accuracy-heavy and latency-heavy weights pick
    // different techniques (must exist given the trade-off).
    let mut flipped = false;
    for f in 2..=meta.num_nodes {
        let cands = est.candidate_metrics(f).unwrap();
        if cands.len() < 2 {
            continue;
        }
        let a = select(&cands, &Objectives::new(0.9, 0.05, 0.05)).unwrap().chosen;
        let b = select(&cands, &Objectives::new(0.05, 0.9, 0.05)).unwrap().chosen;
        if a != b {
            flipped = true;
            break;
        }
    }
    assert!(flipped, "weights never changed the selection");
}

#[test]
fn candidate_enumeration_matches_manifest() {
    let Some(fx) = fixture() else { return };
    for model in ["resnet32", "mobilenetv2"] {
        let meta = fx.store.model(model).unwrap();
        for f in 2..=meta.num_nodes {
            let c = candidates(meta, f);
            assert!(c.contains(&Technique::Repartition));
            assert_eq!(
                c.iter().any(|t| matches!(t, Technique::SkipConnection(_))),
                meta.skippable_nodes.contains(&f)
            );
            assert_eq!(
                c.iter().any(|t| matches!(t, Technique::EarlyExit(_))),
                meta.exit_nodes.contains(&(f - 1))
            );
        }
    }
}
