//! Property: the sharded engine IS the sequential engine, observably.
//!
//! 1. For ANY seeded per-replica workload with in-span oracle failures,
//!    running the same streams through `serve_routed` sequentially and
//!    sharded onto real threads (any worker count) yields the same
//!    merged report: bucket-identical latency histograms, identical
//!    completion/drop sets, identical failover windows and counters.
//!    (Oracle health keeps detection times a pure function of the plan;
//!    the monitored path's equivalence is covered by fixed fixtures in
//!    the engine's unit tests.)
//! 2. The JSQ-sharded path — which routes over live atomic load
//!    counters and is deliberately NOT bit-reproducible — still
//!    conserves requests: every offered request completes or drops
//!    exactly once, whatever the worker count.
//! 3. Fleet-aware extensions keep both promises: weighted round-robin
//!    (positional, like plain round-robin) stays bucket-exact between
//!    sequential and sharded on heterogeneous fleets, and the live JSQ
//!    family conserves requests under skewed speed factors, mid-run
//!    degradations, and cross-replica work stealing.
//!
//! Failure plans are kept well inside each replica's arrival span
//! (crash <= 0.3x, recovery <= 0.45x of the expected span): a shard
//! stops its clock when its own work drains, so a detection scheduled
//! past one replica's span would fire in the merged sequential run but
//! not in that replica's shard. In-span plans are the documented
//! contract for bucket-exact equivalence.

use continuer::cluster::failure::{Detector, FailurePlan};
use continuer::config::Objectives;
use continuer::coordinator::batcher::BatcherConfig;
use continuer::coordinator::engine::{
    serve, serve_routed, EngineConfig, Execution, HealthMode, SyntheticBackend,
};
use continuer::coordinator::estimator::StaticMetrics;
use continuer::coordinator::router::RoutePolicy;
use continuer::coordinator::{Failover, ServiceReport};
use continuer::runtime::HostTensor;
use continuer::util::proptest::{check, prop_assert, prop_assert_eq, PropResult};
use continuer::workload::{generate, generate_per_replica, Arrival, Request};

fn run_routed(
    replicas: usize,
    nodes: usize,
    stage_ms: f64,
    streams: &[Vec<Request>],
    plans: &[FailurePlan],
    cfg: &EngineConfig,
) -> ServiceReport {
    let mut backends: Vec<SyntheticBackend> = (0..replicas)
        .map(|_| SyntheticBackend::uniform(nodes, stage_ms, 1.0))
        .collect();
    let mut failovers: Vec<Failover> = (0..replicas)
        .map(|_| Failover::new(Objectives::default()))
        .collect();
    let inputs = HostTensor::zeros(vec![8, 4]);
    serve_routed(
        &mut backends,
        &StaticMetrics,
        &mut failovers,
        cfg,
        streams,
        &inputs,
        plans,
    )
    .unwrap()
}

/// The merged sharded report must match the sequential reference on
/// every observable the engine promises to preserve.
fn assert_reports_match(seq: &ServiceReport, shard: &ServiceReport) -> PropResult {
    prop_assert_eq(shard.completed_count, seq.completed_count)?;
    prop_assert_eq(shard.events_processed, seq.events_processed)?;
    prop_assert_eq(shard.batches_dispatched, seq.batches_dispatched)?;
    prop_assert_eq(shard.plan_cache_hits, seq.plan_cache_hits)?;
    prop_assert_eq(shard.plan_cache_misses, seq.plan_cache_misses)?;
    prop_assert_eq(shard.max_in_flight, seq.max_in_flight)?;
    prop_assert_eq(shard.false_failovers(), seq.false_failovers())?;
    prop_assert_eq(shard.degraded_drops(), seq.degraded_drops())?;
    // The global last event belongs to some replica, and that replica's
    // shard processes it at the same clock — spans agree exactly, and
    // with them the derived counters.
    prop_assert(
        shard.sim_span_ms == seq.sim_span_ms,
        &format!(
            "sim span diverged: sequential {} vs sharded {}",
            seq.sim_span_ms, shard.sim_span_ms
        ),
    )?;
    prop_assert(
        (shard.total_downtime_ms() - seq.total_downtime_ms()).abs() <= 1e-9,
        &format!(
            "downtime diverged: sequential {} vs sharded {}",
            seq.total_downtime_ms(),
            shard.total_downtime_ms()
        ),
    )?;
    let rps_tol = 1e-9 * seq.throughput_rps.abs().max(1.0);
    prop_assert(
        (shard.throughput_rps - seq.throughput_rps).abs() <= rps_tol,
        &format!(
            "throughput diverged: sequential {} vs sharded {}",
            seq.throughput_rps, shard.throughput_rps
        ),
    )?;

    // Bucket-for-bucket histogram equality (exact u64 adds commute).
    let (seq_low, seq_counts) = seq.latency_stream.hist().buckets();
    let (shard_low, shard_counts) = shard.latency_stream.hist().buckets();
    prop_assert_eq(shard_low, seq_low)?;
    prop_assert_eq(shard_counts, seq_counts)?;
    prop_assert_eq(shard.latency_stream.n(), seq.latency_stream.n())?;
    prop_assert(
        shard.latency_stream.min() == seq.latency_stream.min()
            && shard.latency_stream.max() == seq.latency_stream.max(),
        "latency min/max diverged between sequential and sharded",
    )?;
    // Welford pairwise combine reorders float adds: moments agree to
    // rounding, not to the bit.
    let tol = 1e-9 * seq.latency.mean.abs().max(1.0);
    prop_assert(
        (shard.latency.mean - seq.latency.mean).abs() <= tol,
        &format!(
            "mean diverged: sequential {} vs sharded {}",
            seq.latency.mean, shard.latency.mean
        ),
    )?;
    let std_tol = 1e-9 * seq.latency.std.abs().max(1.0);
    prop_assert(
        (shard.latency.std - seq.latency.std).abs() <= std_tol,
        &format!(
            "std diverged: sequential {} vs sharded {}",
            seq.latency.std, shard.latency.std
        ),
    )?;

    // Failover windows are plan-driven and must agree exactly.
    let windows = |r: &ServiceReport| {
        let mut w: Vec<String> = r.failovers.iter().map(|w| format!("{w:?}")).collect();
        w.sort();
        w
    };
    prop_assert_eq(windows(shard), windows(seq))?;

    // Drops: the (id, replica, arrival) set is mode-independent even
    // though drop *timestamps* may differ (the sequential engine prunes
    // every replica's queue at each event; a shard only at its own).
    let drops = |r: &ServiceReport| {
        let mut d: Vec<(usize, usize, u64)> = r
            .dropped
            .iter()
            .map(|d| (d.id, d.replica, d.arrival_ms.to_bits()))
            .collect();
        d.sort_unstable();
        d
    };
    prop_assert_eq(drops(shard), drops(seq))?;
    Ok(())
}

#[test]
fn sharded_matches_sequential_on_any_routed_workload() {
    check(40, 0x5AA2DED, |g| {
        let replicas = g.usize(1, 3);
        let nodes = g.usize(3, 5);
        let stage_ms = g.f64(1.0, 6.0);
        let n_per_replica = g.usize(80, 160);
        let rate_rps = g.f64(300.0, 600.0);
        let span_est_ms = n_per_replica as f64 / (rate_rps / 1e3);

        let streams = generate_per_replica(
            n_per_replica,
            Arrival::Poisson { rate_rps },
            8,
            g.rng().next_u64(),
            replicas,
        );
        // Crash + recovery well inside every replica's own span (see
        // the module docs for why that bounds exact equivalence).
        let plans: Vec<FailurePlan> = (0..replicas)
            .map(|_| {
                let node = g.usize(2, nodes);
                let down_ms = g.f64(0.05, 0.3) * span_est_ms;
                let up_ms = down_ms + g.f64(0.02, 0.15) * span_est_ms;
                FailurePlan::crash_recover(node, down_ms, up_ms)
            })
            .collect();
        let mut cfg = EngineConfig {
            batcher: BatcherConfig::new(vec![1, 4], 2.0, 4),
            health: HealthMode::Oracle(Detector::default()),
            deadline_ms: if g.bool() { Some(g.f64(40.0, 200.0)) } else { None },
            pipeline_depth: g.usize(1, 3),
            // Per-replica streams fix the assignment; the route policy
            // is irrelevant on this path.
            route: RoutePolicy::RoundRobin,
            decision_ms_override: Some(1.5),
            record_completions: false,
            speed_factors: Vec::new(),
            steal: false,
            event_queue: Default::default(),
            execution: Execution::Sequential,
            deployment: Default::default(),
        };
        let seq = run_routed(replicas, nodes, stage_ms, &streams, &plans, &cfg);
        prop_assert(
            seq.completed_count + seq.dropped.len() == replicas * n_per_replica,
            "sequential reference must conserve requests",
        )?;

        let workers = g.usize(1, 4);
        cfg.execution = Execution::Sharded(workers);
        let shard = run_routed(replicas, nodes, stage_ms, &streams, &plans, &cfg);
        assert_reports_match(&seq, &shard)
    });
}

#[test]
fn jsq_sharded_conserves_requests_for_any_worker_count() {
    check(30, 0x15011A7, |g| {
        let replicas = g.usize(2, 4);
        let nodes = g.usize(3, 5);
        let n_requests = g.usize(40, 200);
        let rate_rps = g.f64(200.0, 800.0);
        let span_est_ms = n_requests as f64 / (rate_rps / 1e3);

        let mut backends: Vec<SyntheticBackend> = (0..replicas)
            .map(|_| SyntheticBackend::uniform(nodes, g.f64(1.0, 6.0), 1.0))
            .collect();
        let mut failovers: Vec<Failover> = (0..replicas)
            .map(|_| Failover::new(Objectives::default()))
            .collect();
        let plans: Vec<FailurePlan> = (0..replicas)
            .map(|_| {
                let node = g.usize(2, nodes);
                let down_ms = g.f64(0.05, 0.3) * span_est_ms;
                FailurePlan::crash_recover(node, down_ms, down_ms + 0.2 * span_est_ms)
            })
            .collect();
        let cfg = EngineConfig {
            batcher: BatcherConfig::new(vec![1, 4], 2.0, 4),
            health: HealthMode::Oracle(Detector::default()),
            deadline_ms: if g.bool() { Some(g.f64(40.0, 200.0)) } else { None },
            pipeline_depth: g.usize(1, 3),
            route: RoutePolicy::JoinShortestQueue,
            decision_ms_override: Some(1.5),
            // The property inspects per-request ids below.
            record_completions: true,
            speed_factors: Vec::new(),
            steal: false,
            event_queue: Default::default(),
            execution: Execution::Sharded(g.usize(1, 4)),
            deployment: Default::default(),
        };
        let requests = generate(
            n_requests,
            Arrival::Poisson { rate_rps },
            8,
            g.rng().next_u64(),
        );
        let inputs = HostTensor::zeros(vec![8, 4]);
        let report = serve(
            &mut backends,
            &StaticMetrics,
            &mut failovers,
            &cfg,
            &requests,
            &inputs,
            &plans,
        )
        .map_err(|e| format!("engine errored: {e}"))?;

        prop_assert_eq(report.completed.len() + report.dropped.len(), n_requests)?;
        prop_assert_eq(report.completed_count, report.completed.len())?;
        let mut ids: Vec<usize> = report
            .completed
            .iter()
            .map(|c| c.id)
            .chain(report.dropped.iter().map(|d| d.id))
            .collect();
        ids.sort_unstable();
        let expected: Vec<usize> = (0..n_requests).collect();
        prop_assert(ids == expected, "request ids must partition 0..n exactly once")?;
        prop_assert(
            report
                .completed
                .iter()
                .all(|c| c.latency_ms.is_finite() && c.latency_ms >= 0.0),
            "non-finite completion latency",
        )?;
        Ok(())
    });
}

/// Same-seed byte-identity across the event-queue implementations: the
/// calendar queue must reproduce the heap's `ServiceReport` *exactly* —
/// not statistically, not bucket-for-bucket, but byte-for-byte in the
/// report's full `Debug` rendering — because the queues promise the
/// same pop order, and pop order is the only thing the engine consumes.
/// Covered modes: sequential, sharded (positional round-robin),
/// monitored health over a lossy jittered channel, and a repartition
/// deployment with cut-over events in flight.
mod queue_byte_identity {
    use continuer::baselines::AlwaysRepartition;
    use continuer::cluster::failure::{Detector, FailurePlan};
    use continuer::config::Objectives;
    use continuer::coordinator::batcher::BatcherConfig;
    use continuer::coordinator::engine::{
        serve, DeploymentConfig, EngineConfig, Execution, HealthMode, SyntheticBackend,
    };
    use continuer::coordinator::estimator::StaticMetrics;
    use continuer::coordinator::router::RoutePolicy;
    use continuer::coordinator::service::DeployMode;
    use continuer::coordinator::Failover;
    use continuer::health::{DetectorKind, HealthConfig, HeartbeatConfig};
    use continuer::runtime::HostTensor;
    use continuer::util::eventq::QueueKind;
    use continuer::workload::{generate, Arrival};

    fn base_cfg() -> EngineConfig {
        EngineConfig {
            batcher: BatcherConfig::new(vec![1, 4], 2.0, 4),
            health: HealthMode::Oracle(Detector::default()),
            deadline_ms: Some(120.0),
            pipeline_depth: 2,
            route: RoutePolicy::RoundRobin,
            decision_ms_override: Some(1.5),
            record_completions: true,
            speed_factors: Vec::new(),
            steal: false,
            event_queue: QueueKind::Heap,
            execution: Execution::Sequential,
            deployment: Default::default(),
        }
    }

    /// Run the same seeded two-replica crash/recovery fixture under the
    /// given config with each queue kind and return both reports'
    /// `Debug` renderings.
    fn both_queues(mut cfg: EngineConfig) -> (String, String) {
        let mut run = |kind: QueueKind| {
            cfg.event_queue = kind;
            let replicas = 2;
            let mut backends: Vec<SyntheticBackend> = (0..replicas)
                .map(|_| SyntheticBackend::uniform(4, 5.0, 1.0))
                .collect();
            let mut failovers: Vec<Failover> = (0..replicas)
                .map(|_| Failover::new(Objectives::default()))
                .collect();
            let reqs = generate(120, Arrival::Poisson { rate_rps: 500.0 }, 8, 23);
            let plans = vec![
                FailurePlan::crash_recover(2, 40.0, 120.0),
                FailurePlan::crash_recover(3, 60.0, 140.0),
            ];
            let inputs = HostTensor::zeros(vec![8, 4]);
            let report = serve(
                &mut backends,
                &StaticMetrics,
                &mut failovers,
                &cfg,
                &reqs,
                &inputs,
                &plans,
            )
            .unwrap();
            format!("{report:?}")
        };
        (run(QueueKind::Heap), run(QueueKind::Calendar))
    }

    #[test]
    fn sequential_report_is_byte_identical() {
        let (heap, calendar) = both_queues(base_cfg());
        assert_eq!(heap, calendar, "sequential: queue choice changed the report");
    }

    #[test]
    fn sharded_report_is_byte_identical() {
        // Positional round-robin: the sharded engine is deterministic,
        // so each shard's calendar must match each shard's heap — and
        // with them the merged report.
        let mut cfg = base_cfg();
        cfg.execution = Execution::Sharded(2);
        let (heap, calendar) = both_queues(cfg);
        assert_eq!(heap, calendar, "sharded: queue choice changed the report");
    }

    #[test]
    fn monitored_report_is_byte_identical() {
        // Monitored health floods the queue with heartbeat events on a
        // fixed interval — the calendar's worst case for same-bucket
        // collisions — and the channel's seeded jitter/loss draws must
        // come out in the same order under both queues.
        let mut cfg = base_cfg();
        cfg.health = HealthMode::Monitored(HealthConfig {
            heartbeat: HeartbeatConfig {
                interval_ms: 10.0,
                jitter_ms: 1.0,
                loss_prob: 0.1,
                blackout: None,
            },
            detector: DetectorKind::FixedTimeout { timeout_ms: 35.0 },
            failover_slowdown: f64::INFINITY,
            quarantine_ms: 20.0,
            slowdown_window: 8,
            seed: 7,
        });
        let (heap, calendar) = both_queues(cfg);
        assert_eq!(heap, calendar, "monitored: queue choice changed the report");
    }

    #[test]
    fn deploy_mode_report_is_byte_identical() {
        // Repartition deployment: the boxed Deploy events (transfer
        // done, warm-up done, cut-over) ride the queue alongside the
        // serving traffic and must fire in the same order.
        let mut cfg = base_cfg();
        cfg.deployment = DeploymentConfig {
            mode: DeployMode::MakeBeforeBreak,
            warmup_ms: 10.0,
        };
        let mut run = |kind: QueueKind| {
            cfg.event_queue = kind;
            let mut backends = vec![SyntheticBackend::uniform(4, 5.0, 1.0)
                .with_deployment(vec![1_000_000; 5], 25_000.0)];
            let mut failovers = vec![Failover::with_policy(Box::new(AlwaysRepartition))];
            let reqs = generate(300, Arrival::Poisson { rate_rps: 150.0 }, 8, 11);
            let inputs = HostTensor::zeros(vec![8, 4]);
            let report = serve(
                &mut backends,
                &StaticMetrics,
                &mut failovers,
                &cfg,
                &reqs,
                &inputs,
                &[FailurePlan::crash(3, 200.0)],
            )
            .unwrap();
            format!("{report:?}")
        };
        let heap = run(QueueKind::Heap);
        let calendar = run(QueueKind::Calendar);
        assert_eq!(heap, calendar, "deploy: queue choice changed the report");
    }
}

/// Weighted round-robin is positional: the sharded engine pre-splits
/// the stream with the same smooth-WRR schedule the sequential router
/// walks, so the bucket-exact equivalence contract extends to
/// heterogeneous fleets (skewed static speed factors).
#[test]
fn weighted_rr_sharded_matches_sequential_on_skewed_fleets() {
    check(25, 0x33EED5, |g| {
        let replicas = g.usize(2, 4);
        let nodes = g.usize(3, 5);
        let stage_ms = g.f64(1.0, 6.0);
        let n_requests = g.usize(80, 200);
        let rate_rps = g.f64(300.0, 700.0);
        let span_est_ms = n_requests as f64 / (rate_rps / 1e3);
        let speed_factors: Vec<f64> = (0..replicas).map(|_| g.f64(0.5, 1.5)).collect();
        // In-span crash + recovery per replica: even the least-weighted
        // replica keeps receiving arrivals across the whole stream (the
        // WRR interleave period is a handful of requests), so the
        // in-span contract from the module docs still applies.
        let plans: Vec<FailurePlan> = (0..replicas)
            .map(|_| {
                let node = g.usize(2, nodes);
                let down_ms = g.f64(0.05, 0.25) * span_est_ms;
                let up_ms = down_ms + g.f64(0.02, 0.15) * span_est_ms;
                FailurePlan::crash_recover(node, down_ms, up_ms)
            })
            .collect();
        let requests = generate(
            n_requests,
            Arrival::Poisson { rate_rps },
            8,
            g.rng().next_u64(),
        );
        let mut cfg = EngineConfig {
            batcher: BatcherConfig::new(vec![1, 4], 2.0, 4),
            health: HealthMode::Oracle(Detector::default()),
            deadline_ms: if g.bool() { Some(g.f64(40.0, 200.0)) } else { None },
            pipeline_depth: g.usize(1, 3),
            route: RoutePolicy::WeightedRoundRobin,
            decision_ms_override: Some(1.5),
            record_completions: false,
            speed_factors,
            steal: false,
            event_queue: Default::default(),
            execution: Execution::Sequential,
            deployment: Default::default(),
        };
        let run = |cfg: &EngineConfig| -> ServiceReport {
            let mut backends: Vec<SyntheticBackend> = (0..replicas)
                .map(|_| SyntheticBackend::uniform(nodes, stage_ms, 1.0))
                .collect();
            let mut failovers: Vec<Failover> = (0..replicas)
                .map(|_| Failover::new(Objectives::default()))
                .collect();
            let inputs = HostTensor::zeros(vec![8, 4]);
            serve(
                &mut backends,
                &StaticMetrics,
                &mut failovers,
                cfg,
                &requests,
                &inputs,
                &plans,
            )
            .unwrap()
        };
        let seq = run(&cfg);
        prop_assert(
            seq.completed_count + seq.dropped.len() == n_requests,
            "sequential reference must conserve requests",
        )?;
        cfg.execution = Execution::Sharded(g.usize(1, 4));
        let shard = run(&cfg);
        assert_reports_match(&seq, &shard)
    });
}

/// The fleet-aware live-routed path — skewed static speeds, mid-run
/// degradations on every replica, speed-weighted JSQ, work stealing on
/// or off — still conserves requests exactly: every offered request
/// completes or drops exactly once, whatever the worker count.
#[test]
fn skewed_degraded_fleet_with_stealing_conserves_requests() {
    check(30, 0x57EA1ED, |g| {
        let replicas = g.usize(2, 4);
        let nodes = g.usize(3, 5);
        let n_requests = g.usize(60, 200);
        let rate_rps = g.f64(200.0, 800.0);
        let span_est_ms = n_requests as f64 / (rate_rps / 1e3);
        let speed_factors: Vec<f64> = (0..replicas).map(|_| g.f64(0.4, 1.6)).collect();
        let steal = g.bool();
        let route = if g.bool() {
            RoutePolicy::WeightedJoinShortestQueue
        } else {
            RoutePolicy::JoinShortestQueue
        };

        let mut backends: Vec<SyntheticBackend> = (0..replicas)
            .map(|_| SyntheticBackend::uniform(nodes, g.f64(1.0, 6.0), 1.0))
            .collect();
        let mut failovers: Vec<Failover> = (0..replicas)
            .map(|_| Failover::new(Objectives::default()))
            .collect();
        // Every replica takes a degraded window somewhere inside the
        // stream: the weighted feeder sheds load off it, and (with
        // stealing on) its backlog migrates to healthy siblings — the
        // property holds either way.
        let plans: Vec<FailurePlan> = (0..replicas)
            .map(|_| {
                let node = g.usize(2, nodes);
                let at_ms = g.f64(0.05, 0.4) * span_est_ms;
                let duration_ms = g.f64(0.1, 0.4) * span_est_ms;
                FailurePlan::degraded(node, at_ms, g.f64(1.5, 4.0), duration_ms)
            })
            .collect();
        let cfg = EngineConfig {
            batcher: BatcherConfig::new(vec![1, 4], 2.0, 4),
            health: HealthMode::Oracle(Detector::default()),
            deadline_ms: if g.bool() { Some(g.f64(40.0, 200.0)) } else { None },
            pipeline_depth: g.usize(1, 3),
            route,
            decision_ms_override: Some(1.5),
            // The property inspects per-request ids below.
            record_completions: true,
            speed_factors,
            steal,
            event_queue: Default::default(),
            execution: Execution::Sharded(g.usize(1, 4)),
            deployment: Default::default(),
        };
        let requests = generate(
            n_requests,
            Arrival::Poisson { rate_rps },
            8,
            g.rng().next_u64(),
        );
        let inputs = HostTensor::zeros(vec![8, 4]);
        let report = serve(
            &mut backends,
            &StaticMetrics,
            &mut failovers,
            &cfg,
            &requests,
            &inputs,
            &plans,
        )
        .map_err(|e| format!("engine errored: {e}"))?;

        prop_assert_eq(report.completed.len() + report.dropped.len(), n_requests)?;
        let mut ids: Vec<usize> = report
            .completed
            .iter()
            .map(|c| c.id)
            .chain(report.dropped.iter().map(|d| d.id))
            .collect();
        ids.sort_unstable();
        let expected: Vec<usize> = (0..n_requests).collect();
        prop_assert(ids == expected, "request ids must partition 0..n exactly once")?;
        prop_assert(
            report
                .completed
                .iter()
                .all(|c| c.latency_ms.is_finite() && c.latency_ms >= 0.0),
            "non-finite completion latency",
        )?;
        Ok(())
    });
}
