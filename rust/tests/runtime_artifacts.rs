//! Integration tests over the real AOT artifacts (require `make artifacts`
//! to have run; they are skipped with a message when artifacts/ is absent).

use std::path::PathBuf;

use continuer::cluster::sim::{steps_for, EdgeCluster};
use continuer::config::LinkConfig;
use continuer::dnn::variants::Technique;
use continuer::runtime::{ArtifactStore, Engine, UnitKind};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts/ (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_parses_and_is_consistent() {
    let Some(dir) = artifacts_dir() else { return };
    let store = ArtifactStore::open(&dir).unwrap();
    assert!(store.models.contains_key("resnet32"));
    assert!(!store.micro.is_empty());
    let m = store.model("resnet32").unwrap();
    assert_eq!(m.num_nodes, 14);
    assert_eq!(m.exit_nodes.len(), 13);
    assert_eq!(m.skippable_nodes.len(), 10, "paper: 10 skip connections");
    // boundary chain consistency: out_shape of node i == in_shape of i+1
    for w in m.nodes.windows(2) {
        assert_eq!(w[0].out_shape, w[1].in_shape, "node {} boundary", w[0].index);
    }
    assert!(!m.history.is_empty());
}

#[test]
fn single_block_executes() {
    let Some(dir) = artifacts_dir() else { return };
    let store = ArtifactStore::open(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let unit = store
        .load_unit(&engine, "resnet32", UnitKind::Node(1), 1)
        .unwrap();
    let (images, _) = store.test_set().unwrap();
    let x = images.slice0(0, 1).unwrap();
    let y = unit.run(&engine, &x).unwrap();
    assert_eq!(y.shape, unit.out_shape);
    assert!(y.data.iter().all(|v| v.is_finite()));
}

#[test]
fn full_pipeline_matches_python_accuracy() {
    let Some(dir) = artifacts_dir() else { return };
    let store = ArtifactStore::open(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let meta = store.model("resnet32").unwrap();
    let cluster = EdgeCluster::new(&engine, &store, meta, LinkConfig::default(), 0);
    let (images, labels) = store.test_set().unwrap();
    let n = 32.min(images.shape[0]);
    let acc = cluster
        .measure_accuracy(
            Technique::Repartition,
            None,
            &images.slice0(0, n).unwrap(),
            &labels[..n],
            32,
        )
        .unwrap();
    // python-side full-test accuracy is ~0.99; a 32-sample slice should be
    // in the same regime if the rust pipeline computes the same function.
    let expected = meta.final_accuracy.repartition;
    assert!(
        (acc - expected).abs() < 0.15,
        "rust measured {acc} vs python {expected}"
    );
}

#[test]
fn exit_and_skip_paths_execute() {
    let Some(dir) = artifacts_dir() else { return };
    let store = ArtifactStore::open(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let meta = store.model("resnet32").unwrap();
    let cluster = EdgeCluster::new(&engine, &store, meta, LinkConfig::default(), 0);
    let (images, _) = store.test_set().unwrap();
    let x = images.slice0(0, 1).unwrap();

    let exit = meta.exit_nodes[2];
    let (logits, timing) = cluster
        .execute_steps(&steps_for(meta, Technique::EarlyExit(exit), Some(exit + 1)), &x)
        .unwrap();
    assert_eq!(*logits.shape.last().unwrap(), store.num_classes);
    assert!(timing.total_ms() > 0.0);

    let skip = meta.skippable_nodes[0];
    let (logits, _) = cluster
        .execute_steps(&steps_for(meta, Technique::SkipConnection(skip), Some(skip)), &x)
        .unwrap();
    assert_eq!(*logits.shape.last().unwrap(), store.num_classes);
}

#[test]
fn failed_node_rejects_execution() {
    let Some(dir) = artifacts_dir() else { return };
    let store = ArtifactStore::open(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let meta = store.model("resnet32").unwrap();
    let mut cluster = EdgeCluster::new(&engine, &store, meta, LinkConfig::default(), 0);
    cluster.fail(3);
    let (images, _) = store.test_set().unwrap();
    let x = images.slice0(0, 1).unwrap();
    // healthy path goes through node 3 -> must fail
    let err = cluster.execute_technique(Technique::Repartition, None, &x);
    assert!(err.is_err());
    // repartitioned path re-hosts node 3's block -> must succeed
    let ok = cluster.execute_technique(Technique::Repartition, Some(3), &x);
    assert!(ok.is_ok(), "{:?}", ok.err());
}
