//! Properties of the streaming metrics path:
//!
//! 1. Histogram-derived p50/p95/p99 stay within one bucket's relative
//!    error of the exact sorted-vector percentiles on seeded random
//!    workloads (the interpolated exact percentile lies between two
//!    adjacent order statistics; the histogram answer must land within
//!    one bucket's width of that bracket).
//! 2. Same-seed engine runs remain byte-identical with
//!    `record_completions` on, and flipping the flag changes only the
//!    per-request record vector — every streamed aggregate matches.

use continuer::cluster::failure::{Detector, FailurePlan};
use continuer::config::Objectives;
use continuer::coordinator::batcher::BatcherConfig;
use continuer::coordinator::engine::{serve, EngineConfig, Execution, HealthMode, SyntheticBackend};
use continuer::coordinator::estimator::StaticMetrics;
use continuer::coordinator::router::RoutePolicy;
use continuer::coordinator::{Failover, ServiceReport};
use continuer::runtime::HostTensor;
use continuer::util::histogram::LogHistogram;
use continuer::util::proptest::{check, prop_assert};
use continuer::workload::{generate, Arrival};

#[test]
fn histogram_percentiles_track_exact_sorted_percentiles() {
    const GROWTH: f64 = 1.02;
    check(200, 0x5EED1, |g| {
        // Mixed-scale latencies: some runs tight, some heavy-tailed.
        let scale = g.f64(1.0, 500.0);
        let mut xs = g.vec_f64(0.01, scale, 1..400);
        if g.bool() {
            // Inject a far tail so percentile buckets spread out.
            let tail = g.f64(scale, scale * 50.0);
            xs.push(tail);
        }
        let mut h = LogHistogram::latency_default();
        for &x in &xs {
            h.record(x);
        }
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        for q in [50.0, 95.0, 99.0] {
            let approx = h.quantile(q);
            // The exact interpolated percentile lies between these two
            // order statistics; the histogram must land within one
            // bucket's relative width of that bracket.
            let pos = (q / 100.0) * (sorted.len() - 1) as f64;
            let lo = sorted[pos.floor() as usize];
            let hi = sorted[pos.ceil() as usize];
            prop_assert(
                approx >= lo / GROWTH && approx <= hi * GROWTH,
                &format!(
                    "q{q}: histogram {approx} outside [{}, {}] (n={})",
                    lo / GROWTH,
                    hi * GROWTH,
                    sorted.len()
                ),
            )?;
        }
        Ok(())
    });
}

fn engine_run(record_completions: bool, seed: u64) -> ServiceReport {
    let mut backends = vec![
        SyntheticBackend::uniform(4, 5.0, 1.0),
        SyntheticBackend::uniform(4, 5.0, 1.0),
    ];
    let mut failovers = vec![
        Failover::new(Objectives::default()),
        Failover::new(Objectives::default()),
    ];
    let cfg = EngineConfig {
        batcher: BatcherConfig::new(vec![1, 4], 2.0, 4),
        health: HealthMode::Oracle(Detector::default()),
        deadline_ms: Some(500.0),
        pipeline_depth: 3,
        route: RoutePolicy::JoinShortestQueue,
        decision_ms_override: Some(1.5),
        record_completions,
        speed_factors: Vec::new(),
        steal: false,
        event_queue: Default::default(),
        execution: Execution::Sequential,
        deployment: Default::default(),
    };
    let requests = generate(120, Arrival::Poisson { rate_rps: 600.0 }, 8, seed);
    let inputs = HostTensor::zeros(vec![8, 4]);
    serve(
        &mut backends,
        &StaticMetrics,
        &mut failovers,
        &cfg,
        &requests,
        &inputs,
        &[FailurePlan::crash_recover(3, 25.0, 60.0)],
    )
    .unwrap()
}

#[test]
fn same_seed_runs_byte_identical_with_recording_on() {
    let a = format!("{:?}", engine_run(true, 7));
    let b = format!("{:?}", engine_run(true, 7));
    assert_eq!(a, b, "same-seed recorded runs must be byte-identical");
}

#[test]
fn same_seed_runs_byte_identical_with_streaming_only() {
    let a = format!("{:?}", engine_run(false, 7));
    let b = format!("{:?}", engine_run(false, 7));
    assert_eq!(a, b, "same-seed streaming runs must be byte-identical");
}

#[test]
fn recording_flag_changes_only_the_record_vector() {
    let on = engine_run(true, 11);
    let off = engine_run(false, 11);
    assert_eq!(on.completed.len(), on.completed_count);
    assert!(off.completed.is_empty());
    assert_eq!(on.completed_count, off.completed_count);
    assert_eq!(format!("{:?}", on.latency), format!("{:?}", off.latency));
    assert_eq!(format!("{:?}", on.dropped), format!("{:?}", off.dropped));
    assert_eq!(format!("{:?}", on.failovers), format!("{:?}", off.failovers));
    assert_eq!(on.throughput_rps, off.throughput_rps);
    assert_eq!(on.sim_span_ms, off.sim_span_ms);
    assert_eq!(on.events_processed, off.events_processed);
    assert_eq!(on.batches_dispatched, off.batches_dispatched);
    assert_eq!(on.plan_cache_hits, off.plan_cache_hits);
    assert_eq!(on.plan_cache_misses, off.plan_cache_misses);
}
