//! Integration: the full serving loop with failure injection — a short
//! end-to-end run asserting service continuity across a failover
//! (skipped when artifacts/ is absent).

use std::path::PathBuf;

use continuer::config::Config;
use continuer::exper::e2e::{run_e2e, E2eParams};
use continuer::exper::ExpContext;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts/ (run `make artifacts`)");
        None
    }
}

#[test]
fn service_survives_node_failure() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = Config::default();
    cfg.artifacts_dir = dir;
    let ctx = ExpContext::open(cfg).unwrap();
    let meta = ctx.store.model("resnet32").unwrap();
    let fail_node = meta.skippable_nodes[meta.skippable_nodes.len() / 2];
    let p = E2eParams {
        model: "resnet32".into(),
        n_requests: 16,
        rate_rps: 8.0,
        fail_node,
        fail_at_ms: 700.0,
    };
    let report = run_e2e(&ctx, &p).unwrap();

    // every request completed despite the mid-run failure
    assert_eq!(report.completed.len(), 16, "dropped={}", report.dropped);
    assert_eq!(report.dropped, 0);

    // exactly one failover happened and it picked a real technique
    assert_eq!(report.failovers.len(), 1);
    let (start, end, tech) = report.failovers[0];
    assert!(start >= 700.0, "detection at {start} >= failure time");
    assert!(end - start < 200.0, "downtime {} ms", end - start);
    // requests served after the failover carry the chosen technique
    let after: Vec<_> = report
        .completed
        .iter()
        .filter(|c| c.technique.is_some())
        .collect();
    assert!(!after.is_empty(), "some requests must be served degraded");
    assert!(after.iter().all(|c| c.technique.unwrap() == tech));

    // latency is finite and sane
    assert!(report.latency.mean > 0.0);
    assert!(report.latency.p99 < 60_000.0);
    assert!(report.throughput_rps > 0.0);
}

#[test]
fn service_healthy_run_no_failovers() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = Config::default();
    cfg.artifacts_dir = dir;
    let ctx = ExpContext::open(cfg).unwrap();
    let p = E2eParams {
        model: "mobilenetv2".into(),
        n_requests: 8,
        rate_rps: 10.0,
        fail_node: 3,
        fail_at_ms: 1e12, // never
    };
    let report = run_e2e(&ctx, &p).unwrap();
    assert_eq!(report.completed.len(), 8);
    assert!(report.failovers.is_empty());
    assert!(report
        .completed
        .iter()
        .all(|c| c.technique.is_none()), "all healthy");
}
