//! Integration: the full serving engine with failure injection — short
//! end-to-end runs asserting service continuity across a failover, in the
//! seed-equivalent single-pipeline configuration and in a pipelined
//! multi-replica one (skipped when artifacts/ is absent).

use std::path::PathBuf;

use continuer::config::Config;
use continuer::exper::e2e::{run_e2e, E2eParams};
use continuer::exper::ExpContext;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts/ (run `make artifacts`)");
        None
    }
}

#[test]
fn service_survives_node_failure() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = Config::default();
    cfg.artifacts_dir = dir;
    let ctx = ExpContext::open(cfg).unwrap();
    let meta = ctx.store.model("resnet32").unwrap();
    let fail_node = meta.skippable_nodes[meta.skippable_nodes.len() / 2];
    let p = E2eParams::single("resnet32".into(), 16, 8.0, fail_node, 700.0);
    let report = run_e2e(&ctx, &p).unwrap();

    // every request completed despite the mid-run failure
    assert_eq!(report.completed.len(), 16, "dropped={}", report.dropped.len());
    assert!(report.dropped.is_empty());

    // the non-pipelined configuration reproduces the seed's one-batch-
    // in-flight serving regime
    assert_eq!(report.max_in_flight, 1);

    // exactly one failover happened and it picked a real technique
    assert_eq!(report.failovers.len(), 1);
    let w = report.failovers[0];
    assert!(w.start_ms >= 700.0, "detection at {} >= failure time", w.start_ms);
    assert!(w.downtime_ms() < 200.0, "downtime {} ms", w.downtime_ms());
    // requests served after the failover carry the chosen technique
    let after: Vec<_> = report
        .completed
        .iter()
        .filter(|c| c.technique.is_some())
        .collect();
    assert!(!after.is_empty(), "some requests must be served degraded");
    assert!(after.iter().all(|c| c.technique.unwrap() == w.technique));

    // latency is finite and sane
    assert!(report.latency.mean > 0.0);
    assert!(report.latency.p99 < 60_000.0);
    assert!(report.throughput_rps > 0.0);
}

#[test]
fn service_healthy_run_no_failovers() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = Config::default();
    cfg.artifacts_dir = dir;
    let ctx = ExpContext::open(cfg).unwrap();
    let p = E2eParams::single("mobilenetv2".into(), 8, 10.0, 3, 1e12 /* never */);
    let report = run_e2e(&ctx, &p).unwrap();
    assert_eq!(report.completed.len(), 8);
    assert!(report.failovers.is_empty());
    assert!(report
        .completed
        .iter()
        .all(|c| c.technique.is_none()), "all healthy");
}

#[test]
fn multi_replica_pipelined_serving_isolates_failure() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = Config::default();
    cfg.artifacts_dir = dir;
    let ctx = ExpContext::open(cfg).unwrap();
    let meta = ctx.store.model("resnet32").unwrap();
    let fail_node = meta.skippable_nodes[meta.skippable_nodes.len() / 2];
    // Saturating arrivals so join-shortest-queue spreads traffic over both
    // replicas; the failure lands mid-stream on replica 0.
    let p = E2eParams {
        model: "resnet32".into(),
        n_requests: 12,
        rate_rps: 200.0,
        fail_node,
        fail_at_ms: 30.0,
        replicas: 2,
        pipeline_depth: 2,
        monitored: false,
    };
    let report = run_e2e(&ctx, &p).unwrap();

    assert_eq!(report.completed.len(), 12, "dropped={}", report.dropped.len());
    // the failure hits replica 0 only
    assert_eq!(report.failovers.len(), 1);
    assert_eq!(report.failovers[0].replica, 0);
    // replica 1 keeps serving the healthy full pipeline throughout
    assert!(report
        .completed
        .iter()
        .filter(|c| c.replica == 1)
        .all(|c| c.technique.is_none()));
    // both replicas took traffic
    assert!(report.completed.iter().any(|c| c.replica == 0));
    assert!(report.completed.iter().any(|c| c.replica == 1));
}
