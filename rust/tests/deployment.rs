//! Integration properties of the repartition deployment model.
//!
//! 1. Make-before-break conserves every request across the cut-over:
//!    nothing drops, nothing requeues, and completions carry the
//!    fallback technique through the window then the repartitioned plan
//!    after it.
//! 2. Break-before-make's dispatch stall equals the modeled
//!    transfer + warm-up span exactly — downtime is the model, not an
//!    emergent accident.
//! 3. Sequential and sharded execution agree on the same seeded
//!    deployment scenario, deployment windows included.
//! 4. The zero-movement degenerate configuration (no weight bytes)
//!    reproduces the `Instantaneous` engine byte-for-byte, whatever the
//!    configured mode.
//! 5. A recovery that lands mid-deployment abandons it: the window
//!    closes uncompleted at the rollback instant.

use continuer::baselines::AlwaysRepartition;
use continuer::cluster::failure::{Detector, FailurePlan};
use continuer::coordinator::batcher::BatcherConfig;
use continuer::coordinator::engine::{
    serve, serve_routed, DeploymentConfig, EngineConfig, Execution, HealthMode, SyntheticBackend,
};
use continuer::coordinator::estimator::StaticMetrics;
use continuer::coordinator::router::RoutePolicy;
use continuer::coordinator::service::{DeployMode, ServiceReport};
use continuer::coordinator::Failover;
use continuer::dnn::variants::Technique;
use continuer::runtime::HostTensor;
use continuer::workload::{generate, generate_per_replica, Arrival, Request};

const NODES: usize = 4;
const CRASH_NODE: usize = 3;
/// 1 MB over 25 kB/ms: a 40 ms transfer for the one re-hosted block.
const WEIGHT_BYTES: usize = 1_000_000;
const BYTES_PER_MS: f64 = 25_000.0;
const WARMUP_MS: f64 = 10.0;
const SPAN_MS: f64 = WEIGHT_BYTES as f64 / BYTES_PER_MS + WARMUP_MS;

fn cfg(mode: DeployMode, warmup_ms: f64, deadline_ms: Option<f64>) -> EngineConfig {
    EngineConfig {
        batcher: BatcherConfig::new(vec![1], 2.0, 1),
        health: HealthMode::Oracle(Detector::default()),
        deadline_ms,
        pipeline_depth: 1,
        route: RoutePolicy::RoundRobin,
        decision_ms_override: Some(1.5),
        record_completions: true,
        speed_factors: Vec::new(),
        steal: false,
        event_queue: Default::default(),
        execution: Execution::Sequential,
        deployment: DeploymentConfig { mode, warmup_ms },
    }
}

fn deploy_backend() -> SyntheticBackend {
    SyntheticBackend::uniform(NODES, 5.0, 1.0)
        .with_deployment(vec![WEIGHT_BYTES; NODES + 1], BYTES_PER_MS)
}

/// One replica, repartition forced, crash per `plan`.
fn run_one(cfg: &EngineConfig, backend: SyntheticBackend, plan: FailurePlan) -> ServiceReport {
    let mut backends = vec![backend];
    let mut failovers = vec![Failover::with_policy(Box::new(AlwaysRepartition))];
    let requests = generate(300, Arrival::Poisson { rate_rps: 150.0 }, 8, 11);
    let inputs = HostTensor::zeros(vec![8, 4]);
    serve(
        &mut backends,
        &StaticMetrics,
        &mut failovers,
        cfg,
        &requests,
        &inputs,
        &[plan],
    )
    .unwrap()
}

#[test]
fn make_before_break_conserves_requests_across_cutover() {
    let report = run_one(
        &cfg(DeployMode::MakeBeforeBreak, WARMUP_MS, None),
        deploy_backend(),
        FailurePlan::crash(CRASH_NODE, 200.0),
    );
    // Conservation: every offered request completes, nothing drops or
    // requeues at the cut-over.
    assert_eq!(report.completed_count, 300);
    assert!(report.dropped.is_empty(), "dropped: {:?}", report.dropped);
    // One deployment, completed, served through by the repartition-free
    // fallback (StaticMetrics offers skip-connection), zero stall.
    assert_eq!(report.deploy_windows.len(), 1);
    let w = &report.deploy_windows[0];
    assert_eq!(w.mode, DeployMode::MakeBeforeBreak);
    assert!(w.completed);
    assert_eq!(w.fallback, Some(Technique::SkipConnection(CRASH_NODE)));
    assert_eq!(report.deploy_stall_ms(), 0.0);
    // Completions walk healthy -> fallback -> repartitioned: the window
    // is long enough (50 ms at 150 rps) that the fallback must serve.
    let tagged =
        |t: Option<Technique>| report.completed.iter().filter(|c| c.technique == t).count();
    assert!(tagged(None) > 0, "healthy completions before the crash");
    assert!(
        tagged(Some(Technique::SkipConnection(CRASH_NODE))) > 0,
        "fallback must serve through the deployment window"
    );
    assert!(
        tagged(Some(Technique::Repartition)) > 0,
        "repartitioned plan must serve after the cut-over"
    );
}

#[test]
fn break_before_make_stall_is_exactly_the_modeled_span() {
    let report = run_one(
        &cfg(DeployMode::BreakBeforeMake, WARMUP_MS, None),
        deploy_backend(),
        FailurePlan::crash(CRASH_NODE, 200.0),
    );
    assert_eq!(report.deploy_windows.len(), 1);
    let w = &report.deploy_windows[0];
    assert_eq!(w.mode, DeployMode::BreakBeforeMake);
    assert!(w.completed);
    assert_eq!(w.fallback, None, "break-before-make has no fallback");
    assert!((w.transfer_ms - WEIGHT_BYTES as f64 / BYTES_PER_MS).abs() < 1e-9);
    assert!((w.warmup_ms - WARMUP_MS).abs() < 1e-9);
    assert!(
        (w.duration_ms() - SPAN_MS).abs() < 1e-9,
        "window duration {} != modeled span {SPAN_MS}",
        w.duration_ms()
    );
    assert!((report.deploy_stall_ms() - SPAN_MS).abs() < 1e-9);
    // No deadline: the stall queues requests, it does not shed them.
    assert_eq!(report.completed_count, 300);
    assert!(report.dropped.is_empty());
}

fn run_routed_deploy(streams: &[Vec<Request>], cfg: &EngineConfig) -> ServiceReport {
    let replicas = streams.len();
    let mut backends: Vec<SyntheticBackend> = (0..replicas).map(|_| deploy_backend()).collect();
    let mut failovers: Vec<Failover> = (0..replicas)
        .map(|_| Failover::with_policy(Box::new(AlwaysRepartition)))
        .collect();
    let inputs = HostTensor::zeros(vec![8, 4]);
    // Both replicas crash mid-stream, well inside their arrival spans.
    let plans = vec![FailurePlan::crash(2, 80.0), FailurePlan::crash(3, 120.0)];
    serve_routed(
        &mut backends,
        &StaticMetrics,
        &mut failovers,
        cfg,
        streams,
        &inputs,
        &plans,
    )
    .unwrap()
}

#[test]
fn sharded_execution_reproduces_deployments() {
    let streams = generate_per_replica(120, Arrival::Poisson { rate_rps: 300.0 }, 8, 0xD3, 2);
    let mut c = cfg(DeployMode::MakeBeforeBreak, 5.0, None);
    let seq = run_routed_deploy(&streams, &c);
    c.execution = Execution::Sharded(2);
    let shard = run_routed_deploy(&streams, &c);

    assert_eq!(shard.completed_count, seq.completed_count);
    let (seq_low, seq_counts) = seq.latency_stream.hist().buckets();
    let (shard_low, shard_counts) = shard.latency_stream.hist().buckets();
    assert_eq!(shard_low, seq_low);
    assert_eq!(shard_counts, seq_counts);

    // Deployment windows are plan-driven state: the merged sharded
    // report must carry the sequential run's windows exactly.
    let key = |r: &ServiceReport| {
        let mut w = r.deploy_windows.clone();
        w.sort_by_key(|w| (w.start_ms.to_bits(), w.replica));
        w
    };
    assert_eq!(seq.deploy_windows.len(), 2, "one deployment per replica");
    assert_eq!(key(&shard), key(&seq));
    let windows = |r: &ServiceReport| {
        let mut w: Vec<String> = r.failovers.iter().map(|w| format!("{w:?}")).collect();
        w.sort();
        w
    };
    assert_eq!(windows(&shard), windows(&seq));
}

#[test]
fn zero_movement_deployment_degenerates_to_instantaneous() {
    // No weight bytes configured: repartitioning moves nothing, so a
    // deployment-aware engine must behave exactly like the legacy
    // instantaneous swap — same completions, drops, windows, cache
    // counters, bit-identical aggregates — in either mode.
    let plan = || FailurePlan::crash_recover(CRASH_NODE, 100.0, 160.0);
    let base = run_one(
        &cfg(DeployMode::Instantaneous, 0.0, Some(80.0)),
        SyntheticBackend::uniform(NODES, 5.0, 1.0),
        plan(),
    );
    for mode in [DeployMode::BreakBeforeMake, DeployMode::MakeBeforeBreak] {
        // A nonzero warm-up must be irrelevant when nothing transfers.
        let r = run_one(
            &cfg(mode, 25.0, Some(80.0)),
            SyntheticBackend::uniform(NODES, 5.0, 1.0),
            plan(),
        );
        assert!(r.deploy_windows.is_empty(), "{mode:?} deployed nothing");
        assert_eq!(r.completed, base.completed);
        assert_eq!(r.dropped, base.dropped);
        assert_eq!(r.failovers, base.failovers);
        assert_eq!(r.completed_count, base.completed_count);
        assert_eq!(r.plan_cache_hits, base.plan_cache_hits);
        assert_eq!(r.plan_cache_misses, base.plan_cache_misses);
        assert_eq!(r.latency.mean.to_bits(), base.latency.mean.to_bits());
        assert_eq!(r.latency.std.to_bits(), base.latency.std.to_bits());
        assert_eq!(r.throughput_rps.to_bits(), base.throughput_rps.to_bits());
        assert_eq!(r.sim_span_ms.to_bits(), base.sim_span_ms.to_bits());
        let (low, counts) = base.latency_stream.hist().buckets();
        let (rlow, rcounts) = r.latency_stream.hist().buckets();
        assert_eq!(rlow, low);
        assert_eq!(rcounts, counts);
    }
}

#[test]
fn recovery_mid_deployment_abandons_the_window() {
    // Crash at 100 ms, recovery at 130 ms — inside the 50 ms deployment
    // span, so the cut-over never happens: the rollback is a routing
    // flip and the half-transferred partition is abandoned.
    let report = run_one(
        &cfg(DeployMode::BreakBeforeMake, WARMUP_MS, None),
        deploy_backend(),
        FailurePlan::crash_recover(CRASH_NODE, 100.0, 130.0),
    );
    assert_eq!(report.deploy_windows.len(), 1);
    let w = &report.deploy_windows[0];
    assert!(!w.completed, "recovery must abandon the deployment");
    assert!(
        w.duration_ms() < SPAN_MS,
        "abandoned window {} must close before the span {SPAN_MS}",
        w.duration_ms()
    );
    // The abandoned break-before-make window still stalled dispatch for
    // its (truncated) duration.
    assert!((report.deploy_stall_ms() - w.duration_ms()).abs() < 1e-12);
    // Dispatch stalled through the whole (abandoned) window, so the
    // repartitioned plan never served a single request — the rollback
    // put the replica straight back on the healthy full pipeline.
    assert!(!report
        .completed
        .iter()
        .any(|c| c.technique == Some(Technique::Repartition)));
    assert_eq!(report.completed_count, 300);
    assert!(report.dropped.is_empty());
    let healthy = report.completed.iter().filter(|c| c.technique.is_none()).count();
    assert!(healthy > 0, "healthy completions resume after recovery");
}
