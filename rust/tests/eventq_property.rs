//! Property suite for the event-queue implementations: the adaptive
//! calendar queue must pop in *exactly* the order the `BinaryHeap`
//! reference does — same times, same seqs, same items — under arbitrary
//! push/pop schedules. Every engine result rides on this equivalence
//! (`EngineConfig::event_queue` defaults to `Calendar`), so the
//! properties push hard on the calendar's edge cases: same-timestamp
//! ties, grow/shrink rebuilds, sparse far-future schedules, and
//! interleaved pops that rewind the bucket cursor.

use continuer::util::eventq::{
    AnyQueue, CalendarQueue, EventQueue, HeapQueue, QueueKind,
};
use continuer::util::proptest::{check, prop_assert, prop_assert_eq, PropResult};

/// Drive both queues through the same schedule of pushes (with
/// occasional interleaved pops) and assert every pop — and every
/// `peek_time` — agrees. `ops` is a list of (time, item) pushes; a
/// `None` slot pops from both instead.
fn lockstep(ops: &[Option<(f64, u32)>]) -> PropResult {
    let mut heap = HeapQueue::new();
    let mut cal = CalendarQueue::new();
    let mut seq = 0u64;
    for op in ops {
        match op {
            Some((t, item)) => {
                seq += 1;
                heap.push(*t, seq, *item);
                cal.push(*t, seq, *item);
            }
            None => {
                prop_assert_eq(heap.pop(), cal.pop())?;
            }
        }
        prop_assert_eq(heap.peek_time(), cal.peek_time())?;
        prop_assert_eq(heap.len(), cal.len())?;
    }
    while !heap.is_empty() || !cal.is_empty() {
        prop_assert_eq(heap.pop(), cal.pop())?;
    }
    Ok(())
}

#[test]
fn calendar_matches_heap_on_arbitrary_schedules() {
    check(200, 0xE7E, |g| {
        let n = g.usize(1, 120);
        let horizon = g.f64(1.0, 5_000.0);
        let ops: Vec<Option<(f64, u32)>> = (0..n)
            .map(|i| {
                if g.bool() && i > 0 {
                    None // interleaved pop
                } else {
                    Some((g.f64(0.0, horizon), i as u32))
                }
            })
            .collect();
        lockstep(&ops)
    });
}

#[test]
fn same_timestamp_ties_pop_in_seq_order() {
    // Clusters of identical timestamps: the FIFO tie-break is the whole
    // determinism contract, and in the calendar it exercises the
    // intra-bucket (at_ms, seq) ordering rather than bucket selection.
    check(200, 0x71E5, |g| {
        let n_times = g.usize(1, 8);
        let times: Vec<f64> = (0..n_times).map(|_| g.f64(0.0, 100.0)).collect();
        let n = g.usize(1, 80);
        let ops: Vec<Option<(f64, u32)>> = (0..n)
            .map(|i| {
                if g.bool() && i > 2 {
                    None
                } else {
                    Some((*g.pick(&times), i as u32))
                }
            })
            .collect();
        lockstep(&ops)
    });
}

#[test]
fn resize_boundaries_preserve_order() {
    // Push far past the grow threshold (len > 2 × buckets, starting at
    // 8), drain below the shrink threshold, push again: every rebuild
    // retunes the bucket width from observed gaps and must not disturb
    // the pop order.
    check(60, 0x9E51, |g| {
        let mut ops: Vec<Option<(f64, u32)>> = Vec::new();
        let mut item = 0u32;
        for _wave in 0..g.usize(1, 4) {
            let pushes = g.usize(20, 120); // well past 2×8
            let scale = g.f64(0.01, 1_000.0); // retune target varies per wave
            for _ in 0..pushes {
                ops.push(Some((g.f64(0.0, scale), item)));
                item += 1;
            }
            for _ in 0..g.usize(10, pushes) {
                ops.push(None); // drain through the shrink threshold
            }
        }
        lockstep(&ops)
    });
}

#[test]
fn monotone_engine_shaped_schedules_match() {
    // The engine's real pattern: pops advance a virtual clock and every
    // push lands at or after it (the watermark invariant), so the
    // calendar's cursor only ever moves forward. Sparse heartbeat-like
    // far-future events ride along.
    check(100, 0xC10C, |g| {
        let mut heap = HeapQueue::new();
        let mut cal = CalendarQueue::new();
        let mut seq = 0u64;
        let mut clock = 0.0f64;
        for i in 0..g.usize(10, 200) {
            if g.bool() || heap.is_empty() {
                seq += 1;
                let far = if g.rng().bool(0.1) { 1_000.0 } else { 1.0 };
                let t = clock + g.f64(0.0, 20.0) * far;
                heap.push(t, seq, i as u32);
                cal.push(t, seq, i as u32);
            } else {
                let a = heap.pop();
                let b = cal.pop();
                prop_assert_eq(a, b)?;
                if let Some((t, _, _)) = a {
                    prop_assert(t >= clock, "pops must be non-decreasing")?;
                    clock = t;
                }
            }
        }
        while let Some(a) = heap.pop() {
            prop_assert_eq(Some(a), cal.pop())?;
        }
        prop_assert(cal.pop().is_none(), "calendar must drain with the heap")
    });
}

#[test]
fn any_queue_dispatch_matches_direct_implementations() {
    // AnyQueue is what the engine actually holds: both kinds must
    // behave exactly like the queue they wrap.
    check(60, 0xA17, |g| {
        let mut any_heap = AnyQueue::new(QueueKind::Heap);
        let mut any_cal = AnyQueue::new(QueueKind::Calendar);
        let mut reference = HeapQueue::new();
        for s in 0..g.usize(1, 100) as u64 {
            let t = g.f64(0.0, 500.0);
            any_heap.push(t, s, s);
            any_cal.push(t, s, s);
            reference.push(t, s, s);
        }
        while let Some(want) = reference.pop() {
            prop_assert_eq(Some(want), any_heap.pop())?;
            prop_assert_eq(Some(want), any_cal.pop())?;
        }
        prop_assert(
            any_heap.pop().is_none() && any_cal.pop().is_none(),
            "all queues drain together",
        )
    });
}
