//! End-to-end properties of the Chrome `trace_event` export
//! (`continuer trace`): schema validity, same-seed byte determinism,
//! and Sequential-vs-Sharded span equivalence.

use std::collections::{BTreeMap, BTreeSet};

use continuer::coordinator::engine::Execution;
use continuer::exper::trace_export::record_with;
use continuer::obs::trace::chrome_trace;
use continuer::util::json::Json;

const REQUESTS: usize = 400;
const REPLICAS: usize = 2;
const SEED: u64 = 9;

fn trace_events(doc: &Json) -> &[Json] {
    doc.get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array")
}

fn ph(e: &Json) -> &str {
    e.get("ph").and_then(Json::as_str).unwrap_or("")
}

fn num(e: &Json, key: &str) -> f64 {
    e.get(key)
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("event missing numeric '{key}': {e:?}"))
}

/// Every `ph:"X"` span carries finite, non-negative ts/dur, and spans
/// on the same (pid, tid) track never overlap once time-ordered; every
/// track referenced by a span has pid and tid metadata.
#[test]
fn spans_are_valid_and_non_overlapping_per_track() {
    let events = record_with(REQUESTS, REPLICAS, SEED, Execution::Sequential).unwrap();
    let doc = chrome_trace(&events);
    let evs = trace_events(&doc);

    let mut named_processes: BTreeSet<u64> = BTreeSet::new();
    let mut named_threads: BTreeSet<(u64, u64)> = BTreeSet::new();
    for e in evs.iter().filter(|e| ph(e) == "M") {
        let pid = num(e, "pid") as u64;
        match e.get("name").and_then(Json::as_str) {
            Some("process_name") => {
                named_processes.insert(pid);
            }
            Some("thread_name") => {
                named_threads.insert((pid, num(e, "tid") as u64));
            }
            other => panic!("unexpected metadata record {other:?}"),
        }
    }
    assert_eq!(named_processes.len(), REPLICAS, "one process per replica");

    let mut tracks: BTreeMap<(u64, u64), Vec<(f64, f64)>> = BTreeMap::new();
    let mut spans = 0usize;
    for e in evs.iter().filter(|e| ph(e) == "X") {
        let (ts, dur) = (num(e, "ts"), num(e, "dur"));
        assert!(ts.is_finite() && ts >= 0.0, "bad ts in {e:?}");
        assert!(dur.is_finite() && dur >= 0.0, "bad dur in {e:?}");
        let track = (num(e, "pid") as u64, num(e, "tid") as u64);
        assert!(
            named_processes.contains(&track.0) && named_threads.contains(&track),
            "span on unnamed track {track:?}"
        );
        tracks.entry(track).or_default().push((ts, dur));
        spans += 1;
    }
    assert!(spans > 0, "the demo scenario must produce duration events");

    for (track, ranges) in &mut tracks {
        ranges.sort_by(|a, b| a.0.total_cmp(&b.0));
        for w in ranges.windows(2) {
            let ((t0, d0), (t1, _)) = (w[0], w[1]);
            assert!(
                t0 + d0 <= t1 + 1e-6,
                "overlapping spans on track {track:?}: [{t0}, {}] then start {t1}",
                t0 + d0
            );
        }
    }

    // Instants are well-formed too (scoped, finite timestamp).
    for e in evs.iter().filter(|e| ph(e) == "i") {
        assert_eq!(e.get("s").and_then(Json::as_str), Some("t"));
        assert!(num(e, "ts").is_finite());
    }
}

/// The export is a pure function of (workload, seed): two independent
/// recordings render byte-for-byte identical JSON.
#[test]
fn same_seed_traces_are_byte_identical() {
    let a = chrome_trace(&record_with(REQUESTS, REPLICAS, SEED, Execution::Sequential).unwrap());
    let b = chrome_trace(&record_with(REQUESTS, REPLICAS, SEED, Execution::Sequential).unwrap());
    assert_eq!(a.to_string(), b.to_string());
    assert_ne!(
        a.to_string(),
        chrome_trace(&record_with(REQUESTS, REPLICAS, SEED + 1, Execution::Sequential).unwrap())
            .to_string(),
        "different seeds must not collide"
    );
}

/// Sharded execution buffers events per shard and merges them; the
/// exported trace must contain the same work — equal span counts per
/// category and equal stage-span counts per (replica, node) track —
/// as the sequential reference.
#[test]
fn sequential_and_sharded_traces_carry_the_same_spans() {
    let seq = chrome_trace(&record_with(REQUESTS, REPLICAS, SEED, Execution::Sequential).unwrap());
    let shard =
        chrome_trace(&record_with(REQUESTS, REPLICAS, SEED, Execution::Sharded(2)).unwrap());

    let census = |doc: &Json| {
        let mut by_cat: BTreeMap<String, usize> = BTreeMap::new();
        let mut stage_tracks: BTreeMap<(u64, u64), usize> = BTreeMap::new();
        for e in trace_events(doc).iter().filter(|e| ph(e) == "X") {
            let cat = e.get("cat").and_then(Json::as_str).unwrap_or("").to_string();
            if cat == "stage" {
                *stage_tracks
                    .entry((num(e, "pid") as u64, num(e, "tid") as u64))
                    .or_insert(0) += 1;
            }
            *by_cat.entry(cat).or_insert(0) += 1;
        }
        (by_cat, stage_tracks)
    };
    let (seq_cats, seq_tracks) = census(&seq);
    let (shard_cats, shard_tracks) = census(&shard);
    assert!(seq_cats.get("stage").copied().unwrap_or(0) > 0);
    assert!(seq_cats.get("failover").copied().unwrap_or(0) > 0);
    assert_eq!(seq_cats, shard_cats);
    assert_eq!(seq_tracks, shard_tracks);
}
