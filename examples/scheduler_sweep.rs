//! Scheduler sweep: shows how the user-defined objective weights change
//! CONTINUER's choice for the same failure — the paper's central trade-off
//! (accuracy vs latency vs downtime) made visible.
//!
//! Run: `cargo run --release --example scheduler_sweep -- [--model m] [--fail-node k]`

use anyhow::Result;

use continuer::baselines::all_policies;
use continuer::config::{Config, Objectives};
use continuer::coordinator::estimator::Estimator;
use continuer::coordinator::profiler::DowntimeTable;
use continuer::coordinator::scheduler::select;
use continuer::exper::table2::layer_samples;
use continuer::exper::{default_artifacts_dir, require_artifacts, ExpContext};
use continuer::predict::{AccuracyModel, GbdtParams, LatencyModel};
use continuer::util::bench::Table;
use continuer::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).collect());
    let mut cfg = Config::default();
    cfg.artifacts_dir = default_artifacts_dir();
    require_artifacts(&cfg.artifacts_dir)?;
    let ctx = ExpContext::open(cfg)?;

    let model = args.get_or("model", "resnet32").to_string();
    let meta = ctx.store.model(&model)?;
    let failed = args.get_usize("fail-node", meta.skippable_nodes[meta.skippable_nodes.len() / 2])?;

    let params = GbdtParams::default();
    let samples = layer_samples(&ctx)?;
    let (lat_model, _) = LatencyModel::fit(&samples, &params, 0)?;
    let metas: Vec<_> = ctx.store.models.values().collect();
    let (acc_model, _) = AccuracyModel::fit(&metas, &params, 0)?;
    let link = continuer::cluster::link::LinkModel::new(ctx.config.link.clone());
    let downtime = DowntimeTable::new();
    let est = Estimator::new(
        meta,
        &lat_model,
        &acc_model,
        &link,
        &downtime,
        ctx.config.reinstate_ms,
    );
    let candidates = est.candidate_metrics(failed)?;

    println!("failure of node {failed} on {model}; candidates:");
    for c in &candidates {
        println!(
            "  {:20} acc {:6.2}%  latency {:7.2} ms  downtime {:.2} ms",
            c.technique.label(),
            c.accuracy,
            c.latency_ms,
            c.downtime_ms
        );
    }

    // Sweep characteristic weightings.
    let mut t = Table::new(
        "choice vs objective weights (w_acc, w_lat, w_down)",
        &["weights", "chosen technique"],
    );
    for (wa, wl, wd) in [
        (0.9, 0.05, 0.05),
        (0.05, 0.9, 0.05),
        (0.05, 0.05, 0.9),
        (0.5, 0.3, 0.2),
        (0.33, 0.33, 0.33),
        (0.2, 0.6, 0.2),
        (0.6, 0.2, 0.2),
    ] {
        let w = Objectives::new(wa, wl, wd);
        let d = select(&candidates, &w)?;
        t.row(&[format!("({wa:.2}, {wl:.2}, {wd:.2})"), d.chosen.label()]);
    }
    t.print();

    // Baseline policies for comparison.
    let mut t = Table::new("baseline policies", &["policy", "chosen technique"]);
    for p in all_policies(Objectives::default()) {
        t.row(&[p.name().to_string(), p.decide(&candidates)?.chosen.label()]);
    }
    t.print();
    Ok(())
}
