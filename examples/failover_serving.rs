//! End-to-end serving driver (the mandated full-system validation): serve
//! a poisson request stream through the distributed ResNet-32 pipeline,
//! crash a node mid-run, and report throughput/latency before vs after
//! CONTINUER's failover. Supports replica sharding and stage-level
//! pipelining via the event-driven engine. Results are recorded in
//! EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example failover_serving -- [--model m]
//!       [--requests n] [--rate rps] [--fail-node k] [--fail-at ms]
//!       [--replicas r] [--depth d] [--monitored]`
//!
//! `--monitored` detects failures through the simulated heartbeat
//! monitor (phi-accrual, false positives, quarantine) instead of the
//! oracle detector.

use anyhow::Result;

use continuer::config::Config;
use continuer::exper::e2e::{print_report, run_e2e, E2eParams};
use continuer::exper::{default_artifacts_dir, require_artifacts, ExpContext};
use continuer::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).collect());
    let mut cfg = Config::default();
    cfg.artifacts_dir = default_artifacts_dir();
    require_artifacts(&cfg.artifacts_dir)?;
    let ctx = ExpContext::open(cfg)?;

    let model = args.get_or("model", "resnet32").to_string();
    let meta = ctx.store.model(&model)?;
    let default_fail = meta
        .skippable_nodes
        .get(meta.skippable_nodes.len() / 2)
        .copied()
        .unwrap_or(meta.num_nodes / 2);
    let p = E2eParams {
        model,
        n_requests: args.get_usize("requests", 60)?,
        rate_rps: args.get_f64("rate", 6.0)?,
        fail_node: args.get_usize("fail-node", default_fail)?,
        fail_at_ms: args.get_f64("fail-at", 4000.0)?,
        replicas: args.get_usize("replicas", 1)?,
        pipeline_depth: args.get_usize("depth", 1)?,
        monitored: args.flag("monitored") || args.get("monitored") == Some("true"),
    };
    let report = run_e2e(&ctx, &p)?;
    print_report(&p, &report);
    Ok(())
}
