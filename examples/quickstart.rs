//! Quickstart: load the AOT artifacts, deploy ResNet-32 across the
//! simulated edge cluster, run one inference through the distributed
//! pipeline, then fail a node and watch CONTINUER pick a recovery
//! technique.
//!
//! Run: `cargo run --release --example quickstart`
//! (requires `make artifacts`)

use anyhow::Result;

use continuer::cluster::sim::EdgeCluster;
use continuer::config::Config;
use continuer::coordinator::estimator::Estimator;
use continuer::coordinator::failover::Failover;
use continuer::coordinator::profiler::DowntimeTable;
use continuer::dnn::variants::Technique;
use continuer::exper::{default_artifacts_dir, require_artifacts};
use continuer::predict::{AccuracyModel, GbdtParams, LatencyModel, LayerSample};
use continuer::runtime::{ArtifactStore, Engine};

fn main() -> Result<()> {
    let mut cfg = Config::default();
    cfg.artifacts_dir = default_artifacts_dir();
    require_artifacts(&cfg.artifacts_dir)?;

    // --- load the runtime + artifacts (python is NOT involved) ----------
    let engine = Engine::cpu()?;
    let store = ArtifactStore::open(&cfg.artifacts_dir)?;
    let meta = store.model("resnet32")?;
    println!(
        "loaded {}: {} nodes, {} exits, full accuracy {:.1}%",
        meta.name,
        meta.num_nodes,
        meta.exits.len(),
        meta.final_accuracy.repartition * 100.0
    );

    // --- deploy on the simulated edge cluster ---------------------------
    let mut cluster = EdgeCluster::new(&engine, &store, meta, cfg.link.clone(), cfg.seed);
    let (images, labels) = store.test_set()?;
    let x = images.slice0(0, 1)?;

    let (logits, timing) = cluster.execute_technique(Technique::Repartition, None, &x)?;
    println!(
        "healthy inference: predicted class {} (label {}), {:.2} ms compute + {:.2} ms network",
        logits.argmax_rows()[0],
        labels[0],
        timing.total_compute_ms(),
        timing.network_ms
    );

    // --- fail a node and let CONTINUER decide ---------------------------
    let failed = 7usize;
    cluster.fail(failed);
    println!("\n*** node {failed} failed ***");

    // Fit the two prediction models (normally done once, offline). A tiny
    // analytic latency sample set keeps the quickstart fast; see
    // `continuer exp table2` for the real profiling sweep.
    let params = GbdtParams::default();
    let samples: Vec<LayerSample> = meta
        .all_layers()
        .iter()
        .map(|l| LayerSample {
            spec: (*l).clone(),
            latency_ms: 1e-6 * l.flops() as f64 + 0.02,
        })
        .collect();
    let (lat_model, _) = LatencyModel::fit(&samples, &params, 0)?;
    let metas: Vec<_> = store.models.values().collect();
    let (acc_model, _) = AccuracyModel::fit(&metas, &params, 0)?;
    let link = continuer::cluster::link::LinkModel::new(cfg.link.clone());
    let downtime = DowntimeTable::new();
    let est = Estimator::new(
        meta,
        &lat_model,
        &acc_model,
        &link,
        &downtime,
        cfg.reinstate_ms,
    );

    let mut failover = Failover::new(cfg.objectives.clone());
    let report = failover.on_failure(&est, failed)?;
    for c in &report.candidates {
        println!(
            "  candidate {:20} acc {:6.2}%  latency {:7.2} ms  downtime {:.2} ms",
            c.technique.label(),
            c.accuracy,
            c.latency_ms,
            c.downtime_ms
        );
    }
    println!(
        "CONTINUER chose {} in {:.2} ms",
        report.decision.chosen.label(),
        report.downtime_ms()
    );

    // --- keep serving with the chosen technique -------------------------
    let (logits, timing) =
        cluster.execute_technique(report.decision.chosen, Some(failed), &x)?;
    println!(
        "degraded inference: predicted class {} (label {}), {:.2} ms total",
        logits.argmax_rows()[0],
        labels[0],
        timing.total_ms()
    );
    Ok(())
}
