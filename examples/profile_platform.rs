//! Platform profiling walk-through: run the layer micro-benchmark sweep,
//! fit the per-layer-type latency models for both platforms, and compare
//! predicted vs measured end-to-end latency of the healthy pipeline — the
//! profiler phase of the paper in one program.
//!
//! Run: `cargo run --release --example profile_platform -- [--model m]`

use anyhow::Result;

use continuer::cluster::sim::{healthy_path, EdgeCluster};
use continuer::config::{Config, Platform};
use continuer::coordinator::profiler::fit_platform;
use continuer::dnn::variants::Technique;
use continuer::exper::table2::layer_samples;
use continuer::exper::{default_artifacts_dir, require_artifacts, ExpContext};
use continuer::predict::GbdtParams;
use continuer::util::bench::{f, Table};
use continuer::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).collect());
    let mut cfg = Config::default();
    cfg.artifacts_dir = default_artifacts_dir();
    require_artifacts(&cfg.artifacts_dir)?;
    let ctx = ExpContext::open(cfg)?;
    let model = args.get_or("model", "resnet32").to_string();
    let meta = ctx.store.model(&model)?;

    // 1. micro sweep (cached in artifacts/results after the first run)
    let samples = layer_samples(&ctx)?;
    println!("profiled {} layer configurations", samples.len());

    // 2. fit per-platform models
    let params = GbdtParams::default();
    let mut t = Table::new(
        "latency predictor quality per platform",
        &["platform", "layer kinds", "mean R2"],
    );
    let mut fitted = Vec::new();
    for platform in [Platform::Host, Platform::platform2()] {
        let fp = fit_platform(&samples, platform, &params, ctx.config.seed)?;
        let mean_r2 =
            fp.quality.iter().map(|q| q.r2).sum::<f64>() / fp.quality.len().max(1) as f64;
        t.row(&[
            fp.platform.name(),
            fp.quality.len().to_string(),
            f(mean_r2, 3),
        ]);
        fitted.push(fp);
    }
    t.print();

    // 3. predicted vs measured end-to-end (healthy pipeline, platform 1)
    let cluster = EdgeCluster::new(&ctx.engine, &ctx.store, meta, ctx.config.link.clone(), 0);
    let (images, _) = ctx.store.test_set()?;
    let sample = images.slice0(0, 1)?;
    let (comp, net) = cluster.measure_latency_split(Technique::Repartition, None, &sample, 5)?;
    let predicted: f64 = meta
        .nodes
        .iter()
        .map(|n| fitted[0].model.predict_path(n.layers.iter()))
        .sum::<f64>()
        + cluster.expected_network_ms(&healthy_path(meta));
    println!(
        "\n{model} healthy pipeline: measured {:.2} ms ({comp:.2} compute + {net:.2} network), predicted {:.2} ms ({:+.1}% error)",
        comp + net,
        predicted,
        100.0 * (predicted - comp - net) / (comp + net)
    );
    Ok(())
}
